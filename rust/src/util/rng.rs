//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! Every stochastic component in the library (workload samplers, test
//! data, property tests) takes an explicit `Rng` so runs are exactly
//! reproducible from a seed — the paper's convergence experiment depends
//! on this.

/// xoshiro256** 1.0 (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-sample / per-thread rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough
    /// for workload sampling; n must be > 0).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }

    /// `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.range(-5, 17);
            assert!((-5..17).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(100, 20);
        let mut u = idx.clone();
        u.sort();
        u.dedup();
        assert_eq!(u.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
