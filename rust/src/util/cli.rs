//! Tiny CLI argument parser (clap is not vendored in this image).
//!
//! Grammar: `prog <subcommand> [positional]... [--key value]... [--flag]...`
//! Positionals must precede the first `--` option (a later bare token
//! binds as the preceding option's value).  Unrecognised keys — and
//! positionals the subcommand never reads — are an error at `finish()`
//! so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    positionals: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    used: std::cell::RefCell<Vec<String>>,
    positionals_used: std::cell::Cell<bool>,
}

impl Args {
    pub fn parse_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
                while let Some(p) = it.peek() {
                    if p.starts_with("--") {
                        break;
                    }
                    out.positionals.extend(it.next());
                }
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{a}'"))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.kv.insert(key, v);
                }
                _ => out.flags.push(key),
            }
        }
        Ok(out)
    }

    /// Bare tokens between the subcommand and the first `--` option.
    pub fn positionals(&self) -> &[String] {
        self.positionals_used.set(true);
        &self.positionals
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.used.borrow_mut().push(key.to_string());
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad float '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.used.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any provided-but-never-queried option (typo guard).
    pub fn finish(&self) -> Result<(), String> {
        if !self.positionals.is_empty() && !self.positionals_used.get() {
            return Err(format!("unexpected argument(s): {}", self.positionals.join(", ")));
        }
        let used = self.used.borrow();
        let unknown: Vec<&str> = self
            .kv
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
            .filter(|k| !used.iter().any(|u| u == k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown option(s): --{}", unknown.join(", --")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = args("train --steps 100 --preset tiny --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get_or("preset", "x"), "tiny");
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let a = args("bench");
        assert_eq!(a.get_usize("iters", 7).unwrap(), 7);
        assert_eq!(a.get_f64("scale", 1.5).unwrap(), 1.5);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_flag_fails_finish() {
        let a = args("run --oops 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_type() {
        let a = args("run --steps abc");
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn positionals_before_options() {
        let a = args("lint rust/src rust/benches --json --strict level");
        assert_eq!(a.subcommand.as_deref(), Some("lint"));
        assert_eq!(a.positionals(), ["rust/src", "rust/benches"]);
        assert!(a.flag("json"));
        assert_eq!(a.get_or("strict", ""), "level");
        a.finish().unwrap();
    }

    #[test]
    fn unread_positionals_fail_finish() {
        let a = args("train extra-token --steps 5");
        let _ = a.get_usize("steps", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = args("--x 1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_or("x", ""), "1");
    }
}
