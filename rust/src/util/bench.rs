//! Micro-benchmark harness (criterion is not vendored in this image).
//!
//! Mirrors the paper's measurement protocol (appendix A.4/A.5): warm-up
//! iterations followed by timed runs, reporting the mean plus robust
//! percentiles.  Used by all `rust/benches/*` targets (built with
//! `harness = false` so `cargo bench` runs them directly).

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub p10_ms: f64,
    pub p90_ms: f64,
}

impl BenchStats {
    pub fn mean_s(&self) -> f64 {
        self.mean_ms / 1e3
    }
}

/// Benchmark configuration.  The paper uses 10 warm-up + 100 timed runs;
/// our CPU engine is slower per call, so callers scale these down while
/// keeping the protocol shape.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
    /// Hard wall-clock budget; iteration stops early (but never below 3
    /// timed runs) once exceeded.
    pub max_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 2, iters: 10, max_seconds: 10.0 }
    }
}

pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchStats {
    for _ in 0..opts.warmup {
        f();
    }
    let budget = Instant::now();
    let mut samples_ms: Vec<f64> = Vec::with_capacity(opts.iters);
    for i in 0..opts.iters {
        let t0 = Instant::now();
        f();
        samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        if budget.elapsed().as_secs_f64() > opts.max_seconds && i >= 2 {
            break;
        }
    }
    samples_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        let idx = ((samples_ms.len() - 1) as f64 * p).round() as usize;
        samples_ms[idx]
    };
    BenchStats {
        name: name.to_string(),
        iters: samples_ms.len(),
        mean_ms: samples_ms.iter().sum::<f64>() / samples_ms.len() as f64,
        median_ms: pct(0.5),
        p10_ms: pct(0.1),
        p90_ms: pct(0.9),
    }
}

/// Time a single invocation (for expensive end-to-end cases).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench(
            "spin",
            BenchOpts { warmup: 1, iters: 20, max_seconds: 5.0 },
            || {
                let mut x = 0u64;
                for i in 0..10_000 {
                    x = x.wrapping_add(i);
                }
                std::hint::black_box(x);
            },
        );
        assert!(s.p10_ms <= s.median_ms && s.median_ms <= s.p90_ms);
        assert!(s.mean_ms > 0.0);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn budget_cuts_iterations() {
        let s = bench(
            "sleepy",
            BenchOpts { warmup: 0, iters: 1000, max_seconds: 0.05 },
            || std::thread::sleep(std::time::Duration::from_millis(10)),
        );
        assert!(s.iters < 1000);
        assert!(s.iters >= 3);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ms) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }
}
