//! Aligned ASCII table printer for the bench harnesses — the benches
//! print the same row layout as the paper's Tables 4–14 so results can
//! be eyeballed side by side.

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: vec![], title: None }
    }

    pub fn title<S: Into<String>>(mut self, t: S) -> Table {
        self.title = Some(t.into());
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with engineering-style width (helper for bench rows).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "ms"]).title("demo");
        t.row(vec!["a", "1.00"]);
        t.row(vec!["longer-name", "12.34"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].find("1.00"), lines[4].find("12.34"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
