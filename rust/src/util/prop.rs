//! Mini property-testing harness (proptest is not vendored; see
//! DESIGN.md §Substitutions).
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it
//! for `cases` seeds and reports the failing seed so a reproduction is
//! one function call away.  No shrinking — failures print their seed and
//! properties are written to generate small cases to begin with.

use super::rng::Rng;

pub struct PropConfig {
    pub cases: u64,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, base_seed: 0xF1A5_4A5C }
    }
}

/// Run `prop` for `cfg.cases` derived seeds; panic with the seed on the
/// first failure (properties signal failure by returning `Err(msg)`).
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check_default("add-commutes", |rng| {
            let (a, b) = (rng.range(-100, 100), rng.range(-100, 100));
            prop_assert!(a + b == b + a, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check(
            "always-fails",
            PropConfig { cases: 3, base_seed: 1 },
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<i64> = vec![];
        check(
            "record",
            PropConfig { cases: 5, base_seed: 9 },
            |rng| {
                first.push(rng.range(0, 1000));
                Ok(())
            },
        );
        let mut second: Vec<i64> = vec![];
        check(
            "record2",
            PropConfig { cases: 5, base_seed: 9 },
            |rng| {
                second.push(rng.range(0, 1000));
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
