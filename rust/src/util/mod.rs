//! std-only utility substrates (the offline image vendors no general
//! crates — see DESIGN.md §Substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
