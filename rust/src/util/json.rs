//! Minimal JSON parser/writer (serde is not vendored in this image).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for `artifacts/manifest.json` and metrics output.  Not a general
//! serde replacement: no zero-copy, no streaming.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from `(key, value)` pairs — the report/bench blob
    /// constructor (later duplicate keys win, matching map insert).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path access: `j.path(&["model", "n_params"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("eof in string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or("eof in escape")? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("short \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                    self.i += 1;
                }
                _ => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn obj_constructor_builds_maps() {
        let v = Json::obj(vec![("b", Json::Num(2.0)), ("a", Json::Bool(true))]);
        assert_eq!(v.get("a"), Some(&Json::Bool(true)));
        assert_eq!(v.get("b").and_then(Json::as_f64), Some(2.0));
        // later duplicate keys win (map insert semantics)
        let v = Json::obj(vec![("k", Json::Num(1.0)), ("k", Json::Num(2.0))]);
        assert_eq!(v.get("k").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn access_helpers() {
        let v = parse(r#"{"model": {"n_params": 3300000}, "arr": [10, 20]}"#).unwrap();
        assert_eq!(v.path(&["model", "n_params"]).unwrap().as_i64(), Some(3_300_000));
        assert_eq!(v.get("arr").unwrap().idx(1).unwrap().as_usize(), Some(20));
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""aéb""#).unwrap();
        assert_eq!(v.as_str(), Some("aéb"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts": {"init": {"file": "init.hlo.txt", "inputs":
            [{"name": "seed", "shape": [1], "dtype": "int32"}]}}}"#;
        let v = parse(src).unwrap();
        let inp = v.path(&["artifacts", "init", "inputs"]).unwrap().idx(0).unwrap();
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("int32"));
    }
}
