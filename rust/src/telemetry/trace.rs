//! Request-scoped tracing spans (DESIGN.md §Telemetry).
//!
//! A [`span`] is an RAII wall-clock timer.  Spans opened while another
//! span is live on the same thread become its children, so a serve
//! request naturally produces the tree
//!
//! ```text
//! serve.request
//! ├── plan.build
//! │   └── plan.classify
//! ├── prefill.pack
//! └── prefill.tiles
//! ```
//!
//! and a decode batch produces `serve.decode_batch → decode.step /
//! decode.verify` children.  Completed root spans are published to a
//! bounded global collector drained by [`take_roots`].
//!
//! Overhead rules (asserted by the `bench_kernel_masks` telemetry
//! section): with tracing disabled every `span()` call is a single
//! relaxed atomic load returning an inert guard — no clock read, no
//! thread-local access, no allocation.  When enabled, sampling is
//! decided once per *root* span (`1` in [`set_sample_every`]`(n)`
//! roots record; `n = 0` keeps the instrumentation active but records
//! nothing); unsampled roots suppress their whole subtree through a
//! thread-local depth counter.

use std::cell::{Cell, RefCell};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Children kept per span before further ones are counted into
/// [`SpanNode::dropped`] instead (bounds memory when a root wraps a
/// long decode loop).
pub const MAX_CHILDREN: usize = 256;

/// Completed root spans retained before the oldest is discarded.
pub const MAX_ROOTS: usize = 64;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(1);
static ROOT_SEQ: AtomicU64 = AtomicU64::new(0);
static ROOTS: Mutex<Vec<SpanNode>> = Mutex::new(Vec::new());

thread_local! {
    static STACK: RefCell<Vec<SpanNode>> = const { RefCell::new(Vec::new()) };
    static SUPPRESS: Cell<u64> = const { Cell::new(0) };
}

/// Master switch.  Off by default; when off, `span()` costs one atomic
/// load and records nothing.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record one in every `n` root spans (`1` = all, `0` = none — the
/// "active but unsampled" mode the overhead bench measures).
pub fn set_sample_every(n: u64) {
    SAMPLE_EVERY.store(n, Ordering::Relaxed);
}

/// A finished (or in-flight, while on the stack) span.
#[derive(Clone, Debug)]
pub struct SpanNode {
    pub name: &'static str,
    /// Opening instant — kept so exporters can place spans on a shared
    /// timeline (chrome://tracing `ts` is relative to the earliest
    /// exported root).  Not serialized by [`to_json`](Self::to_json).
    pub start: Instant,
    pub wall_ms: f64,
    /// Counters attributed to this span via [`SpanGuard::add`].
    pub counters: Vec<(&'static str, u64)>,
    pub children: Vec<SpanNode>,
    /// Children discarded after [`MAX_CHILDREN`].
    pub dropped: u64,
}

impl SpanNode {
    fn new(name: &'static str) -> SpanNode {
        SpanNode {
            name,
            start: Instant::now(),
            wall_ms: 0.0,
            counters: Vec::new(),
            children: Vec::new(),
            dropped: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.to_string())),
            ("wall_ms", Json::Num(self.wall_ms)),
        ];
        if !self.counters.is_empty() {
            pairs.push((
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ));
        }
        if !self.children.is_empty() {
            pairs.push(("children", Json::Arr(self.children.iter().map(|c| c.to_json()).collect())));
        }
        if self.dropped > 0 {
            pairs.push(("children_dropped", Json::Num(self.dropped as f64)));
        }
        Json::obj(pairs)
    }
}

enum Mode {
    /// Tracing globally off — nothing to undo on drop.
    Inert,
    /// Under an unsampled root — decrement the suppress depth on drop.
    Suppressed,
    /// Recording — `depth` is this span's index in the thread stack.
    Active { start: Instant, depth: usize },
}

/// RAII span timer; see [`span`].  `!Send` — a guard closes on the
/// thread that opened it.
pub struct SpanGuard {
    mode: Mode,
    _not_send: PhantomData<*const ()>,
}

/// Open a span.  The guard's drop records wall time and attaches the
/// node to the enclosing span (or publishes it as a root).
pub fn span(name: &'static str) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { mode: Mode::Inert, _not_send: PhantomData };
    }
    let suppressed = SUPPRESS.with(|s| {
        if s.get() > 0 {
            s.set(s.get() + 1);
            return true;
        }
        let is_root = STACK.with(|st| st.borrow().is_empty());
        if is_root {
            let every = SAMPLE_EVERY.load(Ordering::Relaxed);
            let seq = ROOT_SEQ.fetch_add(1, Ordering::Relaxed);
            if every == 0 || seq % every != 0 {
                s.set(1);
                return true;
            }
        }
        false
    });
    if suppressed {
        return SpanGuard { mode: Mode::Suppressed, _not_send: PhantomData };
    }
    let depth = STACK.with(|st| {
        let mut st = st.borrow_mut();
        st.push(SpanNode::new(name));
        st.len() - 1
    });
    SpanGuard { mode: Mode::Active { start: Instant::now(), depth }, _not_send: PhantomData }
}

impl SpanGuard {
    /// Attribute `delta` to `counter` on this span (repeat names
    /// accumulate into one entry).
    pub fn add(&self, counter: &'static str, delta: u64) {
        if let Mode::Active { depth, .. } = self.mode {
            STACK.with(|st| {
                let mut st = st.borrow_mut();
                if let Some(node) = st.get_mut(depth) {
                    if let Some(slot) = node.counters.iter_mut().find(|(k, _)| *k == counter) {
                        slot.1 += delta;
                    } else {
                        node.counters.push((counter, delta));
                    }
                }
            });
        }
    }

    /// Whether this guard is recording (false when tracing is off or
    /// the enclosing root was not sampled).
    pub fn is_recording(&self) -> bool {
        matches!(self.mode, Mode::Active { .. })
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        match self.mode {
            Mode::Inert => {}
            Mode::Suppressed => SUPPRESS.with(|s| s.set(s.get().saturating_sub(1))),
            Mode::Active { start, .. } => {
                let wall_ms = start.elapsed().as_secs_f64() * 1e3;
                STACK.with(|st| {
                    let mut st = st.borrow_mut();
                    // guards drop in LIFO order, so this span is the top
                    let Some(mut node) = st.pop() else { return };
                    node.wall_ms = wall_ms;
                    if let Some(parent) = st.last_mut() {
                        if parent.children.len() < MAX_CHILDREN {
                            parent.children.push(node);
                        } else {
                            parent.dropped += 1;
                        }
                    } else {
                        let mut roots = ROOTS.lock().unwrap_or_else(|p| p.into_inner());
                        if roots.len() >= MAX_ROOTS {
                            roots.remove(0);
                        }
                        roots.push(node);
                    }
                });
            }
        }
    }
}

/// Drain every collected root span (oldest first).
pub fn take_roots() -> Vec<SpanNode> {
    let mut roots = ROOTS.lock().unwrap_or_else(|p| p.into_inner());
    roots.drain(..).collect()
}

/// Serialize root spans for the CLI dump.
pub fn roots_to_json(roots: &[SpanNode]) -> Json {
    Json::Arr(roots.iter().map(|r| r.to_json()).collect())
}

/// Serialize root spans as a chrome://tracing document (the "JSON
/// object format": `{"traceEvents": [...]}` of complete `ph:"X"`
/// events, `ts`/`dur` in microseconds relative to the earliest
/// exported root) — load the file in `chrome://tracing` or Perfetto.
/// Counters and the dropped-children count travel in each event's
/// `args`.
pub fn roots_to_chrome_json(roots: &[SpanNode]) -> Json {
    let t0 = roots.iter().map(|r| r.start).min();
    let mut events = Vec::new();
    if let Some(t0) = t0 {
        for r in roots {
            push_chrome_events(r, t0, &mut events);
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

fn push_chrome_events(node: &SpanNode, t0: Instant, out: &mut Vec<Json>) {
    let ts_us = node.start.saturating_duration_since(t0).as_secs_f64() * 1e6;
    let mut args: Vec<(&str, Json)> = node
        .counters
        .iter()
        .map(|(k, v)| (*k, Json::Num(*v as f64)))
        .collect();
    if node.dropped > 0 {
        args.push(("children_dropped", Json::Num(node.dropped as f64)));
    }
    let mut pairs = vec![
        ("name", Json::Str(node.name.to_string())),
        ("cat", Json::Str("flashmask".to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(node.wall_ms * 1e3)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(1.0)),
    ];
    if !args.is_empty() {
        pairs.push(("args", Json::obj(args)));
    }
    out.push(Json::obj(pairs));
    for c in &node.children {
        push_chrome_events(c, t0, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The enable switch and collector are process-global, so tests that
    // flip them serialize on this lock and assert with `any`-style
    // matching (other tests' spans may interleave into the collector).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _l = locked();
        set_enabled(false);
        take_roots();
        {
            let g = span("t.off");
            assert!(!g.is_recording());
            g.add("x", 1);
        }
        assert!(take_roots().iter().all(|r| r.name != "t.off"));
    }

    #[test]
    fn span_tree_nests_and_attributes_counters() {
        let _l = locked();
        set_enabled(true);
        set_sample_every(1);
        take_roots();
        {
            let root = span("t.root");
            root.add("items", 2);
            root.add("items", 3);
            {
                let _child = span("t.child");
                let _grand = span("t.grand");
            }
            let _sibling = span("t.sibling");
        }
        set_enabled(false);
        let roots = take_roots();
        let root = roots.iter().find(|r| r.name == "t.root").expect("root collected");
        assert!(root.wall_ms >= 0.0);
        assert_eq!(root.counters, vec![("items", 5)]);
        let names: Vec<&str> = root.children.iter().map(|c| c.name).collect();
        assert_eq!(names, vec!["t.child", "t.sibling"]);
        assert_eq!(root.children[0].children[0].name, "t.grand");
        // serializes to parseable json
        let text = roots_to_json(std::slice::from_ref(root)).to_string_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn sample_every_zero_is_active_but_silent() {
        let _l = locked();
        set_enabled(true);
        set_sample_every(0);
        take_roots();
        {
            let root = span("t.unsampled");
            assert!(!root.is_recording());
            // nested spans under an unsampled root are suppressed too,
            // and must not be promoted to roots of their own
            let child = span("t.unsampled_child");
            assert!(!child.is_recording());
        }
        set_enabled(false);
        set_sample_every(1);
        let roots = take_roots();
        assert!(roots.iter().all(|r| !r.name.starts_with("t.unsampled")));
    }

    #[test]
    fn chrome_export_round_trips() {
        let _l = locked();
        set_enabled(true);
        set_sample_every(1);
        take_roots();
        {
            let root = span("t.chrome_root");
            root.add("pages", 7);
            {
                let _child = span("t.chrome_child");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        set_enabled(false);
        let roots = take_roots();
        let root =
            roots.iter().find(|r| r.name == "t.chrome_root").expect("root collected").clone();
        let text = roots_to_chrome_json(&[root]).to_string_pretty();
        let doc = crate::util::json::parse(&text).expect("chrome export parses");
        assert_eq!(doc.get("displayTimeUnit").and_then(|j| j.as_str()), Some("ms"));
        let events = doc.get("traceEvents").and_then(|j| j.as_arr()).expect("traceEvents array");
        assert_eq!(events.len(), 2);
        let parent = &events[0];
        let child = &events[1];
        assert_eq!(parent.get("name").and_then(|j| j.as_str()), Some("t.chrome_root"));
        assert_eq!(child.get("name").and_then(|j| j.as_str()), Some("t.chrome_child"));
        for ev in [parent, child] {
            assert_eq!(ev.get("ph").and_then(|j| j.as_str()), Some("X"));
            assert_eq!(ev.get("pid").and_then(|j| j.as_f64()), Some(1.0));
        }
        // the child opens after its parent and closes no later (half a
        // microsecond of float slack on the close edge)
        let ts = |ev: &Json| ev.get("ts").and_then(|j| j.as_f64()).expect("ts");
        let dur = |ev: &Json| ev.get("dur").and_then(|j| j.as_f64()).expect("dur");
        assert_eq!(ts(parent), 0.0);
        assert!(ts(child) >= ts(parent));
        assert!(dur(child) >= 1e3, "child slept 1ms, dur {} us", dur(child));
        assert!(ts(child) + dur(child) <= ts(parent) + dur(parent) + 0.5);
        // counters ride in args
        assert_eq!(parent.path(&["args", "pages"]).and_then(|j| j.as_f64()), Some(7.0));
    }

    #[test]
    fn child_cap_counts_drops() {
        let _l = locked();
        set_enabled(true);
        set_sample_every(1);
        take_roots();
        {
            let _root = span("t.capped");
            for _ in 0..(MAX_CHILDREN + 10) {
                let _c = span("t.tick");
            }
        }
        set_enabled(false);
        let roots = take_roots();
        let root = roots.iter().find(|r| r.name == "t.capped").expect("root");
        assert_eq!(root.children.len(), MAX_CHILDREN);
        assert_eq!(root.dropped, 10);
    }
}
