//! Metrics registry: named atomic counters, gauges and log2-bucket
//! latency histograms (DESIGN.md §Telemetry).
//!
//! Everything here is pure `std` and lock-light: a metric handle is an
//! `Arc` around atomics, so the registry `Mutex` is only taken on the
//! first lookup of a name (call sites resolve handles once and then
//! update through the `Arc`).  Histograms bucket by `log2(value)` —
//! recording is a `leading_zeros` plus one atomic add, and quantiles
//! are exact *counts* walked over the cumulative bucket distribution,
//! so `quantile(q)` is within one power-of-two bucket of the true
//! sample quantile (asserted by the property tests below).
//!
//! Naming scheme (dotted, lowercase): `<layer>.<metric>` — e.g.
//! `tile.macs`, `plan.cache.hits`, `decode.ttft_ms`, `serve.requests`,
//! `train.step_ms`.  Histogram names carry a `_ms` suffix; samples are
//! stored in integer microseconds and converted back at the edges.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Number of histogram buckets: bucket 0 holds the value 0 and bucket
/// `i >= 1` holds values in `[2^(i-1), 2^i - 1]`, so 65 buckets cover
/// the full `u64` range.
pub const HIST_BUCKETS: usize = 65;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, delta: u64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge with a monotonic-max helper (used for peaks).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is larger (peak tracking).
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Plain (non-atomic) histogram contents — the value type used for
/// merge-law tests and snapshots.  `merge` is commutative and
/// associative (bucket-wise addition), mirroring `DecodeStats::merge`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistData {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl Default for HistData {
    fn default() -> Self {
        HistData { buckets: vec![0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl HistData {
    pub fn merge(&mut self, other: &HistData) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }
}

/// Fixed-bucket log2 latency histogram over `u64` samples
/// (microseconds by convention; see module docs for the unit rule).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index of a sample: 0 for 0, else `64 - leading_zeros`.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Inclusive upper bound of bucket `i` — the value `quantile`
    /// reports, so estimates always sit at or above the true sample.
    #[inline]
    pub fn bucket_ub(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a millisecond duration (stored as integer microseconds).
    pub fn record_ms(&self, ms: f64) {
        self.record((ms * 1000.0).max(0.0).round() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean() / 1000.0
    }

    /// Exact-count quantile estimate: the upper bound of the bucket
    /// containing the rank-`ceil(q*n)` sample.  Returns 0 on the empty
    /// histogram.  For a true sample value `x > 0` the estimate is in
    /// `[x, 2x)` — one log2 bucket of relative error.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for i in 0..HIST_BUCKETS {
            acc += self.buckets[i].load(Ordering::Relaxed);
            if acc >= rank {
                return Self::bucket_ub(i);
            }
        }
        Self::bucket_ub(HIST_BUCKETS - 1)
    }

    /// `quantile` converted back to milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile(q) as f64 / 1000.0
    }

    /// Bucket-wise accumulate `other` into `self` (commutative and
    /// associative over `HistData`; see the merge-law tests).
    pub fn merge_from(&self, other: &Histogram) {
        for i in 0..HIST_BUCKETS {
            let b = other.buckets[i].load(Ordering::Relaxed);
            if b != 0 {
                self.buckets[i].fetch_add(b, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Plain-value snapshot of the atomics.
    pub fn data(&self) -> HistData {
        HistData {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum: self.sum(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum_us", Json::Num(self.sum() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.50))),
            ("p90_ms", Json::Num(self.quantile_ms(0.90))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
        ])
    }
}

/// Named-metric registry.  One global instance backs the whole library
/// ([`global`]); independent instances are used in unit tests so
/// parallel tests never race on shared names.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poison| poison.into_inner())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Handle to the named counter, creating it on first use.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = lock(&self.counters);
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())).clone()
    }

    /// Handle to the named gauge, creating it on first use.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = lock(&self.gauges);
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())).clone()
    }

    /// Handle to the named histogram, creating it on first use.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = lock(&self.hists);
        map.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())).clone()
    }

    /// One-shot counter add (hot paths should cache the handle).
    pub fn add(&self, name: &str, delta: u64) {
        if delta != 0 {
            self.counter(name).add(delta);
        }
    }

    /// One-shot histogram observation in milliseconds.
    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.histogram(name).record_ms(ms);
    }

    /// Serialize every metric to the repo's `util::json` format:
    /// `{ "counters": {..}, "gauges": {..}, "histograms": {..} }` with
    /// names sorted (BTreeMap order) for deterministic output.
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = lock(&self.counters)
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(v.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = lock(&self.gauges)
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(v.get() as f64)))
            .collect();
        let hists: BTreeMap<String, Json> = lock(&self.hists)
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(hists)),
            ]
            .into_iter()
            .collect(),
        )
    }

    /// Drop every registered metric (CLI / bench isolation; existing
    /// handles keep working but are no longer reachable by name).
    pub fn reset(&self) {
        lock(&self.counters).clear();
        lock(&self.gauges).clear();
        lock(&self.hists).clear();
    }
}

/// The process-wide registry every layer publishes into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Rng;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        r.add("a.hits", 3);
        r.add("a.hits", 2);
        assert_eq!(r.counter("a.hits").get(), 5);
        let g = r.gauge("a.peak");
        g.set(7);
        g.set_max(4);
        assert_eq!(g.get(), 7);
        g.set_max(11);
        assert_eq!(g.get(), 11);
    }

    #[test]
    fn registry_handles_are_shared() {
        let r = Registry::new();
        let h1 = r.histogram("x_ms");
        let h2 = r.histogram("x_ms");
        h1.record(10);
        assert_eq!(h2.count(), 1);
    }

    #[test]
    fn histogram_empty_state_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(u64::MAX), 64);
        assert_eq!(Histogram::bucket_ub(0), 0);
        assert_eq!(Histogram::bucket_ub(1), 1);
        assert_eq!(Histogram::bucket_ub(2), 3);
        assert_eq!(Histogram::bucket_ub(64), u64::MAX);
        // every value lands in a bucket whose bounds contain it
        for v in [0u64, 1, 2, 3, 4, 5, 127, 128, 1 << 20, u64::MAX / 2] {
            let b = Histogram::bucket_of(v);
            assert!(v <= Histogram::bucket_ub(b), "v={v} b={b}");
            if b > 0 {
                assert!(v > Histogram::bucket_ub(b - 1), "v={v} b={b}");
            }
        }
    }

    /// Exact quantile with the same rank convention the histogram uses:
    /// the rank-`ceil(q*n)` order statistic.
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len() as u64;
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    #[test]
    fn prop_quantile_within_one_bucket_of_exact() {
        // satellite: across random distributions, quantile(q) must sit
        // in [exact, 2*exact) — the log2 bucket's relative error bound
        check(
            "hist-quantile-vs-exact",
            PropConfig { cases: 48, base_seed: 0x715706A3 },
            |rng| {
                let n = rng.range(1, 400) as usize;
                let h = Histogram::new();
                let mut samples = Vec::with_capacity(n);
                // mix of scales: uniform small, exponential-ish large
                for _ in 0..n {
                    let v = match rng.gen_range(3) {
                        0 => rng.gen_range(16),
                        1 => rng.gen_range(10_000),
                        _ => 1u64 << rng.gen_range(40),
                    };
                    samples.push(v);
                    h.record(v);
                }
                samples.sort_unstable();
                for &q in &[0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                    let exact = exact_quantile(&samples, q);
                    let est = h.quantile(q);
                    crate::prop_assert!(est >= exact, "q={q}: est {est} < exact {exact}");
                    if exact == 0 {
                        crate::prop_assert!(est == 0, "q={q}: est {est} for exact 0");
                    } else {
                        crate::prop_assert!(
                            est < exact.saturating_mul(2),
                            "q={q}: est {est} >= 2*exact ({exact})"
                        );
                    }
                }
                Ok(())
            },
        );
    }

    fn arbitrary_hist(rng: &mut Rng) -> Histogram {
        let h = Histogram::new();
        for _ in 0..rng.range(0, 60) {
            h.record(rng.gen_range(1 << 30));
        }
        h
    }

    #[test]
    fn prop_merge_commutes_and_associates() {
        // mirrors the DecodeStats::merge laws: bucket-wise addition is
        // order-independent
        check(
            "hist-merge-laws",
            PropConfig { cases: 32, base_seed: 0x4E46_11 },
            |rng| {
                let (a, b, c) = (arbitrary_hist(rng), arbitrary_hist(rng), arbitrary_hist(rng));
                // commutativity: a+b == b+a
                let ab = Histogram::new();
                ab.merge_from(&a);
                ab.merge_from(&b);
                let ba = Histogram::new();
                ba.merge_from(&b);
                ba.merge_from(&a);
                crate::prop_assert!(ab.data() == ba.data(), "merge not commutative");
                // associativity: (a+b)+c == a+(b+c)
                let ab_c = Histogram::new();
                ab_c.merge_from(&ab);
                ab_c.merge_from(&c);
                let bc = Histogram::new();
                bc.merge_from(&b);
                bc.merge_from(&c);
                let a_bc = Histogram::new();
                a_bc.merge_from(&a);
                a_bc.merge_from(&bc);
                crate::prop_assert!(ab_c.data() == a_bc.data(), "merge not associative");
                // HistData::merge agrees with Histogram::merge_from
                let mut d = a.data();
                d.merge(&b.data());
                crate::prop_assert!(d == ab.data(), "HistData::merge disagrees");
                Ok(())
            },
        );
    }

    #[test]
    fn snapshot_serializes_all_metric_kinds() {
        let r = Registry::new();
        r.add("plan.cache.hits", 4);
        r.gauge("decode.peak_pages").set(9);
        r.observe_ms("serve.ttft_ms", 3.5);
        r.observe_ms("serve.ttft_ms", 12.0);
        let snap = r.snapshot();
        let counter = snap.get("counters").and_then(|o| o.get("plan.cache.hits"));
        assert_eq!(counter.and_then(Json::as_f64), Some(4.0));
        let gauge = snap.get("gauges").and_then(|o| o.get("decode.peak_pages"));
        assert_eq!(gauge.and_then(Json::as_f64), Some(9.0));
        let h = snap.get("histograms").and_then(|o| o.get("serve.ttft_ms")).expect("hist");
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(2.0));
        assert!(h.get("p99_ms").and_then(Json::as_f64).unwrap() >= 12.0);
        // round-trips through the parser
        let text = snap.to_string_pretty();
        assert_eq!(crate::util::json::parse(&text).as_ref(), Ok(&snap));
    }

    #[test]
    fn reset_clears_names() {
        let r = Registry::new();
        r.add("x", 1);
        r.reset();
        assert_eq!(r.counter("x").get(), 0);
    }
}
