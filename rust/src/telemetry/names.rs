//! Central registry of every telemetry name (DESIGN.md §Static
//! analysis, §Telemetry).
//!
//! Metric, span and log-target strings used to be scattered literals —
//! a typo (`"decode.page_total"`) silently forked a metric series.
//! Every name now lives here as a `const`, call sites reference the
//! const, and the `telemetry-names` lint pass
//! ([`crate::analysis::passes::telemetry_names`]) fails any string
//! literal passed to `counter`/`gauge`/`histogram`/`observe_ms`/
//! `span`/registry `add` (or as a `log::` target) that is not declared
//! below.
//!
//! Naming scheme (checked by `names_follow_scheme` and the lint pass):
//!
//! * metrics and spans are dotted lowercase `layer.noun[.verb]` —
//!   layers today: `tile`, `plan`, `prefill`, `decode`, `prefix`,
//!   `serve`, `router`, `train`;
//! * latency histograms end in `_ms`;
//! * log targets are a single lowercase word naming the emitting
//!   subsystem;
//! * span *attribute* keys (the `SpanGuard::add` first argument) are
//!   dotless single words and are deliberately **not** registered —
//!   they are scoped to their span, not global series.

/// Declare name consts and collect every value into [`ALL`].
macro_rules! names {
    ($($(#[$meta:meta])* $ident:ident => $lit:literal,)*) => {
        $($(#[$meta])* pub const $ident: &str = $lit;)*
        /// Every declared telemetry name, in declaration order — the
        /// closed set the `telemetry-names` lint pass checks literals
        /// against.
        pub const ALL: &[&str] = &[$($lit),*];
    };
}

names! {
    // -- tile layer: prefill kernel census (attention::TileStats) --
    TILE_TOTAL => "tile.total",
    TILE_SKIPPED => "tile.skipped",
    TILE_PARTIAL => "tile.partial",
    TILE_UNMASKED => "tile.unmasked",
    TILE_VISITED => "tile.visited",
    TILE_MACS => "tile.macs",
    TILE_MASK_EVALS => "tile.mask_evals",
    TILE_MASK_CACHE_HITS => "tile.mask_cache_hits",

    // -- plan layer: ExecutionPlan build + PlanCache --
    /// Span: AttnProblem::plan compile.
    PLAN_BUILD => "plan.build",
    /// Span: Eq. 4 tile classification inside the plan build.
    PLAN_CLASSIFY => "plan.classify",
    /// Span: backward pass over a built plan.
    PLAN_BACKWARD => "plan.backward",
    PLAN_BUILDS => "plan.builds",
    PLAN_CACHE_HITS => "plan.cache.hits",
    PLAN_CACHE_MISSES => "plan.cache.misses",
    PLAN_CACHE_EVICTIONS => "plan.cache.evictions",

    // -- prefill layer: spans inside Backend::prefill --
    PREFILL_PACK => "prefill.pack",
    PREFILL_TILES => "prefill.tiles",

    // -- decode layer: DecodeStats::publish + batcher latency --
    /// Span: one decode_step_group kernel invocation.
    DECODE_STEP => "decode.step",
    /// Span: one speculative verify pass.
    DECODE_VERIFY => "decode.verify",
    DECODE_STEPS => "decode.steps",
    DECODE_PAGES_TOTAL => "decode.pages_total",
    DECODE_PAGES_SKIPPED => "decode.pages_skipped",
    DECODE_PAGES_PARTIAL => "decode.pages_partial",
    DECODE_PAGES_UNMASKED => "decode.pages_unmasked",
    DECODE_MACS => "decode.macs",
    DECODE_MASK_EVALS => "decode.mask_evals",
    DECODE_SPEC_PASSES => "decode.spec_passes",
    DECODE_DRAFTED => "decode.drafted",
    DECODE_ACCEPTED => "decode.accepted",
    DECODE_FALLBACK_STEPS => "decode.fallback_steps",
    DECODE_PLANS_BUILT => "decode.plans_built",
    DECODE_PREFILL_MACS => "decode.prefill_macs",
    DECODE_TTFT_MS => "decode.ttft_ms",
    DECODE_ITL_MS => "decode.itl_ms",
    DECODE_PEAK_PAGES => "decode.peak_pages",

    // -- prefix layer: content-addressed KV page sharing --
    PREFIX_COW_COPIES => "prefix.cow_copies",
    PREFIX_COLLISIONS => "prefix.collisions",
    PREFIX_HITS => "prefix.hits",
    PREFIX_MISSES => "prefix.misses",
    PREFIX_SHARED_PAGES => "prefix.shared_pages",

    // -- serve layer: ServeEngine --
    /// Span: one prefill request through the engine.
    SERVE_REQUEST => "serve.request",
    /// Span: one continuous-batching decode tick.
    SERVE_DECODE_BATCH => "serve.decode_batch",
    SERVE_FALLBACKS => "serve.fallbacks",
    SERVE_REQUESTS => "serve.requests",
    SERVE_TOKENS => "serve.tokens",
    SERVE_COMPUTE_MS => "serve.compute_ms",
    SERVE_QUEUE_MS => "serve.queue_ms",
    SERVE_TTFT_MS => "serve.ttft_ms",
    SERVE_ITL_MS => "serve.itl_ms",

    // -- router layer: streaming wave admission --
    /// Span: one admission wave.
    ROUTER_WAVE => "router.wave",
    ROUTER_TTFT_MS => "router.ttft_ms",
    ROUTER_ITL_MS => "router.itl_ms",
    ROUTER_ACTIVE_PEAK => "router.active_peak",
    ROUTER_WAITING_PEAK => "router.waiting_peak",
    ROUTER_CANCELLED => "router.cancelled",
    ROUTER_WAVES => "router.waves",
    ROUTER_FORCED_WAVES => "router.forced_waves",
    ROUTER_PREFILL_REJECTS => "router.prefill_rejects",
    ROUTER_PREEMPTIONS => "router.preemptions",

    // -- train layer: Trainer + coordinator::metrics --
    /// Span: one optimizer step.
    TRAIN_STEP => "train.step",
    TRAIN_STEP_MS => "train.step_ms",
    TRAIN_STEPS => "train.steps",
    TRAIN_TOKENS => "train.tokens",
    TRAIN_BACKWARD_MS => "train.backward_ms",

    // -- log targets (telemetry::log `target` argument) --
    TARGET_ROUTER => "router",
    TARGET_SERVE => "serve",
    TARGET_DECODE => "decode",
    TARGET_TRAIN => "train",
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    fn is_scheme_metric(n: &str) -> bool {
        n.contains('.')
            && n.split('.').all(|seg| {
                !seg.is_empty()
                    && seg.chars().next().is_some_and(|c| c.is_ascii_lowercase())
                    && seg.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
            })
    }

    #[test]
    fn names_follow_scheme() {
        let layers: BTreeSet<&str> =
            ["tile", "plan", "prefill", "decode", "prefix", "serve", "router", "train"]
                .into_iter()
                .collect();
        for n in ALL {
            if n.contains('.') {
                assert!(is_scheme_metric(n), "metric/span name '{n}' breaks the dotted scheme");
                let layer = n.split('.').next().unwrap_or_default();
                assert!(layers.contains(layer), "'{n}' uses undeclared layer '{layer}'");
            } else {
                // log target: one lowercase word
                assert!(
                    n.chars().all(|c| c.is_ascii_lowercase()),
                    "log target '{n}' must be a single lowercase word"
                );
            }
        }
    }

    #[test]
    fn names_are_unique() {
        let set: BTreeSet<&str> = ALL.iter().copied().collect();
        assert_eq!(set.len(), ALL.len(), "duplicate telemetry name declared");
    }

    #[test]
    fn histograms_end_in_ms() {
        // every name fed to observe_ms/histogram carries the _ms suffix
        for n in [
            DECODE_TTFT_MS,
            DECODE_ITL_MS,
            SERVE_COMPUTE_MS,
            SERVE_QUEUE_MS,
            SERVE_TTFT_MS,
            SERVE_ITL_MS,
            ROUTER_TTFT_MS,
            ROUTER_ITL_MS,
            TRAIN_STEP_MS,
            TRAIN_BACKWARD_MS,
        ] {
            assert!(n.ends_with("_ms"), "latency histogram '{n}' missing the _ms suffix");
        }
    }
}
