//! Leveled structured logger (DESIGN.md §Telemetry).
//!
//! Library code must not call `eprintln!`/`println!` directly — the
//! `scripts/verify.sh` grep gate enforces it (reports/CLI/table output
//! is exempt).  Instead, call [`debug`]/[`info`]/[`warn`]/[`error`]
//! with a `target` (the emitting subsystem, e.g. `"serve"`) and a
//! message.  Records below [`set_min_level`] (default `Info`) are
//! dropped; the rest go to stderr as `[LEVEL] target: message` —
//! unless a test holds a [`capture`] guard, in which case they are
//! buffered for assertion instead.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Severity, ordered `Debug < Info < Warn < Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        })
    }
}

/// One emitted log line.
#[derive(Clone, Debug)]
pub struct LogRecord {
    pub level: Level,
    pub target: &'static str,
    pub msg: String,
}

static MIN_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static CAPTURE: Mutex<Option<Vec<LogRecord>>> = Mutex::new(None);
// serializes concurrent `capture()` holders (parallel tests)
static CAPTURE_SERIAL: Mutex<()> = Mutex::new(());

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poison| poison.into_inner())
}

/// Drop records below `level` (default `Info`).
pub fn set_min_level(level: Level) {
    MIN_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse a level name — `debug|info|warn|warning|error`, any case.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" | "warning" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// Environment variable [`init_from_env`] reads the min level from.
pub const LOG_ENV_VAR: &str = "FLASHMASK_LOG";

/// Wire the min level from `FLASHMASK_LOG` (unset or unparsable leaves
/// the current level).  The CLI and the bench binaries call this at
/// startup, so `FLASHMASK_LOG=debug flashmask serve …` surfaces router
/// traces without a recompile; the CLI's `--log-level` flag overrides
/// the variable.  Returns the level applied, if any.
pub fn init_from_env() -> Option<Level> {
    let lv = parse_level(&std::env::var(LOG_ENV_VAR).ok()?)?;
    set_min_level(lv);
    Some(lv)
}

pub fn min_level() -> Level {
    match MIN_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

/// Emit a record (prefer the level helpers below).
pub fn log(level: Level, target: &'static str, msg: String) {
    if (level as u8) < MIN_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    let mut cap = lock(&CAPTURE);
    if let Some(buf) = cap.as_mut() {
        buf.push(LogRecord { level, target, msg });
        return;
    }
    drop(cap);
    eprintln!("[{level}] {target}: {msg}");
}

pub fn debug(target: &'static str, msg: impl Into<String>) {
    log(Level::Debug, target, msg.into());
}

pub fn info(target: &'static str, msg: impl Into<String>) {
    log(Level::Info, target, msg.into());
}

pub fn warn(target: &'static str, msg: impl Into<String>) {
    log(Level::Warn, target, msg.into());
}

pub fn error(target: &'static str, msg: impl Into<String>) {
    log(Level::Error, target, msg.into());
}

/// RAII capture guard: while alive, records are buffered instead of
/// written to stderr.  Guards serialize across threads, so parallel
/// tests block rather than corrupt each other's buffers — but any
/// thread's records land in the active buffer, so assert with
/// `any`-style matching, not exact equality.
pub struct Capture {
    _serial: MutexGuard<'static, ()>,
}

pub fn capture() -> Capture {
    let serial = lock(&CAPTURE_SERIAL);
    *lock(&CAPTURE) = Some(Vec::new());
    Capture { _serial: serial }
}

impl Capture {
    /// Drain everything captured so far.
    pub fn take(&self) -> Vec<LogRecord> {
        let mut cap = lock(&CAPTURE);
        match cap.as_mut() {
            Some(buf) => std::mem::take(buf),
            None => Vec::new(),
        }
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        *lock(&CAPTURE) = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_buffers_and_level_filters() {
        let cap = capture();
        debug("t", "below min level, dropped");
        warn("t", format!("warn {}", 7));
        error("t", "boom");
        let recs = cap.take();
        assert!(recs.iter().any(|r| r.level == Level::Warn && r.msg == "warn 7"));
        assert!(recs.iter().any(|r| r.level == Level::Error && r.target == "t"));
        assert!(!recs.iter().any(|r| r.level == Level::Debug && r.target == "t"));
        // drained — a second take starts empty of our records
        assert!(!cap.take().iter().any(|r| r.target == "t"));
    }

    #[test]
    fn min_level_is_adjustable() {
        let cap = capture();
        set_min_level(Level::Debug);
        debug("t2", "now visible");
        set_min_level(Level::Info);
        debug("t2", "hidden again");
        let recs = cap.take();
        assert_eq!(recs.iter().filter(|r| r.target == "t2").count(), 1);
    }

    #[test]
    fn parse_level_accepts_names_any_case() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("INFO"), Some(Level::Info));
        assert_eq!(parse_level("Warning"), Some(Level::Warn));
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn init_from_env_sets_min_level() {
        // hold the capture guard to serialize with the other tests
        // that touch the global min level
        let _cap = capture();
        std::env::set_var(LOG_ENV_VAR, "error");
        assert_eq!(init_from_env(), Some(Level::Error));
        assert_eq!(min_level(), Level::Error);
        std::env::set_var(LOG_ENV_VAR, "not-a-level");
        assert_eq!(init_from_env(), None);
        assert_eq!(min_level(), Level::Error, "unparsable value leaves the level");
        std::env::remove_var(LOG_ENV_VAR);
        assert_eq!(init_from_env(), None);
        set_min_level(Level::Info); // restore the default for other tests
    }

    #[test]
    fn level_ordering_and_display() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
        assert_eq!(Level::Warn.to_string(), "WARN");
    }
}
