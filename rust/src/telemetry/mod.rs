//! Unified telemetry: metrics registry, tracing spans and leveled
//! logging (DESIGN.md §Telemetry).
//!
//! Three pure-`std` pillars share this module:
//!
//! * [`metrics`] — named atomic counters/gauges plus log2-bucket
//!   latency histograms with p50/p90/p99 estimation, all publishing
//!   into one process-wide [`metrics::global`] registry whose
//!   [`metrics::Registry::snapshot`] serializes to `util::json`.
//! * [`trace`] — RAII spans forming a per-request tree (plan build →
//!   pack → tile loop / decode step / speculation verify) with
//!   configurable sampling and a global off switch.
//! * [`log`] — the leveled logger library code uses instead of
//!   `eprintln!` (enforced by the `direct-print` lint pass run from
//!   `scripts/verify.sh`); capturable in tests.
//! * [`names`] — the closed registry of metric/span/log-target name
//!   consts; the `telemetry-names` lint pass rejects undeclared
//!   literals at call sites (DESIGN.md §Static analysis).
//!
//! Emitters live with their layers: `attention::TileStats::publish`,
//! `decode::DecodeStats::publish`, `PlanCache` hit/miss/evict
//! counters, `ContinuousBatcher`/`ServeEngine` TTFT and inter-token
//! latency histograms, and `coordinator::metrics` step-time
//! histograms all feed the same registry, dumped by the
//! `flashmask metrics` subcommand and merged into `BENCH_kernel.json`
//! by `scripts/bench.sh`.

pub mod log;
pub mod metrics;
pub mod names;
pub mod trace;

pub use metrics::{Counter, Gauge, HistData, Histogram, Registry};
