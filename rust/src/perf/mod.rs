//! Performance accounting and analytic models.
//!
//! * [`flops`] — the paper's sparsity-aware FLOPs accounting (appendix
//!   A.5.1): reported FLOPs count only non-fully-masked tiles.
//! * [`a100_model`] — an analytic A100 timing model calibrated to the
//!   paper's own per-tile throughputs (Tables 4–6 anchors); regenerates
//!   the TFLOPs/s columns of Tables 4–9 at the paper's scales, which the
//!   CPU engine cannot reach in wall-clock.
//! * [`memory_model`] — training memory model reproducing Table 2 and
//!   Figs. 4(b)/7, including the Llama-2 7B/13B/70B configurations and
//!   the Table 1 parallelism layout.

pub mod a100_model;
pub mod flops;
pub mod memory_model;
pub mod roofline;
