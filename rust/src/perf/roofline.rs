//! TPU roofline estimator for the L1 Pallas kernel (DESIGN.md §7).
//!
//! `interpret=True` runs the kernel as CPU numpy, so real-TPU
//! performance must be *estimated* from the BlockSpec structure: VMEM
//! footprint per grid step, bytes streamed HBM↔VMEM, MXU FLOPs, and the
//! resulting arithmetic intensity vs the machine balance point.

/// TPU v4-like machine model (per core).
#[derive(Clone, Copy, Debug)]
pub struct TpuModel {
    pub name: &'static str,
    pub peak_bf16_tflops: f64,
    pub hbm_gb_s: f64,
    pub vmem_mib: f64,
}

pub const TPU_V4: TpuModel =
    TpuModel { name: "TPUv4-core", peak_bf16_tflops: 137.5, hbm_gb_s: 600.0, vmem_mib: 16.0 };

/// A100 SXM for cross-checking against the paper's utilization band.
pub const A100: TpuModel =
    TpuModel { name: "A100-SXM", peak_bf16_tflops: 312.0, hbm_gb_s: 2039.0, vmem_mib: 0.192 };

/// Static analysis of one forward grid step of the FlashMask kernel.
#[derive(Clone, Copy, Debug)]
pub struct KernelFootprint {
    pub br: usize,
    pub bc: usize,
    pub d: usize,
    pub n: usize,
    pub dtype_bytes: usize,
}

impl KernelFootprint {
    /// VMEM resident bytes during one (i, j) tile iteration:
    /// Q_i + K_j + V_j + S/P tile (f32) + O accumulator (f32) + the four
    /// interval vectors for the block + 8 min/max scalars.
    pub fn vmem_bytes(&self) -> usize {
        let qkv = (self.br + 2 * self.bc) * self.d * self.dtype_bytes;
        let s_tile = self.br * self.bc * 4;
        let o_acc = self.br * self.d * 4 + 3 * self.br * 4; // + m, l, alpha
        let masks = 4 * self.bc * 4 + 8 * 4;
        qkv + s_tile + o_acc + masks
    }

    pub fn fits_vmem(&self, tpu: &TpuModel) -> bool {
        // x2 for double buffering the K/V stream
        (2 * self.vmem_bytes()) as f64 <= tpu.vmem_mib * 1024.0 * 1024.0
    }

    /// MXU MACs per tile (two Br×Bc×d matmuls forward).
    pub fn tile_macs(&self) -> u64 {
        2 * (self.br * self.bc * self.d) as u64
    }

    /// HBM bytes moved per tile in the steady state (K_j, V_j stream;
    /// Q_i amortized over Tc tiles; mask vectors over Tr).
    pub fn tile_hbm_bytes(&self) -> f64 {
        let kv = (2 * self.bc * self.d * self.dtype_bytes) as f64;
        let q_amort = (self.br * self.d * self.dtype_bytes) as f64 / (self.n / self.bc) as f64;
        let mask_amort = (4.0 * self.bc as f64 * 4.0) / (self.n / self.br) as f64;
        kv + q_amort + mask_amort
    }

    /// FLOPs per HBM byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        (2 * self.tile_macs()) as f64 / self.tile_hbm_bytes()
    }

    /// Predicted fraction of peak on `tpu` (min of compute and memory
    /// rooflines), assuming perfect overlap.
    pub fn roofline_fraction(&self, tpu: &TpuModel) -> f64 {
        let balance = tpu.peak_bf16_tflops * 1e12 / (tpu.hbm_gb_s * 1e9); // flops per byte
        let ai = self.arithmetic_intensity();
        (ai / balance).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_tile() -> KernelFootprint {
        KernelFootprint { br: 128, bc: 128, d: 128, n: 32768, dtype_bytes: 2 }
    }

    #[test]
    fn vmem_fits_with_double_buffering() {
        let f = paper_tile();
        // DESIGN.md §7: ~0.27 MiB per step
        let mib = f.vmem_bytes() as f64 / (1024.0 * 1024.0);
        assert!((0.15..0.5).contains(&mib), "vmem {mib} MiB");
        assert!(f.fits_vmem(&TPU_V4));
    }

    #[test]
    fn paper_tiles_sit_at_the_measured_band() {
        // With the K/V-streaming schedule, AI ≈ Br flops/byte.  At the
        // paper's Br=128 that is ~56% of the TPUv4 balance point — right
        // inside the 37.8-62.3% of peak the paper measures on A100.
        let f = paper_tile();
        let ai = f.arithmetic_intensity();
        assert!((100.0..160.0).contains(&ai), "AI={ai}");
        let frac = f.roofline_fraction(&TPU_V4);
        assert!((0.378..0.75).contains(&frac), "roofline fraction {frac}");
    }

    #[test]
    fn doubling_br_reaches_compute_bound() {
        // the L1 optimization lever: Br=256 clears the balance point
        let f = KernelFootprint { br: 256, bc: 128, d: 128, n: 32768, dtype_bytes: 2 };
        assert!(f.arithmetic_intensity() > 229.0);
        assert_eq!(f.roofline_fraction(&TPU_V4), 1.0);
        assert!(f.fits_vmem(&TPU_V4));
    }

    #[test]
    fn tiny_tiles_go_memory_bound() {
        let f = KernelFootprint { br: 8, bc: 8, d: 32, n: 4096, dtype_bytes: 2 };
        assert!(f.roofline_fraction(&TPU_V4) < 1.0);
    }

    #[test]
    fn paper_band_consistent_on_a100() {
        // the paper achieves 37.8-62.3% of A100 peak; the *roofline*
        // (upper bound) must sit above that band
        let f = KernelFootprint { br: 128, bc: 128, d: 128, n: 32768, dtype_bytes: 2 };
        assert!(f.roofline_fraction(&A100) > 0.623);
    }
}
