//! Analytic A100 timing model, calibrated to the paper's own numbers.
//!
//! The CPU engine reproduces *relative* behaviour (who skips what); this
//! model projects tile censuses onto A100 time so the benches can print
//! Tables 4–9 at the paper's 8K/32K/128K scales.  Per-tile throughput
//! constants are fitted to anchor rows of Tables 4–6:
//!
//! * FLASHMASK fwd: "Full" ≈ 230 TFLOPs/s (all-unmasked tiles),
//!   "Share Question" at 32K ≈ 125 TFLOPs/s (partial-tile dominated).
//! * FlexAttention fwd: "Full" ≈ 161, partial-heavy ≈ 125.
//! * Backward rates are lower (more matmuls, worse locality), fitted to
//!   the same rows' BW columns.
//!
//! A100 SXM peak (BF16 tensor core, no sparsity): 312 TFLOPs/s.

use crate::mask::{BlockTable, FlashMask};

pub const A100_PEAK_TFLOPS: f64 = 312.0;

/// Per-tile execution rates in TFLOPs/s for one method.
#[derive(Clone, Copy, Debug)]
pub struct MethodRates {
    pub fwd_unmasked: f64,
    pub fwd_partial: f64,
    pub bwd_unmasked: f64,
    pub bwd_partial: f64,
    /// Fixed per-call overhead (kernel launches, preprocessing), ms.
    pub overhead_ms: f64,
    /// Per-row-block (fwd) / per-column-block (bwd) prologue+epilogue
    /// cost in unmasked-tile equivalents.  This is why TFLOPs/s falls at
    /// high sparsity: load-Q/rescale/write-O amortize over fewer
    /// executed tiles.  Fitted from the paper's causal-document and
    /// share-question rows at 32K.
    pub fwd_block_overhead_tiles: f64,
    pub bwd_block_overhead_tiles: f64,
}

/// Methods compared in the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    FlashMask,
    FlexAttention,
    /// FlashAttention with a dense materialized mask: computes *every*
    /// tile with element masking (no skipping).
    FlashDenseMask,
    /// Vanilla attention: every tile plus O(N²) mask reads.
    Vanilla,
}

impl Method {
    pub fn rates(&self) -> MethodRates {
        match self {
            Method::FlashMask => MethodRates {
                fwd_unmasked: 232.0,
                fwd_partial: 160.0,
                bwd_unmasked: 208.0,
                bwd_partial: 165.0,
                overhead_ms: 0.02,
                fwd_block_overhead_tiles: 8.0,
                bwd_block_overhead_tiles: 5.0,
            },
            Method::FlexAttention => MethodRates {
                fwd_unmasked: 163.0,
                fwd_partial: 130.0,
                bwd_unmasked: 133.0,
                bwd_partial: 100.0,
                overhead_ms: 0.03,
                fwd_block_overhead_tiles: 3.0,
                bwd_block_overhead_tiles: 10.0,
            },
            Method::FlashDenseMask => MethodRates {
                // element masking on every tile + dense mask HBM traffic
                fwd_unmasked: 150.0,
                fwd_partial: 150.0,
                bwd_unmasked: 120.0,
                bwd_partial: 120.0,
                overhead_ms: 0.02,
                fwd_block_overhead_tiles: 2.0,
                bwd_block_overhead_tiles: 2.0,
            },
            Method::Vanilla => MethodRates {
                // materializes S and P in HBM — heavily memory bound
                fwd_unmasked: 35.0,
                fwd_partial: 35.0,
                bwd_unmasked: 30.0,
                bwd_partial: 30.0,
                overhead_ms: 0.05,
                fwd_block_overhead_tiles: 1.0,
                bwd_block_overhead_tiles: 1.0,
            },
        }
    }

    pub fn skips_fully_masked(&self) -> bool {
        matches!(self, Method::FlashMask | Method::FlexAttention)
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            Method::FlashMask => "FLASHMASK",
            Method::FlexAttention => "FlexAttention",
            Method::FlashDenseMask => "FlashAttn DenseMask",
            Method::Vanilla => "Vanilla Attention",
        }
    }
}

/// Predicted kernel timing + the paper's reported-FLOPs metrics.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub fw_ms: f64,
    pub bw_ms: f64,
    pub fw_tflops: f64,
    pub bw_tflops: f64,
    pub sparsity: f64,
}

impl KernelEstimate {
    pub fn total_ms(&self) -> f64 {
        self.fw_ms + self.bw_ms
    }

    pub fn fw_tflops_per_s(&self) -> f64 {
        self.fw_tflops / (self.fw_ms / 1e3) / 1e12 * 1e12 / 1e12
    }
}

/// Project a mask onto A100 kernel time for `method` at the paper's
/// bench geometry (batch x heads single calls, Br = Bc = 128).
pub fn estimate(
    method: Method,
    mask: &FlashMask,
    batch: usize,
    heads: usize,
    d: usize,
) -> KernelEstimate {
    let n = mask.n();
    let tile = 128usize.min(n);
    let table = BlockTable::build(mask, tile);
    let (fully, partial, unmasked) = table.census(mask, tile);
    let rho = fully as f64 / (fully + partial + unmasked) as f64;

    let tile_flops = 4.0 * (tile * tile * d) as f64; // fwd: 2 matmuls
    let calls = (batch * heads) as f64;
    let rates = method.rates();

    let (p_tiles, u_tiles) = if method.skips_fully_masked() {
        (partial as f64, unmasked as f64)
    } else {
        // non-skipping methods execute fully-masked tiles as partial work
        ((partial + fully) as f64, unmasked as f64)
    };

    // prologue/epilogue per row (fwd) / column (bwd) block, priced in
    // unmasked-tile equivalents — the high-sparsity efficiency sink
    let blocks = (n.div_ceil(tile)) as f64;
    let fw_s = calls
        * ((p_tiles * tile_flops / (rates.fwd_partial * 1e12))
            + ((u_tiles + rates.fwd_block_overhead_tiles * blocks) * tile_flops
                / (rates.fwd_unmasked * 1e12)))
        + rates.overhead_ms / 1e3;
    let bw_tile_flops = tile_flops * 2.5;
    let bw_s = calls
        * ((p_tiles * bw_tile_flops / (rates.bwd_partial * 1e12))
            + ((u_tiles + rates.bwd_block_overhead_tiles * blocks) * bw_tile_flops
                / (rates.bwd_unmasked * 1e12)))
        + rates.overhead_ms / 1e3;

    // the paper counts FLOPs over all non-fully-masked tiles
    let useful_tiles = (partial + unmasked) as f64;
    let fw_tflops = calls * useful_tiles * tile_flops / 1e12;
    KernelEstimate {
        fw_ms: fw_s * 1e3,
        bw_ms: bw_s * 1e3,
        fw_tflops,
        bw_tflops: fw_tflops * 2.5,
        sparsity: rho,
    }
}

/// TFLOPs/s the estimate achieves (the paper's headline metric).
pub fn tflops_per_s(e: &KernelEstimate) -> (f64, f64, f64) {
    let fw = e.fw_tflops / (e.fw_ms / 1e3);
    let bw = e.bw_tflops / (e.bw_ms / 1e3);
    let total = (e.fw_tflops + e.bw_tflops) / (e.total_ms() / 1e3);
    (fw, bw, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;
    use crate::perf::flops::paper_bench_geometry;

    fn pct_diff(a: f64, b: f64) -> f64 {
        (a - b).abs() / b * 100.0
    }

    #[test]
    fn anchors_table5_full_and_causal() {
        // Table 5 (32K, hd128): FLASHMASK Full total 211.4 TFLOPs/s,
        // Causal total 211.7
        let (batch, heads) = paper_bench_geometry(32768, 128);
        let full = estimate(Method::FlashMask, &builders::full(32768), batch, heads, 128);
        let (_, _, total) = tflops_per_s(&full);
        assert!(pct_diff(total, 211.4) < 12.0, "full total={total}");

        let causal = estimate(Method::FlashMask, &builders::causal(32768), batch, heads, 128);
        let (_, _, total) = tflops_per_s(&causal);
        assert!(pct_diff(total, 211.7) < 12.0, "causal total={total}");
        assert!((causal.sparsity - 0.5).abs() < 0.01);
    }

    #[test]
    fn flashmask_beats_flex_everywhere() {
        // the paper's headline: 12.1%–60.7% faster than FlexAttention
        let (batch, heads) = paper_bench_geometry(32768, 128);
        for (kind, mask) in builders::benchmark_suite(32768, 3) {
            let fm = estimate(Method::FlashMask, &mask, batch, heads, 128);
            let fx = estimate(Method::FlexAttention, &mask, batch, heads, 128);
            let (_, _, t_fm) = tflops_per_s(&fm);
            let (_, _, t_fx) = tflops_per_s(&fx);
            let gain = (t_fm / t_fx - 1.0) * 100.0;
            assert!(gain > 0.0, "{kind}: FLASHMASK {t_fm} <= Flex {t_fx}");
            assert!(gain < 110.0, "{kind}: implausible gain {gain}%");
        }
    }

    #[test]
    fn utilization_band_matches_paper() {
        // paper: FLASHMASK achieves 37.8%–62.3% of A100 peak (hd128)
        let (batch, heads) = paper_bench_geometry(32768, 128);
        for (kind, mask) in builders::benchmark_suite(32768, 4) {
            let e = estimate(Method::FlashMask, &mask, batch, heads, 128);
            let (_, _, total) = tflops_per_s(&e);
            let util = total / A100_PEAK_TFLOPS * 100.0;
            assert!(
                (30.0..75.0).contains(&util),
                "{kind}: utilization {util}% outside plausible band"
            );
        }
    }

    #[test]
    fn vanilla_much_slower() {
        let (batch, heads) = paper_bench_geometry(8192, 128);
        let mask = builders::causal(8192);
        let fm = estimate(Method::FlashMask, &mask, batch, heads, 128);
        let va = estimate(Method::Vanilla, &mask, batch, heads, 128);
        assert!(va.total_ms() > 3.0 * fm.total_ms());
    }

    #[test]
    fn latency_linear_in_sparsity() {
        // Fig 4(a): latency ∝ (1-ρ) for the same mask family
        let (batch, heads) = paper_bench_geometry(8192, 128);
        let m1 = builders::causal_document(8192, &[4096, 4096]);
        let m2 = builders::causal_document(8192, &[1024; 8]);
        let e1 = estimate(Method::FlashMask, &m1, batch, heads, 128);
        let e2 = estimate(Method::FlashMask, &m2, batch, heads, 128);
        assert!(e2.sparsity > e1.sparsity);
        assert!(e2.total_ms() < e1.total_ms());
        // ratio of times tracks ratio of (1-ρ), damped by the per-block
        // prologue/epilogue overhead (the Fig 4a curve has an intercept)
        let r_time = e2.total_ms() / e1.total_ms();
        let r_work = (1.0 - e2.sparsity) / (1.0 - e1.sparsity);
        assert!(r_time > r_work, "overhead should damp the ratio");
        assert!((r_time / r_work - 1.0).abs() < 0.8, "{r_time} vs {r_work}");
    }
}
