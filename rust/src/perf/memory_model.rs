//! Training memory model — reproduces paper Table 2, Fig. 4(b), Fig. 7.
//!
//! Calibrated against Table 2's Llama-2 7B column (TP=4, sharding
//! stage-1 over 8, full recompute, bf16 params / f32 grads+opt):
//!
//! | Seq (K) | Param&Opt | Activations | Peak one layer | Total |
//! |  16     |  13.12    |  1.00       |  2.50          | 16.63 |
//!
//! * param+opt: `6 B/param / tp + 12 B/param / (tp * shard)`
//! * activations (sequence parallel): `layers * N * hidden * 2 / tp`
//! * peak-one-layer (recompute): `~80 * N * hidden * 2 / tp`
//!   (80 ≈ attention+MLP intermediates of one recomputed layer)
//! * dense mask: `N² * 2` bytes; FLASHMASK: `16 N` (+ 8 min/max vecs).

use crate::attention::HeadLayout;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Llama-2 model family geometry.
#[derive(Clone, Copy, Debug)]
pub struct LlamaConfig {
    pub name: &'static str,
    pub n_params: f64,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
}

pub const LLAMA2_7B: LlamaConfig =
    LlamaConfig { name: "Llama2-7B", n_params: 6.74e9, hidden: 4096, layers: 32, heads: 32 };
pub const LLAMA2_13B: LlamaConfig =
    LlamaConfig { name: "Llama2-13B", n_params: 13.0e9, hidden: 5120, layers: 40, heads: 40 };
pub const LLAMA2_70B: LlamaConfig =
    LlamaConfig { name: "Llama2-70B", n_params: 69.0e9, hidden: 8192, layers: 80, heads: 64 };

/// Paper Table 1: distributed layout per scale (32 GPUs total).
#[derive(Clone, Copy, Debug)]
pub struct ParallelConfig {
    pub sharding: usize,
    pub tp: usize,
    pub pp: usize,
}

pub fn paper_layout(cfg: &LlamaConfig) -> ParallelConfig {
    match cfg.name {
        "Llama2-7B" => ParallelConfig { sharding: 8, tp: 4, pp: 1 },
        "Llama2-13B" => ParallelConfig { sharding: 4, tp: 4, pp: 2 },
        _ => ParallelConfig { sharding: 1, tp: 8, pp: 4 },
    }
}

/// Attention-mask memory per sample, bytes.
pub fn dense_mask_bytes(n: usize) -> f64 {
    (n as f64) * (n as f64) * 2.0 // bf16
}

pub fn flashmask_bytes(n: usize, bc: usize) -> f64 {
    (4 * n * 4) as f64 + (8 * n.div_ceil(bc) * 4) as f64
}

/// Decode-time KV-cache residency for one sequence, bytes: K and V
/// planes of `kv_heads · n · d` elements each.  The grouped-layout
/// lever the serving stack exploits: residency scales with
/// `layout.kv_heads`, not `layout.q_heads`, so a group-8 GQA model
/// admits ~8× more concurrent sequences from the same page pool.
pub fn kv_cache_bytes(layout: HeadLayout, n: usize, d: usize, bytes_per_el: usize) -> f64 {
    (2 * layout.kv_heads * n * d * bytes_per_el) as f64
}

/// Paged variant of [`kv_cache_bytes`]: residency rounds up to whole
/// pages per KV-head chain (the pool allocates in page granules).
pub fn kv_cache_bytes_paged(
    layout: HeadLayout,
    n: usize,
    d: usize,
    bytes_per_el: usize,
    page_size: usize,
) -> f64 {
    let pages = layout.kv_heads * n.div_ceil(page_size);
    (2 * pages * page_size * d * bytes_per_el) as f64
}

/// Per-GPU memory breakdown, GB.
#[derive(Clone, Copy, Debug)]
pub struct MemoryBreakdown {
    pub param_opt_gb: f64,
    pub activations_gb: f64,
    pub peak_layer_gb: f64,
    pub mask_gb: f64,
    pub total_gb: f64,
}

/// Mask handling variants of the memory model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskMemory {
    FlashMask,
    DenseMask,
    /// Vanilla attention additionally materializes S and P (N² each).
    VanillaDense,
}

pub fn breakdown(
    model: &LlamaConfig,
    par: &ParallelConfig,
    seq: usize,
    mask: MaskMemory,
) -> MemoryBreakdown {
    let p = model.n_params / (par.pp as f64);
    let param_grad = p * 6.0 / par.tp as f64; // bf16 params + f32 grads
    let opt = p * 12.0 / (par.tp * par.sharding) as f64; // f32 m, v, master
    let param_opt_gb = (param_grad + opt) / GB;

    let layers_here = model.layers / par.pp;
    // sequence-parallel activations kept across layers (full recompute:
    // only the layer inputs persist); small-seq runs keep them in the
    // fragmentation slack, matching Table 2's zeros at 4K/8K
    let act = if seq >= 16384 {
        (layers_here * seq * model.hidden * 2) as f64 / par.tp as f64
    } else {
        0.0
    };
    let activations_gb = act / GB;

    let peak_layer = 80.0 * (seq * model.hidden * 2) as f64 / par.tp as f64;
    let peak_layer_gb = peak_layer / GB;

    let mask_bytes = match mask {
        MaskMemory::FlashMask => flashmask_bytes(seq, 128),
        MaskMemory::DenseMask => dense_mask_bytes(seq),
        MaskMemory::VanillaDense => 3.0 * dense_mask_bytes(seq), // M + S + P
    };
    let mask_gb = mask_bytes / GB;

    MemoryBreakdown {
        param_opt_gb,
        activations_gb,
        peak_layer_gb,
        mask_gb,
        total_gb: param_opt_gb + activations_gb + peak_layer_gb + mask_gb,
    }
}

/// Longest sequence fitting in `budget_gb` (Fig. 2's max-seq bars).
pub fn max_seq(model: &LlamaConfig, par: &ParallelConfig, mask: MaskMemory, budget_gb: f64) -> usize {
    let mut best = 0;
    let mut n = 4096;
    while n <= 1024 * 1024 {
        if breakdown(model, par, n, mask).total_gb <= budget_gb {
            best = n;
        }
        n *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol_pct: f64) -> bool {
        (a - b).abs() / b * 100.0 < tol_pct
    }

    #[test]
    fn table2_param_opt_anchor() {
        let b = breakdown(&LLAMA2_7B, &paper_layout(&LLAMA2_7B), 16384, MaskMemory::FlashMask);
        // paper: 13.12 GB
        assert!(close(b.param_opt_gb, 13.12, 15.0), "param_opt={}", b.param_opt_gb);
    }

    #[test]
    fn table2_activation_anchor() {
        let b16 = breakdown(&LLAMA2_7B, &paper_layout(&LLAMA2_7B), 16384, MaskMemory::FlashMask);
        assert!(close(b16.activations_gb, 1.0, 10.0), "act={}", b16.activations_gb);
        let b64 = breakdown(&LLAMA2_7B, &paper_layout(&LLAMA2_7B), 65536, MaskMemory::FlashMask);
        assert!(close(b64.activations_gb, 4.0, 10.0), "act={}", b64.activations_gb);
    }

    #[test]
    fn table2_peak_layer_anchor() {
        let b = breakdown(&LLAMA2_7B, &paper_layout(&LLAMA2_7B), 32768, MaskMemory::FlashMask);
        // paper: 4.95 GB at 32K
        assert!(close(b.peak_layer_gb, 4.95, 15.0), "peak={}", b.peak_layer_gb);
    }

    #[test]
    fn dense_mask_8gb_at_64k() {
        // paper §5.1: "at 64K the dense mask costs 8GB"
        assert!(close(dense_mask_bytes(65536) / super::GB, 8.0, 1.0));
    }

    #[test]
    fn flashmask_memory_is_linear_and_tiny() {
        let f = flashmask_bytes(131072, 128);
        let d = dense_mask_bytes(131072);
        assert!(f < d / 10_000.0, "flashmask {f} vs dense {d}");
        // linear: doubling N doubles bytes
        assert!((flashmask_bytes(262144, 128) / f - 2.0).abs() < 0.01);
    }

    #[test]
    fn flashmask_supports_longer_sequences() {
        let layout = paper_layout(&LLAMA2_7B);
        let m_fm = max_seq(&LLAMA2_7B, &layout, MaskMemory::FlashMask, 80.0);
        let m_dm = max_seq(&LLAMA2_7B, &layout, MaskMemory::DenseMask, 80.0);
        let m_va = max_seq(&LLAMA2_7B, &layout, MaskMemory::VanillaDense, 80.0);
        assert!(m_fm > m_dm, "flashmask {m_fm} <= dense {m_dm}");
        assert!(m_dm >= m_va);
        // paper: dense methods stall around 64K on the 7B config
        assert!((32768..=131072).contains(&m_dm), "dense max {m_dm}");
        assert!(m_fm >= 262144, "flashmask max {m_fm}");
    }

    #[test]
    fn kv_cache_scales_with_kv_heads_not_q_heads() {
        let (n, d) = (8192, 128);
        let mha = kv_cache_bytes(HeadLayout::mha(32), n, d, 2);
        let gqa = kv_cache_bytes(HeadLayout::new(32, 4), n, d, 2);
        let mqa = kv_cache_bytes(HeadLayout::mqa(32), n, d, 2);
        assert!((mha / gqa - 8.0).abs() < 1e-9, "group-8 must cut residency 8x");
        assert!((mha / mqa - 32.0).abs() < 1e-9, "MQA must cut residency q_heads-x");
        // anchor: 32 KV heads, 8K tokens, d=128, bf16 => 2*32*8192*128*2 B = 128 MiB
        assert_eq!(mha, 2.0 * 32.0 * 8192.0 * 128.0 * 2.0);
        // paged residency never undercounts the exact bytes and agrees
        // when n is page-aligned
        let paged = kv_cache_bytes_paged(HeadLayout::new(32, 4), n, d, 2, 16);
        assert_eq!(paged, gqa, "page-aligned n must match exact bytes");
        let ragged = kv_cache_bytes_paged(HeadLayout::new(32, 4), n + 1, d, 2, 16);
        assert!(ragged > gqa && ragged < gqa + (2 * 4 * 16 * d * 2) as f64 + 1.0);
    }

    #[test]
    fn bigger_models_need_more() {
        let s = 32768;
        let b7 = breakdown(&LLAMA2_7B, &paper_layout(&LLAMA2_7B), s, MaskMemory::FlashMask);
        let b70 = breakdown(&LLAMA2_70B, &paper_layout(&LLAMA2_70B), s, MaskMemory::FlashMask);
        assert!(b70.param_opt_gb > b7.param_opt_gb);
    }
}
