//! Sparsity-aware FLOPs accounting (paper appendix A.5.1).
//!
//! The paper computes reported FLOPs from block sparsity: a forward pass
//! does two `N x N x d` matmuls (S = QK^T and O = PV), so
//! `FW = 4 N² d H B (1-ρ)`; the backward does five, `BW = 2.5 x FW`.

/// Forward FLOPs for a batch of attention heads at block sparsity `rho`.
pub fn attention_fwd_flops(batch: usize, heads: usize, n: usize, d: usize, rho: f64) -> f64 {
    4.0 * (batch * heads * d) as f64 * (n as f64) * (n as f64) * (1.0 - rho)
}

/// Backward FLOPs (5 matmuls vs the forward's 2).
pub fn attention_bwd_flops(batch: usize, heads: usize, n: usize, d: usize, rho: f64) -> f64 {
    2.5 * attention_fwd_flops(batch, heads, n, d, rho)
}

/// The paper's kernel-bench geometry: 128K total tokens, hidden 4096.
/// Varying `n` gives the batch; varying `d` gives the head count.
pub fn paper_bench_geometry(n: usize, head_dim: usize) -> (usize, usize) {
    let total_tokens = 128 * 1024;
    let hidden = 4096;
    (total_tokens / n, hidden / head_dim)
}

/// Dense-transformer training FLOPs per token (the 6·P rule).
pub fn transformer_train_flops_per_token(n_params: f64) -> f64 {
    6.0 * n_params
}

/// End-to-end training FLOPs for one step: dense matmul part + the
/// sparsity-dependent attention part.
pub fn train_step_flops(
    n_params: f64,
    batch: usize,
    seq: usize,
    layers: usize,
    heads: usize,
    head_dim: usize,
    rho: f64,
) -> f64 {
    let tokens = (batch * seq) as f64;
    let dense = transformer_train_flops_per_token(n_params) * tokens;
    let attn = (attention_fwd_flops(batch, heads, seq, head_dim, rho)
        + attention_bwd_flops(batch, heads, seq, head_dim, rho))
        * layers as f64;
    dense + attn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_table4_full_row() {
        // Table 4 (8K, hd 128): Full mask FW = 17.59 TFLOPs
        let (batch, heads) = paper_bench_geometry(8192, 128);
        assert_eq!((batch, heads), (16, 32));
        let fw = attention_fwd_flops(batch, heads, 8192, 128, 0.0);
        assert!((fw / 1e12 - 17.59).abs() < 0.01, "fw={}", fw / 1e12);
        let bw = attention_bwd_flops(batch, heads, 8192, 128, 0.0);
        assert!((bw / 1e12 - 43.98).abs() < 0.03, "bw={}", bw / 1e12);
    }

    #[test]
    fn matches_paper_table5_causal_row() {
        // Table 5 (32K, hd 128): Causal (rho 0.50) FW = 35.32 TFLOPs
        let (batch, heads) = paper_bench_geometry(32768, 128);
        let fw = attention_fwd_flops(batch, heads, 32768, 128, 0.50);
        assert!((fw / 1e12 - 35.18).abs() < 0.30, "fw={}", fw / 1e12);
    }

    #[test]
    fn matches_paper_table9_sliding_window() {
        // Table 9 (128K, hd 64): Sliding Window rho=0.94 FW = 17.31 TFLOPs
        let (batch, heads) = paper_bench_geometry(131072, 64);
        assert_eq!((batch, heads), (1, 64));
        let fw = attention_fwd_flops(batch, heads, 131072, 64, 0.94);
        // paper's rho is 0.9385-ish; 0.94 is the rounded table value
        assert!((fw / 1e12 - 17.31).abs() < 0.60, "fw={}", fw / 1e12);
    }

    #[test]
    fn sparsity_scales_linearly() {
        let f0 = attention_fwd_flops(1, 1, 1024, 64, 0.0);
        let f5 = attention_fwd_flops(1, 1, 1024, 64, 0.5);
        assert!((f5 / f0 - 0.5).abs() < 1e-12);
    }
}
