//! Layer-3 coordinator: the training driver.
//!
//! FlashMask's contribution lives at L1/L2, so (per DESIGN.md) L3 is a
//! lean driver with real substance in its substrates: the [`batcher`]
//! packs sampled documents into fixed-length sequences and derives the
//! per-sample FlashMask vectors; the [`trainer`] owns optimizer state
//! and drives the AOT train-step executable; [`metrics`] tracks loss and
//! throughput.  Python is never invoked here.

pub mod batcher;
pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use batcher::{Batch, Batcher};
pub use checkpoint::Checkpoint;
pub use trainer::{StepPlanner, TrainLog, Trainer, TrainerOptions};
