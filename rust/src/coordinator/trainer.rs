//! The training loop: AOT train-step executable + AdamW state, driven
//! entirely from rust.
//!
//! State layout follows the manifest ABI: `params..., m..., v...,
//! step_no, tokens, targets, loss_mask, lts, lte, uts, ute` in, and
//! `loss, params'..., m'..., v'...` out.  Parameters round-trip through
//! host literals each step (the crate's execute API returns one tuple
//! buffer); at the e2e model scales this transfer is a few percent of
//! step time — measured in EXPERIMENTS.md §Perf.

use super::batcher::Batch;
use super::metrics::Metrics;
use crate::attention::api::{AttnProblem, ExecutionPlan, PlanCache};
use crate::mask::FlashMask;
use crate::runtime::{Executable, HostTensor, Runtime};
use anyhow::{anyhow, ensure, Context, Result};
use std::sync::Arc;

/// Per-sample attention plans for training batches, with a [`PlanCache`]
/// held **across steps**: epochs revisit the same packed-document
/// layouts, so the Eq. 4 classification + per-tile mask cache for a
/// given sample mask is built once per unique mask, not once per step.
/// `plans_built()` therefore tracks unique mask keys, not step count —
/// asserted in the tests below and in `bench_train`.
///
/// Shared by [`Trainer::step`] (validation + plan reuse ahead of the
/// fused artifact) and by the CPU training bench, which drives
/// `CpuBackend` prefill/backward directly from the resolved plans.
pub struct StepPlanner {
    cache: PlanCache,
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
    threads: usize,
    skip: bool,
}

impl StepPlanner {
    pub fn new(n: usize, d: usize, br: usize, bc: usize) -> StepPlanner {
        StepPlanner { cache: PlanCache::default(), n, d, br, bc, threads: 1, skip: true }
    }

    /// Thread cap stamped into each plan (default 1).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Eq. 4 tile skipping (default on; `false` = dense-mask baseline).
    pub fn skip(mut self, skip: bool) -> Self {
        self.skip = skip;
        self
    }

    /// Plans actually built (cache misses) — equals the number of
    /// *unique* sample masks seen, not the number of steps.
    pub fn plans_built(&self) -> u64 {
        self.cache.misses()
    }

    /// Cache hits (steps × samples that reused an existing plan).
    pub fn plan_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Resolve one [`ExecutionPlan`] per batch sample, reusing cached
    /// plans for repeated masks.  A malformed sample mask surfaces as
    /// a typed `AttnError` wrapped with the sample index — plan
    /// validation subsumes the old per-sample `validate_parts` check.
    pub fn plan_batch(&mut self, batch: &Batch) -> Result<Vec<Arc<ExecutionPlan>>> {
        let mut plans = Vec::with_capacity(batch.batch);
        for bi in 0..batch.batch {
            let r = bi * batch.n..(bi + 1) * batch.n;
            let mask = FlashMask {
                lts: batch.lts[r.clone()].to_vec(),
                lte: batch.lte[r.clone()].to_vec(),
                uts: batch.uts[r.clone()].to_vec(),
                ute: batch.ute[r].to_vec(),
                causal: true,
            };
            let problem = AttnProblem::new(self.n, self.d)
                .mask(&mask)
                .tile(self.br, self.bc)
                .threads(self.threads)
                .skip(self.skip);
            plans.push(
                self.cache
                    .get_or_build(&problem)
                    .map_err(|e| anyhow!("train batch sample {bi}: {e}"))?,
            );
        }
        Ok(plans)
    }
}

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// `flashmask` or `densemask` (the paper's convergence A/B).
    pub variant: String,
    pub seed: i32,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions { variant: "flashmask".into(), seed: 0, log_every: 10, quiet: false }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub tokens_per_s: f64,
    pub steps: usize,
    pub elapsed_s: f64,
}

pub struct Trainer {
    step_exe: Executable,
    n_leaves: usize,
    params: Vec<HostTensor>,
    opt_m: Vec<HostTensor>,
    opt_v: Vec<HostTensor>,
    step_no: i32,
    opts: TrainerOptions,
    planner: StepPlanner,
    pub metrics: Metrics,
}

impl Trainer {
    /// Initialize from artifacts: runs the `init` executable (so even
    /// parameter initialization happens without python).
    pub fn new(rt: &Runtime, opts: TrainerOptions) -> Result<Trainer> {
        let artifact = format!("train_step_{}", opts.variant);
        let step_exe = rt
            .load(&artifact)
            .with_context(|| format!("loading train-step artifact '{artifact}'"))?;
        let init = rt.load("init")?;
        let seed = HostTensor::I32 { shape: vec![1], data: vec![opts.seed] };
        let params = init.run(&[seed])?;
        let n_leaves = rt.manifest.n_leaves();
        ensure!(params.len() == n_leaves, "init returned {} leaves, want {n_leaves}", params.len());
        // validate the manifest's attention geometry through the
        // unified API once, up front: a bad (max_seq, d_head, Br, Bc)
        // combination surfaces here as a typed AttnError instead of as
        // an opaque artifact failure mid-training
        let m = &rt.manifest.model;
        let template = FlashMask::empty(m.max_seq, true);
        AttnProblem::new(m.max_seq, m.d_head)
            .mask(&template)
            .tile(m.br, m.bc)
            .validate()
            .map_err(|e| anyhow!("manifest attention geometry: {e}"))?;
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::F32 { shape: p.shape().to_vec(), data: vec![0.0; p.numel()] })
            .collect();
        Ok(Trainer {
            step_exe,
            n_leaves,
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            step_no: 0,
            opts,
            planner: StepPlanner::new(m.max_seq, m.d_head, m.br, m.bc),
            metrics: Metrics::new(),
        })
    }

    /// Unique sample masks planned so far (PlanCache misses); stays
    /// flat across steps that revisit the same packed layouts.
    pub fn plans_built(&self) -> u64 {
        self.planner.plans_built()
    }

    pub fn n_params(&self) -> usize {
        self.params.iter().map(HostTensor::numel).sum()
    }

    /// Execute one optimizer step on a batch; returns the loss.
    ///
    /// Each sample's mask is resolved through the cross-step
    /// [`StepPlanner`]: a malformed interval surfaces here as a typed
    /// error with the sample index instead of as NaNs three layers down
    /// the train-step artifact, and repeated packed-document layouts
    /// reuse their cached plan (Eq. 4 classification + tile mask cache)
    /// instead of rebuilding it every step.  The manifest-level
    /// attention geometry was validated through `attention::api` once
    /// in [`Trainer::new`].
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let sp = crate::telemetry::trace::span(crate::telemetry::names::TRAIN_STEP);
        sp.add("tokens", (batch.batch * batch.n) as u64);
        self.planner.plan_batch(batch)?;
        sp.add("plans_built", self.planner.plans_built());
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(3 * self.n_leaves + 1 + 7);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_m.iter().cloned());
        inputs.extend(self.opt_v.iter().cloned());
        inputs.push(HostTensor::I32 { shape: vec![], data: vec![self.step_no] });
        inputs.extend(batch.to_tensors());

        let mut out = {
            // the AOT artifact fuses forward+backward+optimizer; the
            // span marks where the backward lives under `train.step`
            // (the CPU path's `CpuBackend::backward` opens the same
            // span name and feeds the `train.backward_ms` histogram)
            let bsp = crate::telemetry::trace::span(crate::telemetry::names::PLAN_BACKWARD);
            bsp.add("fused", 1);
            self.step_exe.run(&inputs)?
        };
        ensure!(
            out.len() == 1 + 3 * self.n_leaves,
            "train step returned {} outputs, want {}",
            out.len(),
            1 + 3 * self.n_leaves
        );
        let loss = out[0].scalar_f32()?;
        let rest = out.split_off(1);
        let mut it = rest.into_iter();
        self.params = (&mut it).take(self.n_leaves).collect();
        self.opt_m = (&mut it).take(self.n_leaves).collect();
        self.opt_v = (&mut it).take(self.n_leaves).collect();
        self.step_no += 1;
        self.metrics.record(loss, batch.loss_tokens.max(batch.batch * batch.n));
        Ok(loss)
    }

    /// Snapshot the full optimizer state.
    pub fn checkpoint(&self) -> super::Checkpoint {
        super::Checkpoint {
            step: self.step_no as u32,
            params: self.params.clone(),
            opt_m: self.opt_m.clone(),
            opt_v: self.opt_v.clone(),
        }
    }

    /// Restore from a snapshot (shapes must match the manifest ABI).
    pub fn restore(&mut self, ck: super::Checkpoint) -> Result<()> {
        ensure!(ck.params.len() == self.n_leaves, "checkpoint leaf count mismatch");
        for (a, b) in ck.params.iter().zip(&self.params) {
            ensure!(a.shape() == b.shape(), "checkpoint shape mismatch");
        }
        self.params = ck.params;
        self.opt_m = ck.opt_m;
        self.opt_v = ck.opt_v;
        self.step_no = ck.step as i32;
        Ok(())
    }

    /// Run `steps` optimizer steps pulling batches from `batcher`.
    pub fn train(&mut self, batcher: &mut super::Batcher, steps: usize) -> Result<TrainLog> {
        for s in 0..steps {
            let batch = batcher.next_batch();
            let loss = self.step(&batch)?;
            if !self.opts.quiet && (s + 1) % self.opts.log_every.max(1) == 0 {
                crate::telemetry::log::info(
                    crate::telemetry::names::TARGET_TRAIN,
                    format!(
                        "step {:>5}  loss {:>8.4}  ema {:>8.4}  {:>9.0} tok/s  rho={:.2}",
                        s + 1,
                        loss,
                        self.metrics.ema_loss(),
                        self.metrics.tokens_per_s(),
                        batch.sparsity,
                    ),
                );
            }
        }
        Ok(TrainLog {
            losses: self.metrics.losses.clone(),
            tokens_per_s: self.metrics.tokens_per_s(),
            steps: self.metrics.steps,
            elapsed_s: self.metrics.elapsed_s(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::StepPlanner;
    use crate::coordinator::Batcher;
    use crate::workload::docgen::Task;

    #[test]
    fn step_planner_builds_once_per_unique_mask_not_per_step() {
        let (n, batch) = (128, 2);
        let mut batcher = Batcher::new(n, batch, Task::Sft, 9);
        let b = batcher.next_batch();
        let mut planner = StepPlanner::new(n, 16, 32, 32);

        let plans = planner.plan_batch(&b).expect("generated batch must plan");
        assert_eq!(plans.len(), batch);
        let built_after_first = planner.plans_built();
        assert!((1..=batch as u64).contains(&built_after_first));

        // replaying the same batch for more "steps" builds nothing new:
        // plans_built counts unique masks, not steps
        for _ in 0..3 {
            planner.plan_batch(&b).expect("replay must plan");
        }
        assert_eq!(planner.plans_built(), built_after_first);
        assert!(planner.plan_hits() >= 3 * batch as u64);

        // a genuinely new batch layout may add plans, never remove
        let b2 = batcher.next_batch();
        planner.plan_batch(&b2).expect("second batch must plan");
        assert!(planner.plans_built() >= built_after_first);
    }

    #[test]
    fn step_planner_rejects_malformed_sample_with_index() {
        let (n, batch) = (64, 2);
        let mut batcher = Batcher::new(n, batch, Task::Sft, 3);
        let mut b = batcher.next_batch();
        // corrupt sample 1's lower-triangular start interval
        b.lts[n] = n as i32 + 7;
        let mut planner = StepPlanner::new(n, 16, 32, 32);
        let err = planner.plan_batch(&b).expect_err("corrupt mask must fail");
        assert!(format!("{err:#}").contains("sample 1"), "got: {err:#}");
    }
}
