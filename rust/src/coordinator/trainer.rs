//! The training loop: AOT train-step executable + AdamW state, driven
//! entirely from rust.
//!
//! State layout follows the manifest ABI: `params..., m..., v...,
//! step_no, tokens, targets, loss_mask, lts, lte, uts, ute` in, and
//! `loss, params'..., m'..., v'...` out.  Parameters round-trip through
//! host literals each step (the crate's execute API returns one tuple
//! buffer); at the e2e model scales this transfer is a few percent of
//! step time — measured in EXPERIMENTS.md §Perf.

use super::batcher::Batch;
use super::metrics::Metrics;
use crate::attention::api::AttnProblem;
use crate::mask::FlashMask;
use crate::runtime::{Executable, HostTensor, Runtime};
use anyhow::{anyhow, ensure, Context, Result};

#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// `flashmask` or `densemask` (the paper's convergence A/B).
    pub variant: String,
    pub seed: i32,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions { variant: "flashmask".into(), seed: 0, log_every: 10, quiet: false }
    }
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub tokens_per_s: f64,
    pub steps: usize,
    pub elapsed_s: f64,
}

pub struct Trainer {
    step_exe: Executable,
    n_leaves: usize,
    params: Vec<HostTensor>,
    opt_m: Vec<HostTensor>,
    opt_v: Vec<HostTensor>,
    step_no: i32,
    opts: TrainerOptions,
    pub metrics: Metrics,
}

impl Trainer {
    /// Initialize from artifacts: runs the `init` executable (so even
    /// parameter initialization happens without python).
    pub fn new(rt: &Runtime, opts: TrainerOptions) -> Result<Trainer> {
        let artifact = format!("train_step_{}", opts.variant);
        let step_exe = rt
            .load(&artifact)
            .with_context(|| format!("loading train-step artifact '{artifact}'"))?;
        let init = rt.load("init")?;
        let seed = HostTensor::I32 { shape: vec![1], data: vec![opts.seed] };
        let params = init.run(&[seed])?;
        let n_leaves = rt.manifest.n_leaves();
        ensure!(params.len() == n_leaves, "init returned {} leaves, want {n_leaves}", params.len());
        // validate the manifest's attention geometry through the
        // unified API once, up front: a bad (max_seq, d_head, Br, Bc)
        // combination surfaces here as a typed AttnError instead of as
        // an opaque artifact failure mid-training
        let m = &rt.manifest.model;
        let template = FlashMask::empty(m.max_seq, true);
        AttnProblem::new(m.max_seq, m.d_head)
            .mask(&template)
            .tile(m.br, m.bc)
            .validate()
            .map_err(|e| anyhow!("manifest attention geometry: {e}"))?;
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::F32 { shape: p.shape().to_vec(), data: vec![0.0; p.numel()] })
            .collect();
        Ok(Trainer {
            step_exe,
            n_leaves,
            params,
            opt_m: zeros.clone(),
            opt_v: zeros,
            step_no: 0,
            opts,
            metrics: Metrics::new(),
        })
    }

    pub fn n_params(&self) -> usize {
        self.params.iter().map(HostTensor::numel).sum()
    }

    /// Execute one optimizer step on a batch; returns the loss.
    ///
    /// Each sample's FlashMask vectors are validated first via the
    /// allocation-free `FlashMask::validate_parts` (the hot path copies
    /// nothing): a malformed interval surfaces here as a typed error
    /// with the sample index instead of as NaNs three layers down the
    /// train-step artifact.  The manifest-level attention geometry was
    /// validated through `attention::api` once in [`Trainer::new`].
    pub fn step(&mut self, batch: &Batch) -> Result<f32> {
        let sp = crate::telemetry::trace::span("train.step");
        sp.add("tokens", (batch.batch * batch.n) as u64);
        for bi in 0..batch.batch {
            let r = bi * batch.n..(bi + 1) * batch.n;
            FlashMask::validate_parts(
                &batch.lts[r.clone()],
                &batch.lte[r.clone()],
                &batch.uts[r.clone()],
                &batch.ute[r],
                true,
            )
            .map_err(|e| anyhow!("train batch sample {bi}: {e:#}"))?;
        }
        let mut inputs: Vec<HostTensor> =
            Vec::with_capacity(3 * self.n_leaves + 1 + 7);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.opt_m.iter().cloned());
        inputs.extend(self.opt_v.iter().cloned());
        inputs.push(HostTensor::I32 { shape: vec![], data: vec![self.step_no] });
        inputs.extend(batch.to_tensors());

        let mut out = self.step_exe.run(&inputs)?;
        ensure!(
            out.len() == 1 + 3 * self.n_leaves,
            "train step returned {} outputs, want {}",
            out.len(),
            1 + 3 * self.n_leaves
        );
        let loss = out[0].scalar_f32()?;
        let rest = out.split_off(1);
        let mut it = rest.into_iter();
        self.params = (&mut it).take(self.n_leaves).collect();
        self.opt_m = (&mut it).take(self.n_leaves).collect();
        self.opt_v = (&mut it).take(self.n_leaves).collect();
        self.step_no += 1;
        self.metrics.record(loss, batch.loss_tokens.max(batch.batch * batch.n));
        Ok(loss)
    }

    /// Snapshot the full optimizer state.
    pub fn checkpoint(&self) -> super::Checkpoint {
        super::Checkpoint {
            step: self.step_no as u32,
            params: self.params.clone(),
            opt_m: self.opt_m.clone(),
            opt_v: self.opt_v.clone(),
        }
    }

    /// Restore from a snapshot (shapes must match the manifest ABI).
    pub fn restore(&mut self, ck: super::Checkpoint) -> Result<()> {
        ensure!(ck.params.len() == self.n_leaves, "checkpoint leaf count mismatch");
        for (a, b) in ck.params.iter().zip(&self.params) {
            ensure!(a.shape() == b.shape(), "checkpoint shape mismatch");
        }
        self.params = ck.params;
        self.opt_m = ck.opt_m;
        self.opt_v = ck.opt_v;
        self.step_no = ck.step as i32;
        Ok(())
    }

    /// Run `steps` optimizer steps pulling batches from `batcher`.
    pub fn train(&mut self, batcher: &mut super::Batcher, steps: usize) -> Result<TrainLog> {
        for s in 0..steps {
            let batch = batcher.next_batch();
            let loss = self.step(&batch)?;
            if !self.opts.quiet && (s + 1) % self.opts.log_every.max(1) == 0 {
                crate::telemetry::log::info(
                    "train",
                    format!(
                        "step {:>5}  loss {:>8.4}  ema {:>8.4}  {:>9.0} tok/s  rho={:.2}",
                        s + 1,
                        loss,
                        self.metrics.ema_loss(),
                        self.metrics.tokens_per_s(),
                        batch.sparsity,
                    ),
                );
            }
        }
        Ok(TrainLog {
            losses: self.metrics.losses.clone(),
            tokens_per_s: self.metrics.tokens_per_s(),
            steps: self.metrics.steps,
            elapsed_s: self.metrics.elapsed_s(),
        })
    }
}
