//! Batch construction: packed documents → token tensors + FlashMask
//! vectors (the L3 half of the paper's data pipeline, appendix A.2.1).
//!
//! Byte-level tokenization over the synthetic corpus: each document is a
//! question plus task-dependent answers; loss is taken on answer bytes
//! (the SFT/DPO/RM convention), with the final padding document excluded.

use crate::mask::FlashMask;
use crate::runtime::HostTensor;
use crate::util::rng::Rng;
use crate::workload::corpus;
use crate::workload::docgen::{self, Task, TrainSample};

/// One training batch in the train-step ABI layout.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub n: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub lts: Vec<i32>,
    pub lte: Vec<i32>,
    pub uts: Vec<i32>,
    pub ute: Vec<i32>,
    /// Mean block sparsity of the samples (for throughput reporting).
    pub sparsity: f64,
    /// Number of loss-bearing tokens.
    pub loss_tokens: usize,
}

impl Batch {
    /// The 7 batch tensors in ABI order (tokens, targets, loss_mask,
    /// lts, lte, uts, ute).
    pub fn to_tensors(&self) -> Vec<HostTensor> {
        let shape = vec![self.batch, self.n];
        vec![
            HostTensor::I32 { shape: shape.clone(), data: self.tokens.clone() },
            HostTensor::I32 { shape: shape.clone(), data: self.targets.clone() },
            HostTensor::F32 { shape: shape.clone(), data: self.loss_mask.clone() },
            HostTensor::I32 { shape: shape.clone(), data: self.lts.clone() },
            HostTensor::I32 { shape: shape.clone(), data: self.lte.clone() },
            HostTensor::I32 { shape: shape.clone(), data: self.uts.clone() },
            HostTensor::I32 { shape, data: self.ute.clone() },
        ]
    }
}

/// Streams batches of packed samples.
pub struct Batcher {
    pub n: usize,
    pub batch: usize,
    pub task: Task,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, task: Task, seed: u64) -> Batcher {
        Batcher { n, batch, task, rng: Rng::new(seed) }
    }

    /// Fill one sample's tokens/targets/loss-mask from the corpus.
    fn fill_sample(&mut self, s: &TrainSample, tokens: &mut [i32], targets: &mut [i32], lm: &mut [f32]) {
        let n = self.n;
        for doc in &s.docs {
            let mut rng = self.rng.fork(doc.start as u64);
            let (q, answers) = corpus::qa_doc_bytes(doc.question_len, &doc.answer_lens, &mut rng);
            let mut pos = doc.start;
            for &b in &q {
                tokens[pos] = b as i32;
                pos += 1;
            }
            for a in &answers {
                for &b in a {
                    tokens[pos] = b as i32;
                    pos += 1;
                }
            }
            let end = doc.start + doc.len();
            // next-byte targets within the document; final byte wraps to
            // a space (never weighted)
            for i in doc.start..end {
                targets[i] = if i + 1 < end { tokens[i + 1] } else { b' ' as i32 };
            }
            if !doc.is_padding {
                // loss on answer bytes only (minus each answer's last)
                let mut a_start = doc.start + doc.question_len;
                for &al in &doc.answer_lens {
                    for i in a_start..(a_start + al).saturating_sub(1).min(n) {
                        lm[i] = 1.0;
                    }
                    a_start += al;
                }
            }
        }
    }

    pub fn next_batch(&mut self) -> Batch {
        let (b, n) = (self.batch, self.n);
        let mut out = Batch {
            batch: b,
            n,
            tokens: vec![b' ' as i32; b * n],
            targets: vec![b' ' as i32; b * n],
            loss_mask: vec![0.0; b * n],
            lts: vec![0; b * n],
            lte: vec![0; b * n],
            uts: vec![0; b * n],
            ute: vec![0; b * n],
            sparsity: 0.0,
            loss_tokens: 0,
        };
        for bi in 0..b {
            let mut rng = self.rng.fork(0xBA7C + bi as u64);
            let sample = docgen::gen_sample(n, self.task, &mut rng);
            let r = bi * n..(bi + 1) * n;
            self.fill_sample(
                &sample,
                &mut out.tokens[r.clone()],
                &mut out.targets[r.clone()],
                &mut out.loss_mask[r.clone()],
            );
            copy_mask(&sample.mask, bi, n, &mut out);
            out.sparsity += sample.sparsity / b as f64;
        }
        out.loss_tokens = out.loss_mask.iter().filter(|&&x| x > 0.0).count();
        // ensure at least some signal (degenerate layouts can zero out)
        if out.loss_tokens == 0 {
            for bi in 0..b {
                out.loss_mask[bi * n + n / 2] = 1.0;
            }
            out.loss_tokens = b;
        }
        out
    }
}

fn copy_mask(m: &FlashMask, bi: usize, n: usize, out: &mut Batch) {
    let r = bi * n..(bi + 1) * n;
    out.lts[r.clone()].copy_from_slice(&m.lts);
    out.lte[r.clone()].copy_from_slice(&m.lte);
    out.uts[r.clone()].copy_from_slice(&m.uts);
    out.ute[r].copy_from_slice(&m.ute);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut b = Batcher::new(512, 3, Task::Sft, 1);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 3 * 512);
        assert!(batch.tokens.iter().all(|&t| (0..256).contains(&t)));
        assert!(batch.targets.iter().all(|&t| (0..256).contains(&t)));
        assert!(batch.loss_tokens > 0);
        assert!(batch.loss_mask.iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn mask_vectors_valid_per_sample() {
        let mut b = Batcher::new(256, 2, Task::Dpo, 2);
        let batch = b.next_batch();
        for bi in 0..2 {
            let r = bi * 256..(bi + 1) * 256;
            let m = FlashMask {
                lts: batch.lts[r.clone()].to_vec(),
                lte: batch.lte[r.clone()].to_vec(),
                uts: batch.uts[r.clone()].to_vec(),
                ute: batch.ute[r].to_vec(),
                causal: true,
            };
            m.validate().unwrap();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Batcher::new(256, 2, Task::Sft, 7).next_batch();
        let b = Batcher::new(256, 2, Task::Sft, 7).next_batch();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.lts, b.lts);
        assert_eq!(a.loss_mask, b.loss_mask);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Batcher::new(256, 2, Task::Sft, 7).next_batch();
        let b = Batcher::new(256, 2, Task::Sft, 8).next_batch();
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn targets_are_next_token_inside_docs() {
        let mut b = Batcher::new(256, 1, Task::Sft, 3);
        let batch = b.next_batch();
        // at least 90% of positions should satisfy target[i] == token[i+1]
        let mut hits = 0;
        for i in 0..255 {
            if batch.targets[i] == batch.tokens[i + 1] {
                hits += 1;
            }
        }
        assert!(hits > 230, "hits={hits}");
    }

    #[test]
    fn tensor_conversion_order() {
        let mut b = Batcher::new(128, 1, Task::Rm, 4);
        let batch = b.next_batch();
        let t = batch.to_tensors();
        assert_eq!(t.len(), 7);
        assert_eq!(t[0].shape(), &[1, 128]);
        assert!(matches!(t[2], HostTensor::F32 { .. }));
    }
}
