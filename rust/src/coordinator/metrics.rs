//! Training metrics: loss tracking, throughput, CSV export.

use std::time::Instant;

/// Rolling training metrics.
pub struct Metrics {
    start: Instant,
    pub steps: usize,
    pub tokens: usize,
    pub losses: Vec<f32>,
    ema: Option<f64>,
    ema_alpha: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            steps: 0,
            tokens: 0,
            losses: Vec::new(),
            ema: None,
            ema_alpha: 0.1,
        }
    }

    pub fn record(&mut self, loss: f32, tokens: usize) {
        self.steps += 1;
        self.tokens += tokens;
        self.losses.push(loss);
        let l = loss as f64;
        self.ema = Some(match self.ema {
            None => l,
            Some(e) => e + self.ema_alpha * (l - e),
        });
    }

    pub fn ema_loss(&self) -> f64 {
        self.ema.unwrap_or(f64::NAN)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.losses.last().copied()
    }

    /// CSV: `step,loss` per line, with header.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss\n");
        for (i, l) in self.losses.iter().enumerate() {
            out.push_str(&format!("{},{}\n", i + 1, l));
        }
        out
    }

    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_ema() {
        let mut m = Metrics::new();
        m.record(4.0, 100);
        m.record(2.0, 100);
        assert_eq!(m.steps, 2);
        assert_eq!(m.tokens, 200);
        let ema = m.ema_loss();
        assert!(ema < 4.0 && ema > 2.0);
        assert_eq!(m.last_loss(), Some(2.0));
    }

    #[test]
    fn csv_format() {
        let mut m = Metrics::new();
        m.record(1.5, 10);
        m.record(1.25, 10);
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,loss");
        assert_eq!(lines[1], "1,1.5");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn throughput_positive() {
        let mut m = Metrics::new();
        m.record(1.0, 1000);
        assert!(m.tokens_per_s() > 0.0);
    }
}
