//! Training metrics: loss tracking, throughput, CSV export.
//!
//! Step timing is routed through a telemetry [`Histogram`] (local to
//! the run for report percentiles, mirrored into the global registry
//! as `train.step_ms`) rather than a bare `Instant` subtraction, so
//! `flashmask metrics` surfaces training latency alongside the
//! kernel/decode/serve metrics (DESIGN.md §Telemetry).

use crate::telemetry::Histogram;
use std::sync::Arc;
use std::time::Instant;

/// Rolling training metrics.
pub struct Metrics {
    /// Instant of the previous `record()` (or construction) — the delta
    /// to the next `record()` is one step-time histogram sample.
    last: Instant,
    /// Wall time accumulated across recorded steps, in seconds; 0 on
    /// the empty state (the old `start.elapsed()` kept ticking while
    /// idle, skewing throughput).
    elapsed: f64,
    pub steps: usize,
    pub tokens: usize,
    pub losses: Vec<f32>,
    ema: Option<f64>,
    ema_alpha: f64,
    /// This run's step-time distribution (for `step_p50_ms()` etc.).
    step_hist: Histogram,
    /// Global-registry mirror, resolved once at construction.
    g_step: Arc<Histogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            last: Instant::now(),
            elapsed: 0.0,
            steps: 0,
            tokens: 0,
            losses: Vec::new(),
            ema: None,
            ema_alpha: 0.1,
            step_hist: Histogram::new(),
            g_step: crate::telemetry::metrics::global().histogram(crate::telemetry::names::TRAIN_STEP_MS),
        }
    }

    pub fn record(&mut self, loss: f32, tokens: usize) {
        let now = Instant::now();
        let step_ms = (now - self.last).as_secs_f64() * 1e3;
        self.last = now;
        self.elapsed += step_ms / 1e3;
        self.step_hist.record_ms(step_ms);
        self.g_step.record_ms(step_ms);
        let reg = crate::telemetry::metrics::global();
        reg.add(crate::telemetry::names::TRAIN_STEPS, 1);
        reg.add(crate::telemetry::names::TRAIN_TOKENS, tokens as u64);
        self.steps += 1;
        self.tokens += tokens;
        self.losses.push(loss);
        let l = loss as f64;
        self.ema = Some(match self.ema {
            None => l,
            Some(e) => e + self.ema_alpha * (l - e),
        });
    }

    /// Exponential moving average of the loss; 0 before any step (the
    /// old behaviour returned NaN, which poisoned downstream reports).
    pub fn ema_loss(&self) -> f64 {
        self.ema.unwrap_or(0.0)
    }

    /// Wall time attributed to recorded steps; 0 on the empty state.
    pub fn elapsed_s(&self) -> f64 {
        self.elapsed
    }

    /// Token throughput over recorded steps; 0 before any step instead
    /// of a near-zero-division artifact.
    pub fn tokens_per_s(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        self.tokens as f64 / self.elapsed.max(1e-9)
    }

    /// Step-time percentile in ms from the telemetry histogram.
    pub fn step_quantile_ms(&self, q: f64) -> f64 {
        self.step_hist.quantile_ms(q)
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.losses.last().copied()
    }

    /// CSV: `step,loss` per line, with header.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss\n");
        for (i, l) in self.losses.iter().enumerate() {
            out.push_str(&format!("{},{}\n", i + 1, l));
        }
        out
    }

    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_ema() {
        let mut m = Metrics::new();
        m.record(4.0, 100);
        m.record(2.0, 100);
        assert_eq!(m.steps, 2);
        assert_eq!(m.tokens, 200);
        let ema = m.ema_loss();
        assert!(ema < 4.0 && ema > 2.0);
        assert_eq!(m.last_loss(), Some(2.0));
    }

    #[test]
    fn empty_state_is_safe() {
        // satellite: no NaN / divide-by-near-zero before the first step
        let m = Metrics::default();
        assert_eq!(m.steps, 0);
        assert_eq!(m.ema_loss(), 0.0);
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.elapsed_s(), 0.0);
        assert_eq!(m.step_quantile_ms(0.5), 0.0);
        assert_eq!(m.last_loss(), None);
    }

    #[test]
    fn step_timing_feeds_histogram() {
        let mut m = Metrics::new();
        m.record(1.0, 10);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.record(0.9, 10);
        assert_eq!(m.step_hist.count(), 2);
        // the sleep makes the max bucket at least ~2ms; quantile(1.0)
        // returns the bucket upper bound, so it must be >= the sample
        assert!(m.step_quantile_ms(1.0) >= 2.0);
        assert!(m.elapsed_s() > 0.0);
    }

    #[test]
    fn csv_format() {
        let mut m = Metrics::new();
        m.record(1.5, 10);
        m.record(1.25, 10);
        let csv = m.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,loss");
        assert_eq!(lines[1], "1,1.5");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn throughput_positive() {
        let mut m = Metrics::new();
        std::thread::sleep(std::time::Duration::from_millis(1));
        m.record(1.0, 1000);
        assert!(m.tokens_per_s() > 0.0);
    }
}
