//! Checkpointing: save/restore trainer state (params + AdamW moments)
//! to a simple self-describing binary format, so long runs survive
//! restarts without any python involvement.
//!
//! Format (little-endian):
//! ```text
//! magic "FMCK" | version u32 | step u32 | n_leaves u32
//! per leaf: ndim u32 | dims u32* | len u32 | f32 data*
//! repeated 3x (params, m, v)
//! ```

use crate::runtime::HostTensor;
use anyhow::{bail, ensure, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"FMCK";
const VERSION: u32 = 1;

pub struct Checkpoint {
    pub step: u32,
    pub params: Vec<HostTensor>,
    pub opt_m: Vec<HostTensor>,
    pub opt_v: Vec<HostTensor>,
}

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_tensor(w: &mut impl Write, t: &HostTensor) -> Result<()> {
    let (shape, data) = match t {
        HostTensor::F32 { shape, data } => (shape, data),
        _ => bail!("checkpoint supports f32 tensors only"),
    };
    write_u32(w, shape.len() as u32)?;
    for &d in shape {
        write_u32(w, d as u32)?;
    }
    write_u32(w, data.len() as u32)?;
    // SAFETY: `data` is a live `&[f32]`, so `data.as_ptr()` is valid for
    // `data.len() * 4` bytes for the borrow's lifetime, `u8` has no
    // alignment requirement, and every byte of an f32 is initialized
    // plain-old-data (no padding, no invalid bit patterns for u8).  The
    // byte slice borrows `data` immutably and is consumed before the
    // borrow ends.  This is the crate's sole allowed unsafe site (see
    // the `unsafe-hygiene` lint pass allowlist).
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    w.write_all(bytes)?;
    Ok(())
}

fn read_tensor(r: &mut impl Read) -> Result<HostTensor> {
    let ndim = read_u32(r)? as usize;
    ensure!(ndim <= 8, "implausible ndim {ndim}");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(read_u32(r)? as usize);
    }
    let len = read_u32(r)? as usize;
    ensure!(len == shape.iter().product::<usize>().max(1) || shape.is_empty(), "len/shape mismatch");
    let mut bytes = vec![0u8; len * 4];
    r.read_exact(&mut bytes)?;
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(HostTensor::F32 { shape, data })
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        write_u32(&mut w, VERSION)?;
        write_u32(&mut w, self.step)?;
        write_u32(&mut w, self.params.len() as u32)?;
        for group in [&self.params, &self.opt_m, &self.opt_v] {
            ensure!(group.len() == self.params.len(), "group size mismatch");
            for t in group {
                write_tensor(&mut w, t)?;
            }
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        ensure!(&magic == MAGIC, "bad checkpoint magic");
        let version = read_u32(&mut r)?;
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let step = read_u32(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        ensure!(n > 0 && n < 100_000, "implausible leaf count {n}");
        let mut groups = Vec::with_capacity(3);
        for _ in 0..3 {
            let mut g = Vec::with_capacity(n);
            for _ in 0..n {
                g.push(read_tensor(&mut r)?);
            }
            groups.push(g);
        }
        let opt_v = groups.pop().unwrap();
        let opt_m = groups.pop().unwrap();
        let params = groups.pop().unwrap();
        Ok(Checkpoint { step, params, opt_m, opt_v })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensors() -> Vec<HostTensor> {
        vec![
            HostTensor::F32 { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] },
            HostTensor::F32 { shape: vec![4], data: vec![-1.5, 0.0, f32::MIN_POSITIVE, 9.9] },
        ]
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.fmck");
        let ck = Checkpoint { step: 42, params: tensors(), opt_m: tensors(), opt_v: tensors() };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params.len(), 2);
        for (a, b) in ck.params.iter().zip(&back.params) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("fm_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.fmck");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_int_tensors() {
        let ck = Checkpoint {
            step: 0,
            params: vec![HostTensor::I32 { shape: vec![1], data: vec![1] }],
            opt_m: vec![],
            opt_v: vec![],
        };
        let path = std::env::temp_dir().join("fm_ckpt_int.fmck");
        assert!(ck.save(&path).is_err());
    }
}
