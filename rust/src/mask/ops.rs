//! Operations on FlashMask representations.
//!
//! The paper's §3 notes the column-wise interval idea generalizes under
//! transposition (row-wise intervals) and composition; these ops make
//! that concrete and are used by the serving layer to manipulate masks
//! without ever materializing O(N²) state:
//!
//! * [`transpose`] — swap query/key roles (the backward pass of a
//!   causal mask is an "anti-causal" mask).
//! * [`intersect`] — visibility AND (compose two mask constraints);
//!   exact when representable, conservative-error otherwise.
//! * [`shift_append`] — extend a mask for `extra` freshly appended
//!   tokens under causal semantics (incremental prefill).

use super::flashmask::FlashMask;
use anyhow::Result;

/// Transpose the visibility relation: `allowedᵀ[i, j] = allowed[j, i]`.
///
/// Column intervals become row intervals; re-derived via `from_dense`
/// on the transposed oracle (O(N²) — build-time tool, not hot path).
pub fn transpose(m: &FlashMask) -> Result<FlashMask> {
    let n = m.n();
    let src = m.dense_allowed();
    let mut t = vec![false; n * n];
    for i in 0..n {
        for j in 0..n {
            t[j * n + i] = src[i * n + j];
        }
    }
    FlashMask::from_dense(&t, n, false)
}

/// Intersect visibility: a token pair is visible only if visible under
/// both masks.  Fails if the result is not column-interval representable.
pub fn intersect(a: &FlashMask, b: &FlashMask) -> Result<FlashMask> {
    assert_eq!(a.n(), b.n());
    let n = a.n();
    let da = a.dense_allowed();
    let db = b.dense_allowed();
    let both: Vec<bool> = da.iter().zip(&db).map(|(x, y)| *x && *y).collect();
    FlashMask::from_dense(&both, n, a.causal && b.causal)
}

/// Extend a causal mask by `extra` appended tokens: new columns are
/// causal-plain (visible to all later rows), existing columns' lower
/// intervals that previously ended at old `n` now end at the new `n`.
pub fn shift_append(m: &FlashMask, extra: usize) -> FlashMask {
    assert!(m.causal, "shift_append requires a causal mask");
    let old_n = m.n() as i32;
    let new_n = old_n + extra as i32;
    let grow = |v: &[i32], fill: i32| -> Vec<i32> {
        let mut out: Vec<i32> =
            v.iter().map(|&x| if x == old_n { new_n } else { x }).collect();
        out.extend(std::iter::repeat(fill).take(extra));
        out
    };
    let out = FlashMask {
        lts: grow(&m.lts, new_n),
        lte: grow(&m.lte, new_n),
        uts: grow(&m.uts, new_n),
        ute: grow(&m.ute, new_n),
        causal: true,
    };
    // an old interval [s, old_n) means "masked forever": keep new_n end.
    // an old empty interval [old_n, old_n) became [new_n, new_n): still
    // empty.  nothing else changes.
    debug_assert!(out.validate().is_ok());
    out.validate().expect("shift_append produced invalid mask");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;

    #[test]
    fn transpose_involution() {
        let m = builders::causal_document(24, &[10, 8, 6]);
        let t = transpose(&m).unwrap();
        let tt = transpose(&t).unwrap();
        assert_eq!(tt.dense_allowed(), m.dense_allowed());
    }

    #[test]
    fn transpose_semantics() {
        let m = builders::causal(8);
        let t = transpose(&m).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(t.allowed(i, j), m.allowed(j, i));
            }
        }
    }

    #[test]
    fn intersect_document_with_sliding_window() {
        // packed docs AND a local window: the "document sliding window"
        // pattern long-context training uses
        let n = 32;
        let a = builders::causal_document(n, &[16, 16]);
        let b = builders::sliding_window(n, 4);
        let c = intersect(&a, &b).unwrap();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(c.allowed(i, j), a.allowed(i, j) && b.allowed(i, j));
            }
        }
        assert!(c.block_sparsity(8, 8) >= a.block_sparsity(8, 8));
    }

    #[test]
    fn intersect_unrepresentable_fails_loudly() {
        // window AND "not-window" leaves two disjoint intervals per column
        let n = 32;
        let a = builders::sliding_window(n, 4);
        // eviction mask cutting holes mid-window can produce two lower
        // intervals; construct one directly
        let mut b = builders::causal(n);
        b.lts[0] = 2;
        b.lte[0] = 3; // hole at rows [2,3) of column 0
        b.validate().unwrap();
        let c = intersect(&a, &b);
        // column 0 masked rows: [2,3) ∪ [4,n) — two intervals => error
        assert!(c.is_err());
    }

    #[test]
    fn shift_append_grows_causal_doc() {
        let m = builders::causal_document(16, &[8, 8]);
        let g = shift_append(&m, 8);
        assert_eq!(g.n(), 24);
        g.validate().unwrap();
        // old cross-doc invisibility preserved
        assert!(!g.allowed(12, 3));
        // old doc columns stay masked for the new rows too
        assert!(!g.allowed(20, 3));
        // new columns behave causally
        assert!(g.allowed(20, 18));
        assert!(!g.allowed(18, 20));
    }

    #[test]
    fn shift_append_zero_is_identity() {
        let m = builders::causal_document(16, &[10, 6]);
        let g = shift_append(&m, 0);
        assert_eq!(g.dense_allowed(), m.dense_allowed());
    }
}
