//! The paper's core contribution: column-wise sparse attention masks.
//!
//! * [`flashmask`] — the `(LTS, LTE, UTS, UTE)` representation (paper
//!   §4.1), dense-oracle materialization, and reconstruction from dense
//!   masks (with representability checking).
//! * [`builders`] — one constructor per mask family in paper Fig. 1(a).
//! * [`block`] — per-tile min/max precompute (Alg. 1 line 4) and the
//!   three-way tile classification of Eq. 4.
//! * [`incremental`] — decode-time view: the same Eq. 4 classifier at
//!   KV-cache-page granularity, one query row at a time.
//! * [`tree`] — DFS-preorder token trees for speculative decoding;
//!   [`builders::tree_mask`] turns them into LTS/LTE column intervals.
//! * [`types`] — mask-kind enumeration shared by workloads and benches.

pub mod block;
pub mod builders;
pub mod flashmask;
pub mod incremental;
pub mod ops;
pub mod tree;
pub mod types;

pub use block::{BlockClass, BlockTable};
pub use flashmask::FlashMask;
pub use incremental::IncrementalMaskView;
pub use tree::TokenTree;
pub use types::MaskKind;
