//! The FlashMask column-wise sparse representation (paper §4.1).
//!
//! For key column `j` the masked query rows are
//! `[lts[j], lte[j]) ∪ [uts[j], ute[j])` — one interval in the lower-left
//! triangle, one in the upper-right.  `causal` masks leave the UT pair
//! empty (`== n`) because the whole upper triangle is implicit.
//!
//! Memory is `O(N)` (four `i32` vectors) versus the dense mask's
//! `O(N^2)` — the property behind the paper's Fig. 4(b) and Table 2.

use anyhow::{bail, ensure, Result};

/// Column-wise sparse attention mask over an `n x n` score matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct FlashMask {
    pub lts: Vec<i32>,
    pub lte: Vec<i32>,
    pub uts: Vec<i32>,
    pub ute: Vec<i32>,
    pub causal: bool,
}

impl FlashMask {
    /// A mask with no masked intervals (causal => plain causal mask).
    pub fn empty(n: usize, causal: bool) -> FlashMask {
        let e = vec![n as i32; n];
        FlashMask { lts: e.clone(), lte: e.clone(), uts: e.clone(), ute: e, causal }
    }

    pub fn n(&self) -> usize {
        self.lts.len()
    }

    /// Structural validation (interval ordering, bounds, causal
    /// convention).  All builders return validated masks; call this when
    /// ingesting masks from outside (e.g. a request payload).
    pub fn validate(&self) -> Result<()> {
        FlashMask::validate_parts(&self.lts, &self.lte, &self.uts, &self.ute, self.causal)
    }

    /// [`validate`](Self::validate) over borrowed interval slices — the
    /// allocation-free variant for hot paths that hold mask vectors in
    /// batched/flattened form (e.g. the trainer's per-sample batch
    /// rows) and should not clone them just to validate.
    pub fn validate_parts(
        lts: &[i32],
        lte: &[i32],
        uts: &[i32],
        ute: &[i32],
        causal: bool,
    ) -> Result<()> {
        let n = lts.len() as i32;
        ensure!(
            lte.len() == lts.len() && uts.len() == lts.len() && ute.len() == lts.len(),
            "vector length mismatch"
        );
        for j in 0..lts.len() {
            for (name, v) in [("lts", lts[j]), ("lte", lte[j]), ("uts", uts[j]), ("ute", ute[j])]
            {
                ensure!((0..=n).contains(&v), "{name}[{j}] = {v} out of [0, {n}]");
            }
            ensure!(lts[j] <= lte[j], "lower interval inverted at {j}");
            ensure!(uts[j] <= ute[j], "upper interval inverted at {j}");
            if causal {
                ensure!(
                    uts[j] == n && ute[j] == n,
                    "causal mask with non-empty UT interval at {j}"
                );
            }
        }
        Ok(())
    }

    /// Is query row `i` allowed to attend to key column `j`?
    #[inline]
    pub fn allowed(&self, i: usize, j: usize) -> bool {
        if self.causal && i < j {
            return false;
        }
        let i = i as i32;
        let lower = i >= self.lts[j] && i < self.lte[j];
        let upper = i >= self.uts[j] && i < self.ute[j];
        !(lower || upper)
    }

    /// Dense boolean visibility matrix (row-major `n*n`).  O(N^2) — test
    /// oracle and baseline input only, never on a hot path.
    pub fn dense_allowed(&self) -> Vec<bool> {
        let n = self.n();
        let mut out = vec![false; n * n];
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = self.allowed(i, j);
            }
        }
        out
    }

    /// Dense additive bias: `0.0` where allowed, `-inf` where masked.
    pub fn dense_bias(&self) -> Vec<f32> {
        self.dense_allowed()
            .into_iter()
            .map(|a| if a { 0.0 } else { f32::NEG_INFINITY })
            .collect()
    }

    /// Reconstruct a FlashMask from a dense visibility matrix.
    ///
    /// Fails when any column's masked rows do not form one contiguous
    /// interval per triangle — exactly the representability limit the
    /// paper's §6 discusses (e.g. fully random masks).
    pub fn from_dense(allowed: &[bool], n: usize, causal: bool) -> Result<FlashMask> {
        ensure!(allowed.len() == n * n, "dense mask size mismatch");
        let mut m = FlashMask::empty(n, causal);
        for j in 0..n {
            // lower triangle: rows j..n (row >= col)
            let lower: Vec<usize> =
                (j..n).filter(|&i| !allowed[i * n + j]).collect();
            if let Some((s, e)) = contiguous(&lower)? {
                m.lts[j] = s as i32;
                m.lte[j] = e as i32;
            }
            // upper triangle: rows 0..j (row < col)
            let upper: Vec<usize> =
                (0..j).filter(|&i| !allowed[i * n + j]).collect();
            if causal {
                // implicit; any visible upper element is unrepresentable
                if upper.len() != j {
                    bail!("column {j}: upper triangle visible under causal=true");
                }
            } else if let Some((s, e)) = contiguous(&upper)? {
                m.uts[j] = s as i32;
                m.ute[j] = e as i32;
            }
        }
        m.validate()?;
        // verify roundtrip (catches diag corner cases)
        let back = m.dense_allowed();
        ensure!(back == allowed, "reconstruction mismatch (mask not column-interval representable)");
        Ok(m)
    }

    /// Fraction of fully-masked `br x bc` tiles (paper §4.3's ρ),
    /// computed from the interval representation in `O(N)` per tile row —
    /// no dense materialization.
    pub fn block_sparsity(&self, br: usize, bc: usize) -> f64 {
        let table = super::block::BlockTable::build(self, bc);
        let n = self.n();
        let tr = n.div_ceil(br);
        let tc = n.div_ceil(bc);
        let mut fully = 0usize;
        for bi in 0..tr {
            for bj in 0..tc {
                if table.classify(self, bi, br, bj, bc) == super::block::BlockClass::FullyMasked {
                    fully += 1;
                }
            }
        }
        fully as f64 / (tr * tc) as f64
    }

    /// Memory footprint of this representation in bytes (4 i32 vectors).
    pub fn repr_bytes(&self) -> usize {
        4 * self.n() * std::mem::size_of::<i32>()
    }

    /// Memory a dense bf16 mask of the same shape would need.
    pub fn dense_bytes(&self) -> usize {
        self.n() * self.n() * 2
    }
}

fn contiguous(rows: &[usize]) -> Result<Option<(usize, usize)>> {
    if rows.is_empty() {
        return Ok(None);
    }
    let (first, last) = (rows[0], rows[rows.len() - 1]);
    ensure!(
        last - first + 1 == rows.len(),
        "masked rows not contiguous (start {first}, end {last}, count {})",
        rows.len()
    );
    Ok(Some((first, last + 1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_causal_is_triangular() {
        let m = FlashMask::empty(4, true);
        m.validate().unwrap();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m.allowed(i, j), i >= j);
            }
        }
    }

    #[test]
    fn empty_bidirectional_allows_all() {
        let m = FlashMask::empty(4, false);
        assert!(m.dense_allowed().iter().all(|&a| a));
    }

    #[test]
    fn intervals_mask_rows() {
        let mut m = FlashMask::empty(6, true);
        m.lts[1] = 3;
        m.lte[1] = 5; // rows 3,4 cannot see column 1
        m.validate().unwrap();
        assert!(m.allowed(2, 1));
        assert!(!m.allowed(3, 1));
        assert!(!m.allowed(4, 1));
        assert!(m.allowed(5, 1));
    }

    #[test]
    fn validate_rejects_inverted() {
        let mut m = FlashMask::empty(4, true);
        m.lts[0] = 3;
        m.lte[0] = 1;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_causal_with_ut() {
        let mut m = FlashMask::empty(4, true);
        m.uts[2] = 0;
        m.ute[2] = 1;
        assert!(m.validate().is_err());
    }

    #[test]
    fn from_dense_roundtrip_causal_doc() {
        let m = super::super::builders::causal_document(12, &[5, 4, 3]);
        let dense = m.dense_allowed();
        let back = FlashMask::from_dense(&dense, 12, true).unwrap();
        assert_eq!(back.dense_allowed(), dense);
    }

    #[test]
    fn from_dense_roundtrip_bidirectional() {
        let m = super::super::builders::document(12, &[7, 5]);
        let dense = m.dense_allowed();
        let back = FlashMask::from_dense(&dense, 12, false).unwrap();
        assert_eq!(back.dense_allowed(), dense);
    }

    #[test]
    fn from_dense_rejects_random_mask() {
        // checkerboard column — not one interval per triangle
        let n = 8;
        let mut allowed = vec![true; n * n];
        for i in (0..n).step_by(2) {
            allowed[i * n + 3] = false;
        }
        assert!(FlashMask::from_dense(&allowed, n, false).is_err());
    }

    #[test]
    fn memory_footprint_linear_vs_quadratic() {
        let m = FlashMask::empty(4096, true);
        assert_eq!(m.repr_bytes(), 4 * 4096 * 4);
        assert_eq!(m.dense_bytes(), 4096 * 4096 * 2);
        assert!(m.repr_bytes() * 100 < m.dense_bytes());
    }

    #[test]
    fn block_sparsity_causal_half() {
        let m = FlashMask::empty(256, true);
        let rho = m.block_sparsity(32, 32);
        // strictly-above-diagonal tiles: (t*(t-1)/2) / t^2 with t=8
        assert!((rho - 28.0 / 64.0).abs() < 1e-9, "rho={rho}");
    }
}
