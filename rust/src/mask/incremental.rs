//! Incremental (decode-time) view of a FlashMask.
//!
//! During autoregressive decode the only live question is: *which
//! cached KV columns does the current row `t` attend to?*  The
//! column-wise interval representation (§4.1) answers it in O(1) per
//! column — `t ∈ [lts[j], lte[j]) ∪ [uts[j], ute[j])` — and the Eq. 4
//! min/max classifier answers it in O(1) per *page* of columns: a
//! cache page is a 1×page_size tile of the score matrix, so the same
//! [`BlockTable`] machinery classifies it as fully-visible /
//! partially-visible / skipped without materializing anything.
//!
//! This is what lets sliding-window, packed-document and KV-eviction
//! masks skip whole cache pages at decode time (the Binary Block
//! Masking observation applied to the KV cache, PAPERS.md).

use super::block::{BlockClass, BlockTable};
use super::flashmask::FlashMask;

/// Page-granular decode view: a [`BlockTable`] built with the cache
/// page size as the key-block size, queried one query row at a time.
#[derive(Clone, Debug)]
pub struct IncrementalMaskView {
    page_size: usize,
    table: BlockTable,
}

impl IncrementalMaskView {
    pub fn new(mask: &FlashMask, page_size: usize) -> IncrementalMaskView {
        assert!(page_size >= 1);
        IncrementalMaskView { page_size, table: BlockTable::build(mask, page_size) }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages covering the full sequence length.
    pub fn n_pages(&self) -> usize {
        self.table.tc()
    }

    /// Classify cache page `page` for decode row `t`: a 1-row tile of
    /// the score matrix (Eq. 4 with Br = 1).
    pub fn classify_page(&self, mask: &FlashMask, t: usize, page: usize) -> BlockClass {
        self.table.classify(mask, t, 1, page, self.page_size)
    }

    /// Is column `j` visible to decode row `t`?  O(1), same interval
    /// test the prefill kernel applies element-wise.
    pub fn visible(&self, mask: &FlashMask, t: usize, j: usize) -> bool {
        mask.allowed(t, j)
    }

    /// Page visit range `[lo, hi)` for decode row `t` over the first
    /// `n_pages` cached pages: every page outside the range is
    /// FullyMasked (verified — the boundary scan classifies them), so
    /// the kernel's page loop can run `lo..hi` and bulk-account the
    /// rest without touching them.  Pages *inside* the range still
    /// need per-page classification (non-contiguous masks can have
    /// interior holes).  Under an implicit-causal mask the upper bound
    /// starts at the diagonal page in O(1); the remaining boundary
    /// scans cost one classification per *excluded* page — work the
    /// dense loop paid anyway, moved out of the hot loop.
    pub fn visit_range(&self, mask: &FlashMask, t: usize, n_pages: usize) -> (usize, usize) {
        let np = n_pages.min(self.n_pages());
        let mut hi = if mask.causal { np.min(t / self.page_size + 1) } else { np };
        let mut lo = 0;
        while lo < hi && self.classify_page(mask, t, lo) == BlockClass::FullyMasked {
            lo += 1;
        }
        while hi > lo && self.classify_page(mask, t, hi - 1) == BlockClass::FullyMasked {
            hi -= 1;
        }
        (lo, hi)
    }

    /// Page census for row `t` over `n_pages` cached pages:
    /// `(skipped, partial, unmasked)`.
    pub fn row_census(&self, mask: &FlashMask, t: usize, n_pages: usize) -> (usize, usize, usize) {
        let (mut f, mut p, mut u) = (0, 0, 0);
        for page in 0..n_pages.min(self.n_pages()) {
            match self.classify_page(mask, t, page) {
                BlockClass::FullyMasked => f += 1,
                BlockClass::PartiallyMasked => p += 1,
                BlockClass::Unmasked => u += 1,
            }
        }
        (f, p, u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::types::MaskKind;
    use crate::mask::{builders, ops};
    use crate::util::prop;

    /// Dense-oracle page class for one decode row.
    fn oracle_class(mask: &FlashMask, t: usize, page: usize, ps: usize) -> BlockClass {
        let n = mask.n();
        let dense = mask.dense_allowed();
        let (mut any_masked, mut any_allowed) = (false, false);
        for j in page * ps..((page + 1) * ps).min(n) {
            if dense[t * n + j] {
                any_allowed = true;
            } else {
                any_masked = true;
            }
        }
        match (any_allowed, any_masked) {
            (false, _) => BlockClass::FullyMasked,
            (true, true) => BlockClass::PartiallyMasked,
            (true, false) => BlockClass::Unmasked,
        }
    }

    /// Soundness contract (same shape as `block::tests::check_sound`):
    /// conservative Partial is fine; skipping a visible column or
    /// declaring a masked column mask-free is not.
    fn check_sound(mask: &FlashMask, t: usize, ps: usize) -> Result<(), String> {
        let view = IncrementalMaskView::new(mask, ps);
        let dense = mask.dense_allowed();
        let n = mask.n();
        for page in 0..view.n_pages() {
            let got = view.classify_page(mask, t, page);
            let want = oracle_class(mask, t, page, ps);
            let ok = match (got, want) {
                (BlockClass::FullyMasked, BlockClass::FullyMasked) => true,
                (BlockClass::FullyMasked, _) => false, // would skip visible KV!
                (BlockClass::Unmasked, BlockClass::Unmasked) => true,
                (BlockClass::Unmasked, _) => false, // would miss a mask!
                (BlockClass::PartiallyMasked, _) => true,
            };
            if !ok {
                return Err(format!("t={t} page {page} (ps {ps}): got {got:?}, want {want:?}"));
            }
        }
        // the element-wise fallback must agree with the dense oracle
        for j in 0..n {
            if view.visible(mask, t, j) != dense[t * n + j] {
                return Err(format!("t={t} col {j}: visible() disagrees with dense"));
            }
        }
        Ok(())
    }

    #[test]
    fn sliding_window_skips_old_pages() {
        let (n, ps, w) = (128, 16, 16);
        let m = builders::sliding_window(n, w);
        let view = IncrementalMaskView::new(&m, ps);
        // at the last row only the window's pages are live
        let (skipped, _, _) = view.row_census(&m, n - 1, view.n_pages());
        assert!(skipped >= 6, "expected most of 8 pages skipped, got {skipped}");
        // at the first row nothing behind us exists to skip... but the
        // causal future pages are skipped
        assert_eq!(view.classify_page(&m, 0, 4), BlockClass::FullyMasked);
    }

    #[test]
    fn eviction_mask_skips_fully_evicted_pages() {
        let n = 64;
        let mut m = builders::causal(n);
        // evict columns 0..16 from row 32 on (a whole 16-column page)
        for j in 0..16 {
            m.lts[j] = 32;
            m.lte[j] = n as i32;
        }
        m.validate().unwrap();
        let view = IncrementalMaskView::new(&m, 16);
        assert_eq!(view.classify_page(&m, 31, 0), BlockClass::Unmasked);
        assert_eq!(view.classify_page(&m, 32, 0), BlockClass::FullyMasked);
        assert_eq!(view.classify_page(&m, 63, 0), BlockClass::FullyMasked);
    }

    #[test]
    fn causal_diagonal_page_is_partial_until_filled() {
        let n = 64;
        let m = builders::causal(n);
        let view = IncrementalMaskView::new(&m, 16);
        // row 20 sits inside page 1: columns 21..32 are future => partial
        assert_eq!(view.classify_page(&m, 20, 1), BlockClass::PartiallyMasked);
        // row 31 is the page's last column: all of page 1 visible
        assert_eq!(view.classify_page(&m, 31, 1), BlockClass::Unmasked);
        // fully-past page and fully-future page
        assert_eq!(view.classify_page(&m, 40, 1), BlockClass::Unmasked);
        assert_eq!(view.classify_page(&m, 10, 1), BlockClass::FullyMasked);
    }

    #[test]
    fn agrees_after_incremental_mask_growth() {
        // the serving path grows masks with ops::shift_append as tokens
        // stream in; the view over the grown mask must stay sound
        let m = builders::causal_document(48, &[24, 24]);
        let g = ops::shift_append(&m, 16);
        for t in [0, 23, 24, 47, 48, 63] {
            check_sound(&g, t, 16).unwrap();
        }
    }

    #[test]
    fn visit_range_bounds_live_pages() {
        let (n, ps, w) = (128, 16, 16);
        let m = builders::sliding_window(n, w);
        let view = IncrementalMaskView::new(&m, ps);
        // last row: only the diagonal page is live (window 16 == page)
        let (lo, hi) = view.visit_range(&m, n - 1, view.n_pages());
        assert_eq!((lo, hi), (7, 8));
        // first row: page 0 only (causal future bounded in O(1))
        assert_eq!(view.visit_range(&m, 0, view.n_pages()), (0, 1));
        // a row masked by page-aligned eviction yields an empty range
        // (conservative Partial boundary pages stay in range for
        // non-aligned masks — the kernel element-masks those)
        let mut ev = builders::causal(32);
        for j in 0..32 {
            ev.lts[j] = (j as i32 / 8) * 8; // evicted from its page start
            ev.lte[j] = 32;
        }
        ev.validate().unwrap();
        let evv = IncrementalMaskView::new(&ev, 8);
        let (lo, hi) = evv.visit_range(&ev, 20, evv.n_pages());
        assert!(lo >= hi, "masked row must produce an empty range, got [{lo},{hi})");
    }

    #[test]
    fn prop_visit_range_sound_all_benchmark_kinds() {
        // pages outside [lo, hi) are FullyMasked; non-empty ranges end
        // on live pages (tight bounds); every live page is inside
        prop::check(
            "visit-range-sound",
            prop::PropConfig { cases: 24, base_seed: 0xBEEF },
            |rng| {
                let n = 128;
                let t = rng.range(0, n as i64) as usize;
                let ps = *rng.choose(&[8usize, 16, 32]);
                for kind in MaskKind::BENCHMARK {
                    let mask = builders::build(kind, n, rng);
                    let view = IncrementalMaskView::new(&mask, ps);
                    let np = view.n_pages();
                    let (lo, hi) = view.visit_range(&mask, t, np);
                    for page in 0..np {
                        let class = view.classify_page(&mask, t, page);
                        if (page < lo || page >= hi) && class != BlockClass::FullyMasked {
                            return Err(format!(
                                "{kind}: t={t} ps={ps} page {page} live but outside [{lo},{hi})"
                            ));
                        }
                    }
                    if lo < hi {
                        if view.classify_page(&mask, t, lo) == BlockClass::FullyMasked {
                            return Err(format!("{kind}: t={t} lo {lo} not live"));
                        }
                        if view.classify_page(&mask, t, hi - 1) == BlockClass::FullyMasked {
                            return Err(format!("{kind}: t={t} hi-1 {} not live", hi - 1));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_view_sound_all_benchmark_kinds() {
        // satellite: every benchmark mask kind, random decode positions
        // and page sizes, against the dense materialization oracle
        prop::check(
            "incremental-view-sound",
            prop::PropConfig { cases: 24, base_seed: 0xDEC0DE },
            |rng| {
                let n = 128;
                let t = rng.range(0, n as i64) as usize;
                let ps = *rng.choose(&[8usize, 16, 32]);
                for kind in MaskKind::BENCHMARK {
                    let mask = builders::build(kind, n, rng);
                    check_sound(&mask, t, ps).map_err(|e| format!("{kind}: {e}"))?;
                }
                Ok(())
            },
        );
    }
}
