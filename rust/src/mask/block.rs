//! Per-tile min/max precompute and tile classification (paper Alg. 1
//! line 4 and Eq. 4).
//!
//! The eight `⌈N/Bc⌉`-sized vectors are the paper's "Preprocessing" step;
//! [`BlockTable::classify`] is the real-time decision the kernel makes
//! for every `(i, j)` tile: skip it entirely, run it with element-wise
//! masking, or run it mask-free.

use super::flashmask::FlashMask;

/// Three-way tile type of paper Eq. 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockClass {
    /// Every element masked — the kernel skips the tile (zero FLOPs).
    FullyMasked,
    /// Some elements masked — compute + apply element-wise interval test.
    PartiallyMasked,
    /// No element masked — compute without any mask work.
    Unmasked,
}

/// Block min/max table for one mask at key-block size `bc`.
#[derive(Clone, Debug)]
pub struct BlockTable {
    pub bc: usize,
    pub lts_min: Vec<i32>,
    pub lts_max: Vec<i32>,
    pub lte_min: Vec<i32>,
    pub lte_max: Vec<i32>,
    pub uts_min: Vec<i32>,
    pub uts_max: Vec<i32>,
    pub ute_min: Vec<i32>,
    pub ute_max: Vec<i32>,
}

fn minmax(v: &[i32], bc: usize) -> (Vec<i32>, Vec<i32>) {
    let tc = v.len().div_ceil(bc);
    let mut mins = Vec::with_capacity(tc);
    let mut maxs = Vec::with_capacity(tc);
    for b in 0..tc {
        let chunk = &v[b * bc..((b + 1) * bc).min(v.len())];
        mins.push(*chunk.iter().min().unwrap());
        maxs.push(*chunk.iter().max().unwrap());
    }
    (mins, maxs)
}

impl BlockTable {
    pub fn build(m: &FlashMask, bc: usize) -> BlockTable {
        let (lts_min, lts_max) = minmax(&m.lts, bc);
        let (lte_min, lte_max) = minmax(&m.lte, bc);
        let (uts_min, uts_max) = minmax(&m.uts, bc);
        let (ute_min, ute_max) = minmax(&m.ute, bc);
        BlockTable { bc, lts_min, lts_max, lte_min, lte_max, uts_min, uts_max, ute_min, ute_max }
    }

    pub fn tc(&self) -> usize {
        self.lts_min.len()
    }

    /// Classify tile `(bi, bj)` with query-block size `br`.
    ///
    /// Follows paper Eq. 4 per triangle, plus the implicit-causal test
    /// for tiles entirely above the diagonal.
    pub fn classify(
        &self,
        m: &FlashMask,
        bi: usize,
        br: usize,
        bj: usize,
        bc: usize,
    ) -> BlockClass {
        debug_assert_eq!(bc, self.bc);
        let row_lo = (bi * br) as i32; // first row in tile
        let row_hi = ((bi + 1) * br) as i32; // one past last row
        let col_lo = (bj * bc) as i32;
        let col_hi = ((bj + 1) * bc) as i32;

        if m.causal && row_hi <= col_lo {
            return BlockClass::FullyMasked; // entirely above the diagonal
        }

        // fully masked by the lower-triangle interval (Eq. 4 case 1)
        if row_lo >= self.lts_max[bj] && row_hi <= self.lte_min[bj] {
            return BlockClass::FullyMasked;
        }
        // fully masked by the upper-triangle interval
        if !m.causal && row_lo >= self.uts_max[bj] && row_hi <= self.ute_min[bj] {
            return BlockClass::FullyMasked;
        }

        let mut partial = false;
        // diagonal-crossing tile under implicit causality
        if m.causal && row_lo < col_hi - 1 {
            partial = true;
        }
        // lower interval intersects the tile (Eq. 4 case 2)
        if row_hi > self.lts_min[bj] && row_lo < self.lte_max[bj] {
            partial = true;
        }
        if !m.causal && row_hi > self.uts_min[bj] && row_lo < self.ute_max[bj] {
            partial = true;
        }
        if partial {
            BlockClass::PartiallyMasked
        } else {
            BlockClass::Unmasked
        }
    }

    /// Tile census over the whole score matrix: (fully, partial, unmasked).
    pub fn census(&self, m: &FlashMask, br: usize) -> (usize, usize, usize) {
        let n = m.n();
        let (tr, tc) = (n.div_ceil(br), self.tc());
        let (mut f, mut p, mut u) = (0, 0, 0);
        for bi in 0..tr {
            for bj in 0..tc {
                match self.classify(m, bi, br, bj, self.bc) {
                    BlockClass::FullyMasked => f += 1,
                    BlockClass::PartiallyMasked => p += 1,
                    BlockClass::Unmasked => u += 1,
                }
            }
        }
        (f, p, u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;
    use crate::util::prop;

    /// Dense-oracle classification of a tile.
    fn oracle_class(m: &FlashMask, bi: usize, br: usize, bj: usize, bc: usize) -> BlockClass {
        let n = m.n();
        let mut any_masked = false;
        let mut any_allowed = false;
        for i in bi * br..((bi + 1) * br).min(n) {
            for j in bj * bc..((bj + 1) * bc).min(n) {
                if m.allowed(i, j) {
                    any_allowed = true;
                } else {
                    any_masked = true;
                }
            }
        }
        match (any_allowed, any_masked) {
            (false, _) => BlockClass::FullyMasked,
            (true, true) => BlockClass::PartiallyMasked,
            (true, false) => BlockClass::Unmasked,
        }
    }

    /// Soundness contract: classification may be conservative (call a
    /// clean tile Partial) but must never skip a tile with visible
    /// elements nor declare a masked element mask-free.
    fn check_sound(m: &FlashMask, br: usize, bc: usize) -> Result<(), String> {
        let table = BlockTable::build(m, bc);
        let n = m.n();
        for bi in 0..n.div_ceil(br) {
            for bj in 0..n.div_ceil(bc) {
                let got = table.classify(m, bi, br, bj, bc);
                let want = oracle_class(m, bi, br, bj, bc);
                let ok = match (got, want) {
                    (BlockClass::FullyMasked, BlockClass::FullyMasked) => true,
                    (BlockClass::FullyMasked, _) => false, // would drop data!
                    (BlockClass::Unmasked, BlockClass::Unmasked) => true,
                    (BlockClass::Unmasked, _) => false, // would miss a mask!
                    (BlockClass::PartiallyMasked, _) => true, // conservative ok
                };
                if !ok {
                    return Err(format!("tile ({bi},{bj}): got {got:?}, want {want:?}"));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn causal_tiles_classified() {
        let m = FlashMask::empty(128, true);
        let t = BlockTable::build(&m, 32);
        // above diagonal => fully masked
        assert_eq!(t.classify(&m, 0, 32, 3, 32), BlockClass::FullyMasked);
        // diagonal tile => partial
        assert_eq!(t.classify(&m, 1, 32, 1, 32), BlockClass::PartiallyMasked);
        // below diagonal => unmasked
        assert_eq!(t.classify(&m, 3, 32, 0, 32), BlockClass::Unmasked);
    }

    #[test]
    fn census_adds_up() {
        let m = builders::causal_document(256, &[100, 80, 76]);
        let t = BlockTable::build(&m, 32);
        let (f, p, u) = t.census(&m, 32);
        assert_eq!(f + p + u, 64);
        assert!(f > 0 && p > 0);
    }

    #[test]
    fn classification_sound_all_builders() {
        for (name, m) in builders::benchmark_suite(128, 5) {
            check_sound(&m, 32, 32).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn prop_classification_sound_random_docs() {
        prop::check_default("block-classify-sound", |rng| {
            let n = 128;
            let k = rng.range(1, 8) as usize;
            let lens = crate::workload::docgen::sample_doc_lens(n, k, 1, rng);
            let m = if rng.f64() < 0.5 {
                builders::causal_document(n, &lens)
            } else {
                builders::document(n, &lens)
            };
            let br = *rng.choose(&[16usize, 32, 64]);
            let bc = *rng.choose(&[16usize, 32, 64]);
            check_sound(&m, br, bc)
        });
    }

    #[test]
    fn prop_block_sparsity_matches_census() {
        prop::check_default("sparsity-census-consistent", |rng| {
            let n = 128;
            let k = rng.range(2, 6) as usize;
            let lens = crate::workload::docgen::sample_doc_lens(n, k, 1, rng);
            let m = builders::causal_document(n, &lens);
            let t = BlockTable::build(&m, 32);
            let (f, _, _) = t.census(&m, 32);
            let rho = m.block_sparsity(32, 32);
            let want = f as f64 / 16.0;
            if (rho - want).abs() > 1e-12 {
                return Err(format!("rho {rho} != census {want}"));
            }
            Ok(())
        });
    }
}
