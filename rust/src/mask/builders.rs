//! Constructors for every mask family in paper Fig. 1(a).
//!
//! Each builder mirrors `python/compile/masks.py` exactly (the pytest
//! suite checks the python side against dense oracles; the rust tests
//! here check the same semantics, so the two layers agree by
//! transitivity — plus `tests/cross_layer.rs` checks a direct vector
//! equality on shared cases).

use super::flashmask::FlashMask;
use super::tree::TokenTree;
use super::types::MaskKind;
use crate::util::rng::Rng;
use crate::workload::docgen::sample_doc_lens;

/// (0) No masking — bidirectional full attention.
pub fn full(n: usize) -> FlashMask {
    FlashMask::empty(n, false)
}

/// (1) GPT-style causal mask.
pub fn causal(n: usize) -> FlashMask {
    FlashMask::empty(n, true)
}

/// (2) Causal sliding window: row `i` sees `j ∈ (i-window, i]`.
pub fn sliding_window(n: usize, window: usize) -> FlashMask {
    assert!(window >= 1);
    let mut m = FlashMask::empty(n, true);
    for j in 0..n {
        m.lts[j] = (j + window).min(n) as i32;
        m.lte[j] = n as i32;
    }
    normalize(m)
}

/// (3) Packed documents, causal within each (SFT packing).
pub fn causal_document(n: usize, doc_lens: &[usize]) -> FlashMask {
    assert_eq!(doc_lens.iter().sum::<usize>(), n);
    let mut m = FlashMask::empty(n, true);
    let mut start = 0;
    for &len in doc_lens {
        let end = start + len;
        for j in start..end {
            m.lts[j] = end as i32;
            m.lte[j] = n as i32;
        }
        start = end;
    }
    normalize(m)
}

/// (4) Bidirectional document mask (BERT/NaViT packing).
pub fn document(n: usize, doc_lens: &[usize]) -> FlashMask {
    assert_eq!(doc_lens.iter().sum::<usize>(), n);
    let mut m = FlashMask::empty(n, false);
    let mut start = 0;
    for &len in doc_lens {
        let end = start + len;
        for j in start..end {
            m.lts[j] = end as i32;
            m.lte[j] = n as i32;
            m.uts[j] = 0;
            m.ute[j] = start as i32;
        }
        start = end;
    }
    normalize(m)
}

/// One shared-question document: question length + per-answer lengths.
#[derive(Clone, Debug)]
pub struct SharedQuestionDoc {
    pub question_len: usize,
    pub answer_lens: Vec<usize>,
}

impl SharedQuestionDoc {
    pub fn total_len(&self) -> usize {
        self.question_len + self.answer_lens.iter().sum::<usize>()
    }
}

/// (5) Shared-question mask (DPO/RM): the question is causally visible
/// to every answer; answers are blind to their siblings.
pub fn share_question(n: usize, docs: &[SharedQuestionDoc]) -> FlashMask {
    let mut m = FlashMask::empty(n, true);
    let mut pos = 0;
    for doc in docs {
        let ds = pos;
        let de = ds + doc.total_len();
        assert!(de <= n, "docs exceed sequence length");
        for j in ds..ds + doc.question_len {
            m.lts[j] = de as i32;
            m.lte[j] = n as i32;
        }
        let mut a_start = ds + doc.question_len;
        for &al in &doc.answer_lens {
            for j in a_start..a_start + al {
                m.lts[j] = (a_start + al) as i32;
                m.lte[j] = n as i32;
            }
            a_start += al;
        }
        pos = de;
    }
    assert_eq!(pos, n, "docs cover {pos} of {n} tokens");
    normalize(m)
}

/// (6) BigBird-style: `n_global` prefix columns globally visible +
/// causal sliding window elsewhere.
pub fn global_sliding_window(n: usize, n_global: usize, window: usize) -> FlashMask {
    assert!(n_global <= n && window >= 1);
    let mut m = sliding_window(n, window);
    for j in 0..n_global {
        m.lts[j] = n as i32;
        m.lte[j] = n as i32;
    }
    normalize(m)
}

/// (7) In-context-learning blockwise mask: demo blocks attend within
/// themselves; the final (test) block attends to everything before it.
pub fn causal_blockwise(n: usize, block_lens: &[usize]) -> FlashMask {
    assert_eq!(block_lens.iter().sum::<usize>(), n);
    assert!(!block_lens.is_empty());
    let mut m = FlashMask::empty(n, true);
    let test_start = n - block_lens[block_lens.len() - 1];
    let mut start = 0;
    for &len in &block_lens[..block_lens.len() - 1] {
        let end = start + len;
        if end < test_start {
            for j in start..end {
                m.lts[j] = end as i32;
                m.lte[j] = test_start as i32;
            }
        }
        start = end;
    }
    normalize(m)
}

/// (8) T5 prefix-LM over one sequence.
pub fn prefix_lm_causal(n: usize, prefix_len: usize) -> FlashMask {
    prefix_lm_document(n, &[n], &[prefix_len])
}

/// (9)(10) Per-document prefix-LM: bidirectional within each document's
/// prefix, causal elsewhere, no cross-document attention.
pub fn prefix_lm_document(n: usize, doc_lens: &[usize], prefix_lens: &[usize]) -> FlashMask {
    assert_eq!(doc_lens.iter().sum::<usize>(), n);
    assert_eq!(doc_lens.len(), prefix_lens.len());
    let mut m = FlashMask::empty(n, false);
    let mut start = 0;
    for (&len, &p) in doc_lens.iter().zip(prefix_lens) {
        let (ds, de) = (start, start + len);
        assert!(p <= len);
        let pe = ds + p;
        for j in ds..de {
            m.lts[j] = de as i32;
            m.lte[j] = n as i32;
            if j < pe {
                // prefix column: only rows of *other* docs above are masked
                if ds > 0 {
                    m.uts[j] = 0;
                    m.ute[j] = ds.min(j) as i32;
                }
            } else if j > 0 {
                // suffix column: all rows above are masked (causal)
                m.uts[j] = 0;
                m.ute[j] = j as i32;
            }
        }
        start = de;
    }
    normalize(m)
}

/// (11) SCFA-style QK sparsity: one contiguous dropped-query range plus
/// an arbitrary set of dropped key columns, causal base.
pub fn qk_sparse(n: usize, q_drop: (usize, usize), k_drop_cols: &[usize]) -> FlashMask {
    let (qs, qe) = q_drop;
    assert!(qs <= qe && qe <= n);
    let mut m = FlashMask::empty(n, true);
    for j in 0..n {
        let s = qs.max(j);
        if s < qe {
            m.lts[j] = s as i32;
            m.lte[j] = qe as i32;
        }
    }
    for &c in k_drop_cols {
        m.lts[c] = c as i32;
        m.lte[c] = n as i32;
    }
    normalize(m)
}

/// (12) Reformer hash-sparse after bucket sort: contiguous hash chunks,
/// causal within each — structurally a causal document mask.
pub fn hash_sparse(n: usize, chunk_lens: &[usize]) -> FlashMask {
    causal_document(n, chunk_lens)
}

/// (13) Random KV-cache eviction: column `j` becomes invisible from a
/// random row `e_j ∈ (j, n]`.
pub fn random_eviction(n: usize, rng: &mut Rng) -> FlashMask {
    let mut m = FlashMask::empty(n, true);
    for j in 0..n {
        let e = rng.range(j as i64 + 1, n as i64 + 1) as usize;
        if e < n {
            m.lts[j] = e as i32;
            m.lte[j] = n as i32;
        }
    }
    normalize(m)
}

/// (14) Speculative-decode tree mask: `prefix_len` committed tokens
/// followed by a DFS-preorder draft tree.  Drafted cache column
/// `prefix_len + i` is visible only to the nodes of `i`'s subtree, so
/// its masked lower-triangle rows are the single interval
/// `[prefix_len + subtree_end(i), n)` — token-tree ancestor visibility
/// expressed as LTS/LTE column intervals (the paper's §3 claim that
/// FlashMask covers tree attention).  Committed columns stay plain
/// causal; row-dependent *base*-mask constraints are applied on top by
/// `decode::spec` at each node's logical position.
pub fn tree_mask(prefix_len: usize, tree: &TokenTree) -> FlashMask {
    let n = prefix_len + tree.len();
    let mut m = FlashMask::empty(n, true);
    for i in 0..tree.len() {
        // empty interval when the subtree reaches the end (normalize
        // keeps it at [n, n))
        m.lts[prefix_len + i] = (prefix_len + tree.subtree_end(i)) as i32;
        m.lte[prefix_len + i] = n as i32;
    }
    normalize(m)
}

/// Canonicalize empty intervals to `[n, n)` and validate.
fn normalize(mut m: FlashMask) -> FlashMask {
    let n = m.n() as i32;
    for j in 0..m.n() {
        if m.lts[j] >= m.lte[j] {
            m.lts[j] = n;
            m.lte[j] = n;
        }
        if m.uts[j] >= m.ute[j] {
            m.uts[j] = n;
            m.ute[j] = n;
        }
    }
    m.validate().expect("builder produced invalid mask");
    m
}

/// Instantiate one benchmark mask at length `n` with workload parameters
/// drawn like the paper's appendix A.5.2 construction.
pub fn build(kind: MaskKind, n: usize, rng: &mut Rng) -> FlashMask {
    // paper A.5.2 document-count ranges: [3,7] at 8K, [10,14] at 32K,
    // [11,15] at 128K; scale similarly in between
    let n_docs = (match n {
        n if n >= 100_000 => rng.range(11, 16),
        n if n >= 20_000 => rng.range(10, 15),
        n if n >= 8_000 => rng.range(3, 8),
        _ => rng.range(2, 7),
    } as usize)
        .min(n / 2)
        .max(1);
    match kind {
        MaskKind::Full => full(n),
        MaskKind::Causal => causal(n),
        MaskKind::SlidingWindow => sliding_window(n, (n / 8).max(1)),
        MaskKind::CausalDocument => {
            causal_document(n, &sample_doc_lens(n, n_docs, 1, rng))
        }
        MaskKind::Document => document(n, &sample_doc_lens(n, n_docs, 1, rng)),
        MaskKind::ShareQuestion => {
            let lens = sample_doc_lens(n, n_docs.min(n / 16).max(1), 8, rng);
            let docs: Vec<SharedQuestionDoc> = lens
                .iter()
                .map(|&dl| {
                    let n_ans = rng.range(2, 7) as usize;
                    // answers ≈ 10-20% of the query each (appendix A.2.1)
                    let a_total = ((dl as f64 * 0.15 * n_ans as f64
                        / (1.0 + 0.15 * n_ans as f64)) as usize)
                        .max(n_ans);
                    SharedQuestionDoc {
                        question_len: dl - a_total,
                        answer_lens: sample_doc_lens(a_total, n_ans, 1, rng),
                    }
                })
                .collect();
            share_question(n, &docs)
        }
        MaskKind::GlobalSlidingWindow => {
            global_sliding_window(n, (n / 16).max(1), (n / 8).max(1))
        }
        MaskKind::CausalBlockwise => {
            causal_blockwise(n, &sample_doc_lens(n, n_docs, 1, rng))
        }
        MaskKind::PrefixLmCausal => prefix_lm_causal(n, (n / 4).max(1)),
        MaskKind::PrefixLmDocument => {
            let lens = sample_doc_lens(n, n_docs, 2, rng);
            let prefixes: Vec<usize> =
                lens.iter().map(|&dl| rng.range(1, (dl / 2).max(2) as i64) as usize).collect();
            prefix_lm_document(n, &lens, &prefixes)
        }
        MaskKind::QkSparse => {
            // SCFA compacts kept tokens, so drops are tile-contiguous:
            // one contiguous query range + one contiguous key range
            let qs = rng.range(0, (n / 2) as i64) as usize;
            let qe = qs + rng.range(0, (n / 8) as i64) as usize;
            let ks = rng.range(0, (n / 2) as i64) as usize;
            let ke = (ks + rng.range(0, (n / 8) as i64) as usize).min(n);
            let cols: Vec<usize> = (ks..ke).collect();
            qk_sparse(n, (qs, qe.min(n)), &cols)
        }
        MaskKind::HashSparse => hash_sparse(n, &sample_doc_lens(n, n_docs, 1, rng)),
        MaskKind::RandomEviction => random_eviction(n, rng),
    }
}

/// The 12-case benchmark suite at length `n` (paper Tables 4–9 rows).
pub fn benchmark_suite(n: usize, seed: u64) -> Vec<(MaskKind, FlashMask)> {
    let mut rng = Rng::new(seed);
    MaskKind::BENCHMARK
        .iter()
        .map(|&k| (k, build(k, n, &mut rng)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn brute<F: Fn(usize, usize) -> bool>(n: usize, pred: F) -> Vec<bool> {
        let mut out = vec![false; n * n];
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = pred(i, j);
            }
        }
        out
    }

    #[test]
    fn causal_semantics() {
        assert_eq!(causal(8).dense_allowed(), brute(8, |i, j| i >= j));
    }

    #[test]
    fn sliding_window_semantics() {
        let m = sliding_window(16, 4);
        assert_eq!(m.dense_allowed(), brute(16, |i, j| j <= i && i < j + 4));
    }

    #[test]
    fn causal_document_semantics() {
        let lens = [5usize, 4, 3];
        let doc = |t: usize| if t < 5 { 0 } else if t < 9 { 1 } else { 2 };
        let m = causal_document(12, &lens);
        assert_eq!(m.dense_allowed(), brute(12, |i, j| i >= j && doc(i) == doc(j)));
    }

    #[test]
    fn document_semantics() {
        let doc = |t: usize| usize::from(t >= 5);
        let m = document(12, &[5, 7]);
        assert_eq!(m.dense_allowed(), brute(12, |i, j| doc(i) == doc(j)));
    }

    #[test]
    fn share_question_semantics() {
        // doc0: q=3 answers [2,3]; doc1: q=2 answers [2]
        let docs = [
            SharedQuestionDoc { question_len: 3, answer_lens: vec![2, 3] },
            SharedQuestionDoc { question_len: 2, answer_lens: vec![2] },
        ];
        let m = share_question(12, &docs);
        // token -> (doc, part): part 0 = question, else answer index
        let lay = [
            (0, 0), (0, 0), (0, 0), (0, 1), (0, 1), (0, 2), (0, 2), (0, 2),
            (1, 0), (1, 0), (1, 1), (1, 1),
        ];
        let want = brute(12, |i, j| {
            let ((di, pi), (dj, pj)) = (lay[i], lay[j]);
            i >= j && di == dj && (pj == 0 || pi == pj)
        });
        assert_eq!(m.dense_allowed(), want);
    }

    #[test]
    fn global_sliding_window_semantics() {
        let m = global_sliding_window(16, 3, 4);
        assert_eq!(
            m.dense_allowed(),
            brute(16, |i, j| i >= j && (j < 3 || i < j + 4))
        );
    }

    #[test]
    fn causal_blockwise_semantics() {
        let m = causal_blockwise(12, &[4, 4, 4]);
        let blk = |t: usize| t / 4;
        let want = brute(12, |i, j| i >= j && (blk(i) == 2 || blk(i) == blk(j)));
        assert_eq!(m.dense_allowed(), want);
    }

    #[test]
    fn prefix_lm_causal_semantics() {
        let m = prefix_lm_causal(12, 5);
        assert_eq!(
            m.dense_allowed(),
            brute(12, |i, j| j <= i || (i < 5 && j < 5))
        );
    }

    #[test]
    fn prefix_lm_document_semantics() {
        let m = prefix_lm_document(12, &[7, 5], &[3, 2]);
        let doc = |t: usize| usize::from(t >= 7);
        let want = brute(12, |i, j| {
            if doc(i) != doc(j) {
                return false;
            }
            let ds = if doc(i) == 0 { 0 } else { 7 };
            let pe = ds + if doc(i) == 0 { 3 } else { 2 };
            j <= i || (i < pe && j < pe)
        });
        assert_eq!(m.dense_allowed(), want);
    }

    #[test]
    fn qk_sparse_semantics() {
        let m = qk_sparse(16, (5, 8), &[2, 11]);
        let want = brute(16, |i, j| {
            i >= j && !(5..8).contains(&i) && j != 2 && j != 11
        });
        assert_eq!(m.dense_allowed(), want);
    }

    #[test]
    fn random_eviction_contiguous_visibility() {
        let mut rng = Rng::new(3);
        let m = random_eviction(32, &mut rng);
        let dense = m.dense_allowed();
        for j in 0..32 {
            let vis: Vec<usize> = (0..32).filter(|&i| dense[i * 32 + j]).collect();
            assert!(!vis.is_empty());
            assert_eq!(vis[0], j, "diagonal visible");
            assert!(vis.windows(2).all(|w| w[1] == w[0] + 1), "contiguous");
        }
    }

    #[test]
    fn benchmark_suite_valid_and_distinct() {
        let suite = benchmark_suite(128, 9);
        assert_eq!(suite.len(), 12);
        for (kind, m) in &suite {
            m.validate().unwrap_or_else(|e| panic!("{kind}: {e}"));
            assert_eq!(m.causal, kind.is_causal(), "{kind}");
        }
    }

    #[test]
    fn build_deterministic_per_seed() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        assert_eq!(
            build(MaskKind::ShareQuestion, 128, &mut a),
            build(MaskKind::ShareQuestion, 128, &mut b)
        );
    }

    /// Ancestor-visibility oracle for `tree_mask`: rows below the draft
    /// region are plain causal; a draft node sees every committed
    /// column plus exactly its own root path (ancestors-or-self).
    fn tree_oracle(prefix: usize, tree: &TokenTree, i: usize, j: usize) -> bool {
        let n = prefix + tree.len();
        debug_assert!(i < n && j < n);
        if j > i {
            return false; // causal
        }
        if i < prefix || j < prefix {
            return true; // committed row or committed column (j <= i)
        }
        tree.is_ancestor_or_self(j - prefix, i - prefix)
    }

    #[test]
    fn tree_mask_chain_is_plain_causal() {
        let t = TokenTree::chain(6);
        let m = tree_mask(10, &t);
        assert_eq!(m.dense_allowed(), causal(16).dense_allowed());
    }

    #[test]
    fn tree_mask_branching_semantics() {
        // two root candidates; first continues as a chain of two
        let t = TokenTree::from_parents(vec![None, Some(0), None]).unwrap();
        let m = tree_mask(2, &t);
        // node 1 (row 3) sees its ancestor node 0 (col 2)...
        assert!(m.allowed(3, 2));
        // ...but node 2 (row 4), a sibling root, does not
        assert!(!m.allowed(4, 2));
        assert!(!m.allowed(4, 3));
        // every draft node sees the committed prefix
        for row in 2..5 {
            assert!(m.allowed(row, 0) && m.allowed(row, 1));
        }
    }

    #[test]
    fn prop_tree_mask_matches_ancestor_visibility() {
        // satellite: random token trees → dense materialization equals
        // the ancestor-visibility definition, every element
        prop::check_default("tree-mask-dense", |rng| {
            let prefix = rng.range(0, 24) as usize;
            let k = rng.range(1, 13) as usize;
            let tree = TokenTree::random(k, rng);
            let m = tree_mask(prefix, &tree);
            m.validate().map_err(|e| e.to_string())?;
            let n = prefix + k;
            let dense = m.dense_allowed();
            for i in 0..n {
                for j in 0..n {
                    let want = tree_oracle(prefix, &tree, i, j);
                    if dense[i * n + j] != want {
                        return Err(format!(
                            "prefix={prefix} k={k} ({i},{j}): mask {} oracle {want}",
                            dense[i * n + j]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_tree_mask_page_classification_sound() {
        // satellite: IncrementalMaskView::classify_page over a tree mask
        // must never call a page with any visible column FullyMasked
        // (skipping it would drop live draft KV)
        use crate::mask::{BlockClass, IncrementalMaskView};
        prop::check_default("tree-mask-page-skip-sound", |rng| {
            let prefix = rng.range(0, 40) as usize;
            let k = rng.range(1, 13) as usize;
            let ps = *rng.choose(&[4usize, 8, 16]);
            let tree = TokenTree::random(k, rng);
            let m = tree_mask(prefix, &tree);
            let view = IncrementalMaskView::new(&m, ps);
            let n = prefix + k;
            for node in 0..k {
                let row = prefix + node;
                for page in 0..view.n_pages() {
                    let cols = page * ps..((page + 1) * ps).min(n);
                    let any_visible =
                        cols.clone().any(|j| tree_oracle(prefix, &tree, row, j));
                    let class = view.classify_page(&m, row, page);
                    if class == BlockClass::FullyMasked && any_visible {
                        return Err(format!(
                            "prefix={prefix} k={k} ps={ps} node {node} page {page}: \
                             skippable but partially visible"
                        ));
                    }
                    if class == BlockClass::Unmasked {
                        if let Some(j) = cols.clone().find(|&j| !tree_oracle(prefix, &tree, row, j)) {
                            return Err(format!(
                                "node {node} page {page}: unmasked but col {j} hidden"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
