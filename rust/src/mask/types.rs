//! Mask-kind enumeration — the paper's 12 benchmark cases (Tables 4–9)
//! plus hash-sparse from Fig. 1(a).

use std::fmt;
use std::str::FromStr;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MaskKind {
    Full,
    Causal,
    SlidingWindow,
    CausalDocument,
    Document,
    ShareQuestion,
    GlobalSlidingWindow,
    CausalBlockwise,
    PrefixLmCausal,
    PrefixLmDocument,
    QkSparse,
    HashSparse,
    RandomEviction,
}

impl MaskKind {
    /// The 12 cases of the paper's kernel benchmark, in table order.
    pub const BENCHMARK: [MaskKind; 12] = [
        MaskKind::Full,
        MaskKind::Causal,
        MaskKind::SlidingWindow,
        MaskKind::CausalDocument,
        MaskKind::Document,
        MaskKind::ShareQuestion,
        MaskKind::GlobalSlidingWindow,
        MaskKind::CausalBlockwise,
        MaskKind::PrefixLmDocument,
        MaskKind::PrefixLmCausal,
        MaskKind::QkSparse,
        MaskKind::RandomEviction,
    ];

    pub fn all() -> Vec<MaskKind> {
        let mut v = Self::BENCHMARK.to_vec();
        v.push(MaskKind::HashSparse);
        v
    }

    /// Paper display name (as used in Tables 4–9).
    pub fn paper_name(&self) -> &'static str {
        match self {
            MaskKind::Full => "Full",
            MaskKind::Causal => "Causal",
            MaskKind::SlidingWindow => "Sliding Window",
            MaskKind::CausalDocument => "Causal Document Mask",
            MaskKind::Document => "Document Mask",
            MaskKind::ShareQuestion => "Share Question Mask",
            MaskKind::GlobalSlidingWindow => "Global Sliding Window",
            MaskKind::CausalBlockwise => "Causal Blockwise Mask",
            MaskKind::PrefixLmDocument => "Prefix LM Document Mask",
            MaskKind::PrefixLmCausal => "Prefix LM Causal Mask",
            MaskKind::QkSparse => "QK-sparse Mask",
            MaskKind::HashSparse => "Hash-Sparse Mask",
            MaskKind::RandomEviction => "Random Eviction Mask",
        }
    }

    pub fn is_causal(&self) -> bool {
        !matches!(
            self,
            MaskKind::Full
                | MaskKind::Document
                | MaskKind::PrefixLmCausal
                | MaskKind::PrefixLmDocument
        )
    }
}

impl fmt::Display for MaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            MaskKind::Full => "full",
            MaskKind::Causal => "causal",
            MaskKind::SlidingWindow => "sliding_window",
            MaskKind::CausalDocument => "causal_document",
            MaskKind::Document => "document",
            MaskKind::ShareQuestion => "share_question",
            MaskKind::GlobalSlidingWindow => "global_sliding_window",
            MaskKind::CausalBlockwise => "causal_blockwise",
            MaskKind::PrefixLmCausal => "prefix_lm_causal",
            MaskKind::PrefixLmDocument => "prefix_lm_document",
            MaskKind::QkSparse => "qk_sparse",
            MaskKind::HashSparse => "hash_sparse",
            MaskKind::RandomEviction => "random_eviction",
        };
        f.write_str(s)
    }
}

impl FromStr for MaskKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        MaskKind::all()
            .into_iter()
            .find(|k| k.to_string() == s)
            .ok_or_else(|| format!("unknown mask kind '{s}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_names() {
        for k in MaskKind::all() {
            assert_eq!(k.to_string().parse::<MaskKind>().unwrap(), k);
        }
    }

    #[test]
    fn benchmark_has_twelve() {
        assert_eq!(MaskKind::BENCHMARK.len(), 12);
    }

    #[test]
    fn causality_flags() {
        assert!(MaskKind::Causal.is_causal());
        assert!(!MaskKind::Document.is_causal());
        assert!(!MaskKind::PrefixLmCausal.is_causal());
        assert!(MaskKind::ShareQuestion.is_causal());
    }
}
