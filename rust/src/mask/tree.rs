//! Token trees for speculative decoding.
//!
//! A draft tree holds `k` speculated tokens laid out in **DFS preorder**
//! over the KV cache slots that follow the committed prefix: node `i`
//! occupies cache position `prefix + i`, its parent is an earlier node
//! (or the committed prefix itself for root children), and every
//! subtree is a contiguous index range `[i, subtree_end(i))`.
//!
//! Preorder is what makes tree attention FlashMask-expressible: the
//! cache rows allowed to attend to drafted column `i` are exactly the
//! nodes of `i`'s subtree, so the *masked* lower-triangle rows for that
//! column are the single interval `[prefix + subtree_end(i), n)` — one
//! `(LTS, LTE)` pair per column, the paper's §4.1 representation.  An
//! arbitrary parent array (subtrees interleaved) would need multiple
//! intervals per column; [`TokenTree::from_parents`] therefore rejects
//! non-preorder layouts.
//!
//! A node's *logical* position is `prefix + depth(i)`: the sequence
//! position it would occupy if its root-to-node path were committed by
//! sequential decode.  Row-dependent masks (sliding window, eviction)
//! must be evaluated at the logical position, not the cache slot —
//! `decode::spec::spec_visible` does exactly that.

use anyhow::{ensure, Result};
use crate::util::rng::Rng;

/// A draft-token tree in DFS preorder over cache slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenTree {
    parents: Vec<Option<usize>>,
    depth: Vec<usize>,
    subtree_end: Vec<usize>,
}

impl TokenTree {
    /// Build from a parent array (`None` = child of the committed
    /// prefix).  Fails unless the array is a DFS preorder: each node's
    /// parent must be the previous node or one of its ancestors (or a
    /// fresh root), which guarantees contiguous subtrees.
    pub fn from_parents(parents: Vec<Option<usize>>) -> Result<TokenTree> {
        let k = parents.len();
        ensure!(k >= 1, "empty draft tree");
        ensure!(parents[0].is_none(), "first preorder node must be a root");
        let mut depth = vec![0usize; k];
        for i in 0..k {
            if let Some(p) = parents[i] {
                ensure!(p < i, "node {i}: parent {p} does not precede it");
                depth[i] = depth[p] + 1;
            }
            if i > 0 {
                // preorder: attach to the rightmost path of node i-1
                let ok = match parents[i] {
                    None => true, // new root closes every open subtree
                    Some(p) => {
                        let mut a = Some(i - 1);
                        loop {
                            match a {
                                Some(x) if x == p => break true,
                                Some(x) => a = parents[x],
                                None => break false,
                            }
                        }
                    }
                };
                ensure!(ok, "node {i}: parents are not in DFS preorder");
            }
        }
        let mut subtree_end: Vec<usize> = (0..k).map(|i| i + 1).collect();
        for i in (1..k).rev() {
            if let Some(p) = parents[i] {
                subtree_end[p] = subtree_end[p].max(subtree_end[i]);
            }
        }
        Ok(TokenTree { parents, depth, subtree_end })
    }

    /// Linear chain of `k` nodes — the degenerate (classic, non-tree)
    /// speculative draft.
    pub fn chain(k: usize) -> TokenTree {
        let parents = (0..k).map(|i| if i == 0 { None } else { Some(i - 1) }).collect();
        TokenTree::from_parents(parents).expect("chain is always preorder")
    }

    pub fn len(&self) -> usize {
        self.parents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parents.is_empty()
    }

    pub fn parent(&self, i: usize) -> Option<usize> {
        self.parents[i]
    }

    /// Ancestor count; a root child has depth 0.  Logical sequence
    /// position of node `i` = `prefix + depth(i)`.
    pub fn depth(&self, i: usize) -> usize {
        self.depth[i]
    }

    /// One past the last node of `i`'s subtree (preorder-contiguous).
    pub fn subtree_end(&self, i: usize) -> usize {
        self.subtree_end[i]
    }

    /// Is `a` an ancestor of `i`, or `i` itself?  O(1) via the subtree
    /// interval — the same test the tree-mask column intervals encode.
    pub fn is_ancestor_or_self(&self, a: usize, i: usize) -> bool {
        a <= i && i < self.subtree_end[a]
    }

    /// Longest root-to-leaf path length in nodes (`max depth + 1`).
    pub fn max_path_len(&self) -> usize {
        self.depth.iter().max().map_or(0, |d| d + 1)
    }

    /// Children of the committed prefix (candidate first tokens).
    pub fn roots(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.parents[i].is_none()).collect()
    }

    /// Direct children of node `i`, in preorder.
    pub fn children(&self, i: usize) -> Vec<usize> {
        (i + 1..self.subtree_end[i]).filter(|&j| self.parents[j] == Some(i)).collect()
    }

    /// Random preorder tree with `k` nodes (property-test generator):
    /// each node attaches uniformly to the rightmost path, so chains,
    /// bushy trees and multi-root forests all occur.
    pub fn random(k: usize, rng: &mut Rng) -> TokenTree {
        assert!(k >= 1);
        let mut parents: Vec<Option<usize>> = vec![None];
        for i in 1..k {
            // candidates: previous node, each of its ancestors, and root
            let mut cand: Vec<Option<usize>> = vec![Some(i - 1)];
            let mut a = parents[i - 1];
            while let Some(x) = a {
                cand.push(Some(x));
                a = parents[x];
            }
            cand.push(None);
            parents.push(*rng.choose(&cand));
        }
        TokenTree::from_parents(parents).expect("generator emits preorder")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn chain_shape() {
        let t = TokenTree::chain(4);
        assert_eq!(t.len(), 4);
        for i in 0..4 {
            assert_eq!(t.depth(i), i);
            assert_eq!(t.subtree_end(i), 4);
        }
        assert_eq!(t.roots(), vec![0]);
        assert_eq!(t.children(1), vec![2]);
        assert_eq!(t.max_path_len(), 4);
        assert!(t.is_ancestor_or_self(0, 3));
        assert!(!t.is_ancestor_or_self(2, 1));
    }

    #[test]
    fn branching_tree_intervals() {
        // 0 ── 1 ── 2
        //  \     └─ 3
        //   4 (second root)
        let t = TokenTree::from_parents(vec![None, Some(0), Some(1), Some(1), None]).unwrap();
        assert_eq!(t.subtree_end(0), 4);
        assert_eq!(t.subtree_end(1), 4);
        assert_eq!(t.subtree_end(2), 3);
        assert_eq!(t.subtree_end(4), 5);
        assert_eq!(t.depth(3), 2);
        assert_eq!(t.roots(), vec![0, 4]);
        assert_eq!(t.children(1), vec![2, 3]);
        assert!(t.is_ancestor_or_self(1, 3));
        assert!(!t.is_ancestor_or_self(2, 3));
        assert!(!t.is_ancestor_or_self(0, 4));
        assert_eq!(t.max_path_len(), 3);
    }

    #[test]
    fn rejects_non_preorder() {
        // subtrees of node 0 and node 2 interleaved: 0, 1(→0), 2, 3(→0)
        assert!(TokenTree::from_parents(vec![None, Some(0), None, Some(0)]).is_err());
        // parent after child
        assert!(TokenTree::from_parents(vec![None, Some(2), Some(0)]).is_err());
        // empty
        assert!(TokenTree::from_parents(vec![]).is_err());
        // first node not a root
        assert!(TokenTree::from_parents(vec![Some(0)]).is_err());
    }

    #[test]
    fn prop_random_trees_have_consistent_intervals() {
        prop::check_default("token-tree-intervals", |rng| {
            let k = rng.range(1, 17) as usize;
            let t = TokenTree::random(k, rng);
            for i in 0..k {
                // subtree interval == reachability via parent links
                for j in 0..k {
                    let mut reach = j == i;
                    let mut a = t.parent(j);
                    while let Some(x) = a {
                        if x == i {
                            reach = true;
                            break;
                        }
                        a = t.parent(x);
                    }
                    if reach != t.is_ancestor_or_self(i, j) {
                        return Err(format!("k={k} i={i} j={j}: interval vs parent-walk"));
                    }
                }
                // depth consistent with parent
                match t.parent(i) {
                    None => {
                        if t.depth(i) != 0 {
                            return Err(format!("root {i} has depth {}", t.depth(i)));
                        }
                    }
                    Some(p) => {
                        if t.depth(i) != t.depth(p) + 1 {
                            return Err(format!("depth chain broken at {i}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
