//! FlashMask — rust reproduction of *"FlashMask: Efficient and Rich Mask
//! Extension of FlashAttention"* (ICLR 2025).
//!
//! Layer-3 of the three-layer stack (see DESIGN.md):
//!
//! * [`mask`] — the paper's column-wise sparse mask representation
//!   (LTS/LTE/UTS/UTE), builders for every mask family in Fig. 1(a) and
//!   the per-tile min/max classifier of Eq. 4.
//! * [`attention`] — a CPU blocked-attention engine executing Alg. 1/2
//!   tile-for-tile (the "GPU simulator"), plus FlexAttention-like and
//!   FlashInfer-BSR-like baselines.  [`attention::api`] is the public
//!   surface: an `AttnProblem` builder compiled to cached
//!   `ExecutionPlan`s and executed on pluggable `Backend`s
//!   (DESIGN.md §Public API).
//! * [`decode`] — the autoregressive serving path: paged KV cache,
//!   single-row flash-decode kernel driven by the incremental mask
//!   view, and a continuous-batching scheduler (DESIGN.md §Decode).
//! * [`workload`] — synthetic dataset generators from appendix
//!   A.2.1 / A.4.1 / A.5.2.
//! * [`perf`] — FLOPs accounting, the calibrated A100 timing model and
//!   the training memory model used to regenerate the paper's tables.
//! * [`runtime`] — PJRT CPU client executing the AOT artifacts emitted
//!   by `python/compile/aot.py` (python never runs at request time).
//! * [`coordinator`] — the training driver: document packing → FlashMask
//!   vectors → PJRT train step → metrics.
//! * [`telemetry`] — unified observability: metrics registry with
//!   latency histograms, request-scoped tracing spans and the leveled
//!   logger (DESIGN.md §Telemetry).
//! * [`util`] — std-only substitutes for crates unavailable in this
//!   offline image (CLI, JSON, PRNG, bench harness, mini-proptest).
//! * [`analysis`] — project-native static analysis (`flashmask lint`):
//!   a lexer-driven checker for the repo's own invariants — hot-path
//!   panic-freedom, deprecated-shim bans, telemetry naming, unsafe
//!   hygiene (DESIGN.md §Static analysis).

// The only unsafe code in this crate is the checkpoint writer's
// byte-level f32 (de)serialization in `coordinator::checkpoint`; the
// `unsafe-hygiene` lint pass enforces that allowlist.  Unsafe bodies
// must spell out each unsafe operation.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod attention;
pub mod coordinator;
pub mod decode;
pub mod reports;
pub mod mask;
pub mod perf;
pub mod runtime;
pub mod server;
pub mod telemetry;
pub mod util;
pub mod workload;
