//! The pluggable lint-pass engine (DESIGN.md §Static analysis).
//!
//! [`run`] walks the requested roots, lexes every `.rs` file once
//! ([`super::lexer`]), hands each [`SourceFile`] to every registered
//! [`Pass`] whose [`Pass::applies`] accepts the path, then filters the
//! collected [`Diagnostic`]s through the suppression pragmas found in
//! the file's comments.  The surviving diagnostics (plus a count of
//! suppressed ones) form the [`Report`] the `flashmask lint`
//! subcommand prints.
//!
//! ## Suppression pragmas
//!
//! ```text
//! // lint: allow(<pass>[:<rule>][, …]) — <reason>
//! // lint: allow-file(<pass>[:<rule>][, …]) — <reason>
//! ```
//!
//! `allow` applies to its own line and the line directly below (so a
//! pragma can sit on the offending line or on a comment line above
//! it); `allow-file` applies to the whole file.  The reason is
//! **required** — a pragma without one is itself an error diagnostic
//! (`pragma:missing-reason`), so every suppression carries its
//! justification in the source.  `-`/`--` are accepted in place of the
//! em-dash.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use super::lexer::{self, SourceFile};
use crate::util::json::Json;

/// Diagnostic severity. Both levels fail `flashmask lint`; the split
/// lets downstream tooling (and future passes) triage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// One finding, addressed `file:line` (1-indexed line).
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Emitting pass (`hot-path-panic`, `deprecated-shim`, …).
    pub pass: &'static str,
    /// Sub-rule within the pass (`unwrap`, `index`, `undeclared`, …).
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub severity: Severity,
    pub message: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!(
            "{}:{}: {} [{}:{}] {}",
            self.file, self.line, self.severity, self.pass, self.rule, self.message
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pass", Json::Str(self.pass.to_string())),
            ("rule", Json::Str(self.rule.to_string())),
            ("file", Json::Str(self.file.clone())),
            ("line", Json::Num(self.line as f64)),
            ("severity", Json::Str(self.severity.to_string())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// Shared per-run state passed to every pass.
pub struct Context {
    /// Telemetry names declared in the linted tree's
    /// `telemetry/names.rs` (falling back to the built-in
    /// [`crate::telemetry::names::ALL`] when the file is not part of
    /// the lint set — e.g. when linting a fixture directory).
    pub declared_names: BTreeSet<String>,
}

/// A lint pass: a named check over one lexed file.
pub trait Pass {
    /// Stable pass name — used in diagnostics and pragma specs.
    fn name(&self) -> &'static str;
    /// One-line description for `--json` / docs.
    fn description(&self) -> &'static str;
    /// Whether this pass runs on `path` (suffix-matched, `/`-separated).
    fn applies(&self, path: &str) -> bool;
    fn run(&self, file: &SourceFile, ctx: &Context, out: &mut Vec<Diagnostic>);
}

/// Parsed suppression pragma.
#[derive(Clone, Debug)]
struct Pragma {
    line: usize,
    file_scope: bool,
    /// `(pass, rule)`; `rule` empty = all rules of the pass.
    specs: Vec<(String, String)>,
    has_reason: bool,
}

/// Extract every `lint:` pragma from a file's comment lines.
fn collect_pragmas(file: &SourceFile) -> Vec<Pragma> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        let c = &line.comment;
        let Some(pos) = c.find("lint:") else { continue };
        let rest = c[pos + "lint:".len()..].trim_start();
        let (file_scope, rest) = if let Some(r) = rest.strip_prefix("allow-file") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("allow") {
            (false, r)
        } else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix('(') else { continue };
        let Some(close) = rest.find(')') else { continue };
        let specs = rest[..close]
            .split(',')
            .map(|s| {
                let s = s.trim();
                match s.split_once(':') {
                    Some((p, r)) => (p.trim().to_string(), r.trim().to_string()),
                    None => (s.to_string(), String::new()),
                }
            })
            .filter(|(p, _)| !p.is_empty())
            .collect();
        // reason: whatever follows the closing paren, minus dash/em-dash
        // separators; must be non-empty
        let reason = rest[close + 1..]
            .trim_start()
            .trim_start_matches(['—', '–', '-', ':'])
            .trim();
        out.push(Pragma {
            line: idx + 1,
            file_scope,
            specs,
            has_reason: !reason.is_empty(),
        });
    }
    out
}

fn pragma_matches(p: &Pragma, d: &Diagnostic) -> bool {
    let in_range = p.file_scope || p.line == d.line || p.line + 1 == d.line;
    in_range
        && p.specs
            .iter()
            .any(|(pass, rule)| pass == d.pass && (rule.is_empty() || rule == d.rule))
}

/// The result of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// Pass names that ran, in registration order.
    pub passes: Vec<&'static str>,
    /// Files lexed.
    pub files: usize,
    /// Non-suppressed findings, ordered by file then line.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings silenced by a reasoned pragma.
    pub suppressed: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Stable JSON shape (schema pinned by `json_schema_is_stable`):
    /// `{tool, schema_version, files, passes, diagnostics, suppressed,
    /// clean}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::Str("flashmask-lint".to_string())),
            ("schema_version", Json::Num(1.0)),
            ("files", Json::Num(self.files as f64)),
            (
                "passes",
                Json::Arr(self.passes.iter().map(|p| Json::Str(p.to_string())).collect()),
            ),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
            ),
            ("suppressed", Json::Num(self.suppressed as f64)),
            ("clean", Json::Bool(self.clean())),
        ])
    }
}

/// Recursively collect `.rs` files under `root` (or `root` itself),
/// sorted for deterministic output.  `target/` build dirs are skipped.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let rd = std::fs::read_dir(root)
        .map_err(|e| format!("lint: cannot read directory {}: {e}", root.display()))?;
    let mut entries: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.file_name().map(|n| n != "target").unwrap_or(true))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Run `passes` over every `.rs` file under `roots`.  Roots that do
/// not exist are an error; pass an explicit file list to lint a
/// subset.
pub fn run(roots: &[PathBuf], passes: &[Box<dyn Pass>]) -> Result<Report, String> {
    let mut files = Vec::new();
    for r in roots {
        collect_rs_files(r, &mut files)?;
    }
    files.dedup();

    // lex everything once
    let mut lexed = Vec::with_capacity(files.len());
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| format!("lint: cannot read {}: {e}", f.display()))?;
        let path = f.to_string_lossy().replace('\\', "/");
        lexed.push(lexer::lex(&path, &src));
    }

    // the declared-name registry: parse the linted tree's names.rs if
    // present (so the lint checks the tree as it is on disk), else the
    // built-in registry
    let declared_names: BTreeSet<String> = match lexed
        .iter()
        .find(|f| f.path.ends_with("telemetry/names.rs"))
    {
        Some(f) => f
            .strings
            .iter()
            .filter(|s| !f.lines.get(s.line - 1).is_some_and(|l| l.in_test))
            .map(|s| s.text.clone())
            .collect(),
        None => crate::telemetry::names::ALL.iter().map(|s| s.to_string()).collect(),
    };
    let ctx = Context { declared_names };

    let mut report = Report {
        passes: passes.iter().map(|p| p.name()).collect(),
        files: lexed.len(),
        ..Report::default()
    };
    for file in &lexed {
        let mut raw = Vec::new();
        for pass in passes {
            if pass.applies(&file.path) {
                pass.run(file, &ctx, &mut raw);
            }
        }
        let pragmas = collect_pragmas(file);
        for d in raw {
            if pragmas.iter().any(|p| pragma_matches(p, &d)) {
                report.suppressed += 1;
            } else {
                report.diagnostics.push(d);
            }
        }
        // a pragma without a reason is itself a finding
        for p in &pragmas {
            if !p.has_reason {
                report.diagnostics.push(Diagnostic {
                    pass: "pragma",
                    rule: "missing-reason",
                    file: file.path.clone(),
                    line: p.line,
                    severity: Severity::Error,
                    message: "suppression pragma requires a reason: \
                              `// lint: allow(pass[:rule]) — <why>`"
                        .to_string(),
                });
            }
        }
    }
    report
        .diagnostics
        .sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct EveryLine;
    impl Pass for EveryLine {
        fn name(&self) -> &'static str {
            "every-line"
        }
        fn description(&self) -> &'static str {
            "test pass flagging every non-empty code line"
        }
        fn applies(&self, _path: &str) -> bool {
            true
        }
        fn run(&self, file: &SourceFile, _ctx: &Context, out: &mut Vec<Diagnostic>) {
            for (i, l) in file.lines.iter().enumerate() {
                if !l.code.trim().is_empty() {
                    out.push(Diagnostic {
                        pass: "every-line",
                        rule: "hit",
                        file: file.path.clone(),
                        line: i + 1,
                        severity: Severity::Error,
                        message: "line".into(),
                    });
                }
            }
        }
    }

    fn run_src(src: &str) -> (Vec<Diagnostic>, usize) {
        let file = lexer::lex("fixture.rs", src);
        let ctx = Context { declared_names: BTreeSet::new() };
        let mut raw = Vec::new();
        EveryLine.run(&file, &ctx, &mut raw);
        let pragmas = collect_pragmas(&file);
        let mut kept = Vec::new();
        let mut suppressed = 0;
        for d in raw {
            if pragmas.iter().any(|p| pragma_matches(p, &d)) {
                suppressed += 1;
            } else {
                kept.push(d);
            }
        }
        (kept, suppressed)
    }

    #[test]
    fn same_line_pragma_suppresses() {
        let (kept, sup) = run_src("let a = 1; // lint: allow(every-line) — fixture\nlet b = 2;\n");
        assert_eq!(sup, 1);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].line, 2);
    }

    #[test]
    fn preceding_line_pragma_suppresses_next_line() {
        let (kept, sup) =
            run_src("// lint: allow(every-line) — fixture\nlet a = 1;\nlet b = 2;\n");
        assert_eq!(sup, 1);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].line, 3);
    }

    #[test]
    fn file_scope_pragma_suppresses_everywhere() {
        let (kept, sup) =
            run_src("// lint: allow-file(every-line) — fixture\nlet a = 1;\nlet b = 2;\n");
        assert_eq!(sup, 2);
        assert!(kept.is_empty());
    }

    #[test]
    fn rule_scoped_pragma_only_matches_its_rule() {
        let (kept, sup) = run_src("let a = 1; // lint: allow(every-line:other) — fixture\n");
        assert_eq!(sup, 0, "rule `other` must not silence rule `hit`");
        assert_eq!(kept.len(), 1);
        let (kept, sup) = run_src("let a = 1; // lint: allow(every-line:hit) — fixture\n");
        assert_eq!(sup, 1);
        assert!(kept.is_empty());
    }

    #[test]
    fn pragma_without_reason_is_flagged() {
        let file = lexer::lex("fixture.rs", "let a = 1; // lint: allow(every-line)\n");
        let pragmas = collect_pragmas(&file);
        assert_eq!(pragmas.len(), 1);
        assert!(!pragmas[0].has_reason);
        // plain-dash separators are accepted as the reason marker
        let file = lexer::lex("fixture.rs", "let a = 1; // lint: allow(every-line) -- fixture\n");
        assert!(collect_pragmas(&file)[0].has_reason);
    }

    #[test]
    fn multiple_specs_in_one_pragma() {
        let (kept, sup) = run_src("let a = 1; // lint: allow(other, every-line:hit) — fixture\n");
        assert_eq!(sup, 1);
        assert!(kept.is_empty());
    }

    #[test]
    fn json_schema_is_stable() {
        let report = Report {
            passes: vec!["every-line"],
            files: 1,
            diagnostics: vec![Diagnostic {
                pass: "every-line",
                rule: "hit",
                file: "x.rs".into(),
                line: 3,
                severity: Severity::Warning,
                message: "m".into(),
            }],
            suppressed: 2,
        };
        let j = report.to_json();
        let fields = j.as_obj().expect("report must serialize to an object");
        let keys: Vec<&str> = fields.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            ["clean", "diagnostics", "files", "passes", "schema_version", "suppressed", "tool"],
            "schema keys are pinned — bump schema_version to change them"
        );
        let diag = j.get("diagnostics").and_then(|d| d.idx(0)).expect("one diagnostic");
        let dkeys: Vec<&str> =
            diag.as_obj().expect("diagnostic object").keys().map(|k| k.as_str()).collect();
        assert_eq!(dkeys, ["file", "line", "message", "pass", "rule", "severity"]);
        let text = j.to_string_pretty();
        for needle in [
            "\"tool\": \"flashmask-lint\"",
            "\"schema_version\": 1",
            "\"files\": 1",
            "\"suppressed\": 2",
            "\"clean\": false",
            "\"pass\": \"every-line\"",
            "\"rule\": \"hit\"",
            "\"file\": \"x.rs\"",
            "\"line\": 3",
            "\"severity\": \"warning\"",
        ] {
            assert!(text.contains(needle), "JSON missing {needle}: {text}");
        }
        // round-trips through the repo's JSON parser
        crate::util::json::parse(&text).expect("lint JSON must reparse");
    }

    #[test]
    fn render_is_file_line_addressed() {
        let d = Diagnostic {
            pass: "p",
            rule: "r",
            file: "a/b.rs".into(),
            line: 7,
            severity: Severity::Error,
            message: "msg".into(),
        };
        assert_eq!(d.render(), "a/b.rs:7: error [p:r] msg");
    }
}
