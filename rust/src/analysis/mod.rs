//! Project-native static analysis: `flashmask lint`.
//!
//! A lexer-driven invariant checker for the repo's own rules — the
//! ones `clippy` cannot know and the old `verify.sh` `awk`/`grep`
//! gates enforced only approximately:
//!
//! * kernel hot paths stay panic-free in release mode,
//! * the deprecated kernel entry points are called from tests only,
//! * library code logs through `telemetry::log`,
//! * telemetry names come from the central `telemetry::names` registry,
//! * `unsafe` is documented and allowlisted.
//!
//! Architecture (DESIGN.md §Static analysis):
//!
//! * [`lexer`] — a small Rust lexer that projects each source line
//!   into *code* (strings/comments blanked, same column layout),
//!   *comment* text, and an `in_test` flag from brace-tracked
//!   `#[cfg(test)]` regions.  Raw strings, nested block comments and
//!   lifetimes-vs-char-literals are handled; macro expansion is not —
//!   passes see the source a reviewer sees.
//! * [`engine`] — the [`Pass`](engine::Pass) trait, diagnostic
//!   collection with `file:line` rendering and stable JSON, and the
//!   suppression pragma `// lint: allow(pass[:rule]) — reason`
//!   (same line, the line above, or `allow-file(…)` for a whole file;
//!   the reason is mandatory).
//! * [`passes`] — the shipped passes and
//!   [`default_passes`](passes::default_passes).
//!
//! Entry points: `flashmask lint [--json] [paths…]` on the CLI (wired
//! into `scripts/verify.sh`), or [`lint`] from tests.

pub mod engine;
pub mod lexer;
pub mod passes;

pub use engine::{Diagnostic, Pass, Report, Severity};

use std::path::PathBuf;

/// Run the default pass set over `roots` (files or directories).
pub fn lint(roots: &[PathBuf]) -> Result<Report, String> {
    engine::run(roots, &passes::default_passes())
}

/// The tree the CLI lints when no paths are given: library sources,
/// benches, and examples, resolved against whichever of the repo-root
/// or crate-root layouts exists at runtime.
pub fn default_roots() -> Vec<PathBuf> {
    let candidates = [
        ["rust/src", "rust/benches", "examples"],
        ["src", "benches", "../examples"],
    ];
    for set in candidates {
        let found: Vec<PathBuf> =
            set.iter().map(PathBuf::from).filter(|p| p.is_dir()).collect();
        if !found.is_empty() {
            return found;
        }
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pass_set_is_complete_and_uniquely_named() {
        let passes = passes::default_passes();
        assert_eq!(passes.len(), 5);
        let mut names: Vec<&str> = passes.iter().map(|p| p.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(
            names,
            vec![
                "deprecated-shim",
                "direct-print",
                "hot-path-panic",
                "telemetry-names",
                "unsafe-hygiene"
            ]
        );
    }

    #[test]
    fn lint_accepts_an_explicit_file_root() {
        // lint() over a single clean in-repo file: the engine resolves
        // declared names from the built-in registry fallback
        let dir = std::env::temp_dir().join("flashmask-lint-modtest");
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("clean.rs");
        std::fs::write(&f, "pub fn ok() -> usize { 1 }\n").unwrap();
        let report = lint(&[f]).unwrap();
        assert!(report.clean(), "{:?}", report.diagnostics);
        assert_eq!(report.files, 1);
    }
}
