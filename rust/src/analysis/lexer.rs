//! A small Rust source lexer for the lint engine (DESIGN.md §Static
//! analysis).
//!
//! The old `verify.sh` gates were `awk`/`grep` pipelines that broke on
//! a `#[cfg(test)]` inside a string literal, a second test module, or
//! a multi-line comment.  This lexer walks the source once and
//! classifies every character as **code**, **comment** or **string**,
//! handling:
//!
//! * line comments and *nested* block comments (`/* /* */ */`);
//! * normal / byte strings with escapes, and raw / raw-byte strings
//!   with arbitrary hash fences (`r#"…"#`, `br##"…"##`);
//! * char literals vs lifetimes (`'a'` vs `<'a>`);
//! * attributes (`#[…]` / `#![…]`, nested brackets);
//! * `#[cfg(test)]` region tracking by brace depth, so nested test
//!   modules and test items anywhere in the file — not just a trailing
//!   `mod tests` — are recognized.
//!
//! The output is line-oriented: for every source line the lexer keeps
//! the raw text, a same-length `code` projection (comment characters
//! and string *contents* blanked with spaces, delimiters kept, so
//! column positions line up), the concatenated comment text, and an
//! `in_test` flag.  String literal bodies are collected separately
//! with their line/column so passes can inspect them without regex
//! games.

/// One string literal occurrence (the body, without delimiters).
#[derive(Clone, Debug)]
pub struct StrLit {
    /// 1-indexed line of the opening delimiter.
    pub line: usize,
    /// 0-indexed column (in characters) of the first delimiter char —
    /// the `"` for normal strings, the `r`/`b` prefix for raw/byte.
    pub col: usize,
    /// Literal body, escapes left as written.
    pub text: String,
}

/// Per-line lexing result.
#[derive(Clone, Debug, Default)]
pub struct LineInfo {
    /// The original line (no trailing newline).
    pub raw: String,
    /// Same length as `raw`: comment chars and string bodies replaced
    /// by spaces, everything else (incl. string delimiters) kept.
    pub code: String,
    /// Comment text on this line (both `//` and `/* */` parts).
    pub comment: String,
    /// True when any part of the line is inside (or is the attribute
    /// opening) a `#[cfg(test)]` region.
    pub in_test: bool,
}

/// A lexed source file.
#[derive(Clone, Debug)]
pub struct SourceFile {
    /// Path as given to [`lex`] (repo-relative in normal runs).
    pub path: String,
    pub lines: Vec<LineInfo>,
    /// Every string literal body, in source order.
    pub strings: Vec<StrLit>,
}

impl SourceFile {
    /// The string literal whose opening delimiter sits at
    /// `(line, col)` (1-indexed line, 0-indexed column).
    pub fn string_at(&self, line: usize, col: usize) -> Option<&StrLit> {
        self.strings.iter().find(|s| s.line == line && s.col == col)
    }
}

/// Lex `src`; `path` is carried through to diagnostics.
pub fn lex(path: &str, src: &str) -> SourceFile {
    Lexer::new(src).run(path)
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    // per-line builders
    raw: String,
    code: String,
    comment: String,
    line_in_test: bool,
    lines: Vec<LineInfo>,
    strings: Vec<StrLit>,
    // structure tracking
    depth: i64,
    /// Brace depths at which a `#[cfg(test)]` region opened; the
    /// region closes when `}` returns to that depth (a stack, so
    /// nested test modules just extend the enclosing region).
    test_regions: Vec<i64>,
    /// A `#[cfg(test)]` attribute was seen and its item has not yet
    /// opened a brace or ended with `;`.
    pending_test: bool,
}

impl Lexer {
    fn new(src: &str) -> Lexer {
        Lexer {
            chars: src.chars().collect(),
            i: 0,
            raw: String::new(),
            code: String::new(),
            comment: String::new(),
            line_in_test: false,
            lines: Vec::new(),
            strings: Vec::new(),
            depth: 0,
            test_regions: Vec::new(),
            pending_test: false,
        }
    }

    fn in_test(&self) -> bool {
        self.pending_test || !self.test_regions.is_empty()
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    /// Record a char as code (kept in the `code` projection).
    fn put_code(&mut self, c: char) {
        self.raw.push(c);
        self.code.push(c);
    }

    /// Record a char as non-code: blanked in `code`, optionally
    /// appended to the line's comment text.
    fn put_blank(&mut self, c: char, is_comment: bool) {
        self.raw.push(c);
        self.code.push(' ');
        if is_comment {
            self.comment.push(c);
        }
    }

    fn newline(&mut self) {
        self.line_in_test |= self.in_test();
        self.lines.push(LineInfo {
            raw: std::mem::take(&mut self.raw),
            code: std::mem::take(&mut self.code),
            comment: std::mem::take(&mut self.comment),
            in_test: self.line_in_test,
        });
        self.line_in_test = false;
    }

    fn cur_line(&self) -> usize {
        self.lines.len() + 1
    }

    fn cur_col(&self) -> usize {
        self.raw.chars().count()
    }

    fn run(mut self, path: &str) -> SourceFile {
        while let Some(c) = self.peek(0) {
            self.line_in_test |= self.in_test();
            match c {
                '\n' => {
                    self.i += 1;
                    self.newline();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => self.string(0, false),
                'b' if self.peek(1) == Some('"') => self.string(1, false),
                'r' | 'b' if self.raw_string_fence(c).is_some() => {
                    let (prefix, hashes) = self.raw_string_fence(c).unwrap_or((1, 0));
                    self.string(prefix + hashes, true);
                }
                '\'' => self.char_or_lifetime(),
                '#' => self.attribute_or_hash(),
                _ => {
                    self.code_char(c);
                    self.i += 1;
                }
            }
        }
        if !self.raw.is_empty() || !self.code.is_empty() {
            self.newline();
        }
        SourceFile { path: path.to_string(), lines: self.lines, strings: self.strings }
    }

    /// If a raw(-byte) string starts at `i`, return
    /// `(prefix_len, hash_count)` where `prefix_len` counts the
    /// `r`/`br` chars before the hashes.
    fn raw_string_fence(&self, c: char) -> Option<(usize, usize)> {
        let (prefix, mut j) = if c == 'r' {
            (1, self.i + 1)
        } else if c == 'b' && self.peek(1) == Some('r') {
            (2, self.i + 2)
        } else {
            return None;
        };
        // an identifier char before r"…" means this is e.g. `for"`…
        // impossible in valid Rust, but identifiers like `br` alone
        // must not trigger: require `"` after the hashes
        if self.i > 0 {
            if let Some(&p) = self.chars.get(self.i - 1) {
                if p.is_alphanumeric() || p == '_' {
                    return None;
                }
            }
        }
        let mut hashes = 0;
        while self.chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if self.chars.get(j) == Some(&'"') {
            Some((prefix, hashes))
        } else {
            None
        }
    }

    fn line_comment(&mut self) {
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            self.put_blank(c, true);
            self.i += 1;
        }
    }

    fn block_comment(&mut self) {
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.put_blank('/', true);
                self.put_blank('*', true);
                self.i += 2;
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.put_blank('*', true);
                self.put_blank('/', true);
                self.i += 2;
                if depth == 0 {
                    return;
                }
            } else if c == '\n' {
                self.i += 1;
                self.newline();
            } else {
                self.put_blank(c, true);
                self.i += 1;
            }
        }
    }

    /// Consume a string literal.  `fence` is the number of prefix
    /// chars before the opening quote (`r`/`b` plus hashes); `raw`
    /// selects raw-string (no escapes, closes on `"` + hashes)
    /// semantics.
    fn string(&mut self, fence: usize, raw: bool) {
        let lit_line = self.cur_line();
        let lit_col = self.cur_col();
        let hashes = if raw { fence.saturating_sub(1) } else { 0 };
        // emit the fence + opening quote as code (delimiters kept)
        for _ in 0..fence {
            let c = self.peek(0).unwrap_or('"');
            self.put_code(c);
            self.i += 1;
        }
        if self.peek(0) == Some('"') {
            self.put_code('"');
            self.i += 1;
        }
        let mut body = String::new();
        while let Some(c) = self.peek(0) {
            if !raw && c == '\\' {
                // escape: consume both chars as string body
                body.push(c);
                self.put_blank(c, false);
                self.i += 1;
                if let Some(e) = self.peek(0) {
                    if e == '\n' {
                        self.i += 1;
                        self.newline();
                    } else {
                        body.push(e);
                        self.put_blank(e, false);
                        self.i += 1;
                    }
                }
                continue;
            }
            if c == '"' {
                // raw strings need `"` + `#`*hashes to close
                let closes = if raw {
                    (1..=hashes).all(|k| self.peek(k) == Some('#'))
                } else {
                    true
                };
                if closes {
                    self.put_code('"');
                    self.i += 1;
                    for _ in 0..hashes {
                        self.put_code('#');
                        self.i += 1;
                    }
                    break;
                }
            }
            if c == '\n' {
                self.i += 1;
                self.newline();
                body.push('\n');
            } else {
                body.push(c);
                self.put_blank(c, false);
                self.i += 1;
            }
        }
        self.strings.push(StrLit { line: lit_line, col: lit_col, text: body });
    }

    fn char_or_lifetime(&mut self) {
        // '\x' escape or 'c' single char => char literal; else lifetime
        let is_char = match self.peek(1) {
            Some('\\') => true,
            Some(c) if c != '\'' => self.peek(2) == Some('\''),
            _ => false,
        };
        if !is_char {
            self.put_code('\'');
            self.i += 1;
            return;
        }
        self.put_code('\'');
        self.i += 1;
        while let Some(c) = self.peek(0) {
            if c == '\\' {
                self.put_blank(c, false);
                self.i += 1;
                if let Some(e) = self.peek(0) {
                    self.put_blank(e, false);
                    self.i += 1;
                }
                continue;
            }
            if c == '\'' {
                self.put_code('\'');
                self.i += 1;
                break;
            }
            self.put_blank(c, false);
            self.i += 1;
        }
    }

    /// `#[…]` / `#![…]` attribute: captured to spot `#[cfg(test)]`.
    /// A bare `#` (raw-string fences are consumed elsewhere) falls
    /// through as a plain code char.
    fn attribute_or_hash(&mut self) {
        let bang = self.peek(1) == Some('!');
        let open = if bang { 2 } else { 1 };
        if self.peek(open) != Some('[') {
            self.put_code('#');
            self.i += 1;
            return;
        }
        self.put_code('#');
        self.i += 1;
        if bang {
            self.put_code('!');
            self.i += 1;
        }
        self.put_code('[');
        self.i += 1;
        let mut text = String::new();
        let mut brackets = 1usize;
        while let Some(c) = self.peek(0) {
            match c {
                '[' => brackets += 1,
                ']' => {
                    brackets -= 1;
                    if brackets == 0 {
                        self.put_code(']');
                        self.i += 1;
                        break;
                    }
                }
                '\n' => {
                    self.i += 1;
                    self.newline();
                    continue;
                }
                _ => {}
            }
            text.push(c);
            self.put_code(c);
            self.i += 1;
        }
        let normalized: String = text.chars().filter(|c| !c.is_whitespace()).collect();
        if !bang && normalized == "cfg(test)" {
            self.pending_test = true;
            self.line_in_test = true;
        }
    }

    fn code_char(&mut self, c: char) {
        match c {
            '{' => {
                if self.pending_test {
                    self.test_regions.push(self.depth);
                    self.pending_test = false;
                }
                self.depth += 1;
            }
            '}' => {
                self.depth -= 1;
                if self.test_regions.last() == Some(&self.depth) {
                    self.test_regions.pop();
                    // the closing `}` line itself still counts as test
                    self.line_in_test = true;
                }
            }
            ';' => {
                // `#[cfg(test)] use …;` — a braceless test item ends
                // at the semicolon (only when no brace opened first)
                if self.pending_test {
                    self.pending_test = false;
                    self.line_in_test = true;
                }
            }
            _ => {}
        }
        self.put_code(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex("t.rs", src).lines.iter().map(|l| l.code.clone()).collect()
    }

    #[test]
    fn comments_are_blanked() {
        let f = lex("t.rs", "let x = 1; // unwrap() here\n/* panic!() */ let y = 2;\n");
        assert!(!f.lines[0].code.contains("unwrap"));
        assert!(f.lines[0].comment.contains("unwrap()"));
        assert!(!f.lines[1].code.contains("panic"));
        assert!(f.lines[1].code.contains("let y = 2;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still comment */ b\nc /* open\nstill\n*/ d\n";
        let code = code_of(src);
        assert!(code[0].contains('a') && code[0].contains('b'));
        assert!(!code[0].contains("still comment"));
        assert!(!code[1].contains("open"));
        assert!(!code[2].contains("still"));
        assert!(code[3].contains('d'));
    }

    #[test]
    fn strings_are_blanked_but_collected() {
        let f = lex("t.rs", "let s = \"println!(\\\"x\\\")\"; call();\n");
        assert!(!f.lines[0].code.contains("println"));
        assert!(f.lines[0].code.contains("call();"));
        assert_eq!(f.strings.len(), 1);
        assert_eq!(f.strings[0].text, "println!(\\\"x\\\")");
        assert_eq!(f.strings[0].line, 1);
    }

    #[test]
    fn raw_strings_with_fences() {
        let f = lex("t.rs", "let s = r#\"has \"quotes\" and #[cfg(test)]\"#; next();\n");
        assert!(!f.lines[0].code.contains("cfg(test)"));
        assert!(f.lines[0].code.contains("next();"));
        assert_eq!(f.strings[0].text, "has \"quotes\" and #[cfg(test)]");
        assert!(!f.lines[0].in_test, "cfg(test) inside a string must not open a region");
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let f = lex("t.rs", "let a = b\"bytes\"; let b2 = br##\"raw # bytes\"##; go();\n");
        assert_eq!(f.strings.len(), 2);
        assert_eq!(f.strings[0].text, "bytes");
        assert_eq!(f.strings[1].text, "raw # bytes");
        assert!(f.lines[0].code.contains("go();"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let f = lex("t.rs", "fn f<'a>(x: &'a str) { let c = '\"'; let d = '\\''; g(); }\n");
        // the quote inside the char literal must not open a string
        assert!(f.strings.is_empty());
        assert!(f.lines[0].code.contains("g();"));
    }

    #[test]
    fn cfg_test_region_tracks_braces() {
        let src = "\
fn live() { x.unwrap(); }
#[cfg(test)]
mod tests {
    fn t() { y.unwrap(); }
}
fn live_again() { z.unwrap(); }
";
        let f = lex("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test, "the attribute line itself");
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test, "closing brace line");
        assert!(!f.lines[5].in_test, "a second live region after the test mod");
    }

    #[test]
    fn nested_modules_inside_test_region() {
        let src = "\
#[cfg(test)]
mod tests {
    mod inner {
        fn t() {}
    }
}
fn live() {}
";
        let f = lex("t.rs", src);
        for l in 0..6 {
            assert!(f.lines[l].in_test, "line {} should be in the test region", l + 1);
        }
        assert!(!f.lines[6].in_test);
    }

    #[test]
    fn braceless_test_item_ends_at_semicolon() {
        let src = "#[cfg(test)]\nuse crate::thing;\nfn live() {}\n";
        let f = lex("t.rs", src);
        assert!(f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn cfg_test_in_comment_or_string_is_inert() {
        let src = "// #[cfg(test)]\nlet s = \"#[cfg(test)]\";\nfn live() {}\n";
        let f = lex("t.rs", src);
        assert!(f.lines.iter().all(|l| !l.in_test));
    }

    #[test]
    fn code_projection_preserves_columns() {
        let src = "reg.add(\"decode.steps\", 1);\n";
        let f = lex("t.rs", src);
        assert_eq!(f.lines[0].code.chars().count(), f.lines[0].raw.chars().count());
        let col = f.lines[0].raw.find('"').unwrap_or_default();
        assert!(f.string_at(1, col).is_some());
        assert_eq!(f.string_at(1, col).map(|s| s.text.as_str()), Some("decode.steps"));
    }

    #[test]
    fn attribute_capture_handles_inner_and_nested() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\n#[cfg_attr(test, allow(dead_code))]\nfn live() {}\n";
        let f = lex("t.rs", src);
        assert!(f.lines.iter().all(|l| !l.in_test), "near-miss attributes must not open regions");
    }
}
