//! The shipped lint passes (DESIGN.md §Static analysis).
//!
//! | pass | guards |
//! |---|---|
//! | [`hot_path`] `hot-path-panic` | no release-mode panic sites in the kernel hot paths |
//! | [`deprecated`] `deprecated-shim` | legacy kernel entry points called from tests only |
//! | [`print`] `direct-print` | library code logs through `telemetry::log` |
//! | [`telemetry_names`] `telemetry-names` | metric/span/log-target literals are declared in `telemetry::names` |
//! | [`unsafe_hygiene`] `unsafe-hygiene` | every `unsafe` carries a `// SAFETY:` contract and sits on the allowlist |
//!
//! Each pass works on the [`lexer`](super::lexer) projection, so names
//! in comments, strings or `#[cfg(test)]` regions never trip it — the
//! failure modes of the old `awk`/`grep` gates these passes replace.

pub mod deprecated;
pub mod hot_path;
pub mod print;
pub mod telemetry_names;
pub mod unsafe_hygiene;

use super::engine::Pass;

/// Every shipped pass, in reporting order.
pub fn default_passes() -> Vec<Box<dyn Pass>> {
    vec![
        Box::new(hot_path::HotPathPanic),
        Box::new(deprecated::DeprecatedShim),
        Box::new(print::DirectPrint),
        Box::new(telemetry_names::TelemetryNames),
        Box::new(unsafe_hygiene::UnsafeHygiene),
    ]
}

pub(crate) fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of `tok` in `code` whose preceding char is not an
/// identifier char (so `debug_assert!(` never matches `assert!(`).
pub(crate) fn find_token(code: &str, tok: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(tok) {
        let pos = from + rel;
        let bounded = code[..pos].chars().next_back().map(|c| !is_ident(c)).unwrap_or(true);
        if bounded {
            out.push(pos);
        }
        from = pos + tok.len().max(1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_token_respects_left_boundary() {
        assert_eq!(find_token("assert!(x); debug_assert!(y);", "assert!(").len(), 1);
        assert_eq!(find_token("sparsity_histogram(n)", "histogram(").len(), 0);
        assert_eq!(find_token("reg.histogram(name)", "histogram(").len(), 1);
    }
}
