//! `hot-path-panic` — panic-freedom for the kernel hot paths.
//!
//! FlashAttention-style kernels fail as silent numeric drift, not
//! crashes, so the repo leans on `debug_assert!` + typed `AttnError`
//! returns; a release-mode panic site in the tile/step/verify loops
//! means a malformed-but-validated input can abort a live serve batch.
//! This pass bans `unwrap`/`expect`/`panic!`-family macros/`assert!`
//! and (as the `index` sub-rule) `[]` indexing in the designated
//! kernel modules.  Remaining sites are either converted in-tree or
//! carry a reasoned pragma, e.g. the deprecated shims' `.expect(`
//! calls on an already-validated argument pack.
//!
//! The `index` sub-rule is lexical — it cannot see types, so it flags
//! every `expr[` site.  The kernel files suppress it file-wide with
//! `// lint: allow-file(hot-path-panic:index) — …` pragmas whose
//! reasons document the schedule invariants that bound the indices;
//! the rule stays on so *new* kernel modules must either use `get` or
//! write the same justification down.

use crate::analysis::engine::{Context, Diagnostic, Pass, Severity};
use crate::analysis::lexer::SourceFile;
use crate::analysis::passes::{find_token, is_ident};

/// Kernel modules under the panic-freedom contract.
const HOT_PATHS: &[&str] = &[
    "attention/gemm.rs",
    "attention/flash.rs",
    "decode/step.rs",
    "decode/spec.rs",
];

pub struct HotPathPanic;

impl Pass for HotPathPanic {
    fn name(&self) -> &'static str {
        "hot-path-panic"
    }

    fn description(&self) -> &'static str {
        "no unwrap/expect/panic!/assert!/[]-indexing in kernel hot-path modules"
    }

    fn applies(&self, path: &str) -> bool {
        HOT_PATHS.iter().any(|p| path.ends_with(p))
    }

    fn run(&self, file: &SourceFile, _ctx: &Context, out: &mut Vec<Diagnostic>) {
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = &line.code;
            let mut push = |rule: &'static str, what: &str| {
                out.push(Diagnostic {
                    pass: "hot-path-panic",
                    rule,
                    file: file.path.clone(),
                    line: idx + 1,
                    severity: Severity::Error,
                    message: format!(
                        "{what} in a kernel hot path — use debug_assert!, a typed \
                         AttnError return, or a reasoned pragma"
                    ),
                });
            };
            if code.contains(".unwrap(") {
                push("unwrap", "`.unwrap()`");
            }
            if code.contains(".expect(") {
                push("expect", "`.expect()`");
            }
            for tok in ["panic!(", "unreachable!(", "todo!(", "unimplemented!("] {
                if !find_token(code, tok).is_empty() {
                    push("panic", "a panicking macro");
                }
            }
            for tok in ["assert!(", "assert_eq!(", "assert_ne!("] {
                if !find_token(code, tok).is_empty() {
                    push("assert", "release-mode `assert!`");
                }
            }
            // `expr[` — identifier / `)` / `]` immediately followed by
            // `[` is indexing (panics on out-of-bounds); `#[`, `vec![`,
            // types and slice patterns are preceded by non-value chars
            let mut prev = ' ';
            for c in code.chars() {
                if c == '[' && (is_ident(prev) || prev == ')' || prev == ']') {
                    push("index", "`[]` indexing (no `get`)");
                    break;
                }
                prev = c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use std::collections::BTreeSet;

    fn run_on(src: &str) -> Vec<Diagnostic> {
        let file = lex("rust/src/attention/gemm.rs", src);
        let ctx = Context { declared_names: BTreeSet::new() };
        let mut out = Vec::new();
        HotPathPanic.run(&file, &ctx, &mut out);
        out
    }

    #[test]
    fn tripping_fixture_flags_each_rule() {
        let diags = run_on(
            "fn hot(v: &[f32], i: usize) -> f32 {\n\
             \x20   let a = v.first().unwrap();\n\
             \x20   let b: &f32 = v.get(1).expect(\"b\");\n\
             \x20   assert!(i < v.len());\n\
             \x20   assert_eq!(*a, *b);\n\
             \x20   if i > 9 { panic!(\"bad\"); }\n\
             \x20   v[i]\n\
             }\n",
        );
        let rules: BTreeSet<&str> = diags.iter().map(|d| d.rule).collect();
        for r in ["unwrap", "expect", "assert", "panic", "index"] {
            assert!(rules.contains(r), "rule {r} must trip: {diags:?}");
        }
    }

    #[test]
    fn near_miss_fixture_stays_clean() {
        // the banned names in a comment, in a string, below
        // #[cfg(test)], and as their debug_* / *_or cousins
        let diags = run_on(
            "// calling unwrap() or panic!() here would be bad\n\
             fn hot(v: &[f32]) -> f32 {\n\
             \x20   let msg = \"never .unwrap() nor assert!(x) nor v[i]\";\n\
             \x20   debug_assert!(!v.is_empty(), \"{}\", msg);\n\
             \x20   debug_assert_eq!(msg.len() > 0, true);\n\
             \x20   v.first().copied().unwrap_or(0.0)\n\
             }\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   #[test]\n\
             \x20   fn t() { let v = [1.0f32]; assert_eq!(v[0].to_bits(), 1.0f32.to_bits()); }\n\
             }\n",
        );
        assert!(diags.is_empty(), "near-miss fixture tripped: {diags:?}");
    }

    #[test]
    fn applies_only_to_kernel_modules() {
        assert!(HotPathPanic.applies("rust/src/attention/gemm.rs"));
        assert!(HotPathPanic.applies("rust/src/decode/spec.rs"));
        assert!(!HotPathPanic.applies("rust/src/server/router.rs"));
        assert!(!HotPathPanic.applies("rust/src/coordinator/checkpoint.rs"));
    }
}
