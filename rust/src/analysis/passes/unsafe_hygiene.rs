//! `unsafe-hygiene` — every `unsafe` carries a written contract and
//! sits on the crate allowlist.
//!
//! The crate is std-only scalar code; it needs `unsafe` in exactly one
//! place (the checkpoint writer's byte-level f32 serialization, see
//! `coordinator/checkpoint.rs`).  Two rules:
//!
//! * `missing-safety` — an `unsafe` keyword without a `// SAFETY:`
//!   comment on the same line or in the comment block just above (at
//!   most [`SAFETY_CODE_GAP`] code lines away; comment lines don't
//!   count, so a long contract stays adjacent).  The contract must say
//!   *why* the invariants hold, next to the code that relies on them.
//! * `not-allowlisted` — `unsafe` in any file other than
//!   `coordinator/checkpoint.rs`.  New unsafe code must extend the
//!   allowlist here, which puts the decision in review where it
//!   belongs instead of letting it slip in silently.
//!
//! `#![deny(unsafe_op_in_unsafe_fn)]` and other identifiers that merely
//! *contain* `unsafe` never match: the keyword is detected with word
//! boundaries on both sides.

use crate::analysis::engine::{Context, Diagnostic, Pass, Severity};
use crate::analysis::lexer::SourceFile;
use crate::analysis::passes::{find_token, is_ident};

/// Files allowed to contain `unsafe` at all.
const ALLOWLIST: &[&str] = &["coordinator/checkpoint.rs"];

/// How many *code* lines may sit between an `unsafe` site and its
/// `// SAFETY:` contract.  Comment-only lines are traversed freely, so
/// a multi-line contract stays adjacent however long it runs.
const SAFETY_CODE_GAP: usize = 3;

pub struct UnsafeHygiene;

impl Pass for UnsafeHygiene {
    fn name(&self) -> &'static str {
        "unsafe-hygiene"
    }

    fn description(&self) -> &'static str {
        "every `unsafe` needs an adjacent // SAFETY: contract and an allowlist entry"
    }

    fn applies(&self, _path: &str) -> bool {
        true
    }

    fn run(&self, file: &SourceFile, _ctx: &Context, out: &mut Vec<Diagnostic>) {
        let allowlisted = ALLOWLIST.iter().any(|p| file.path.ends_with(p));
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = &line.code;
            let hit = find_token(code, "unsafe").into_iter().any(|pos| {
                // right boundary: `unsafe fn` / `unsafe {` yes,
                // `unsafe_op_in_unsafe_fn` no
                code[pos + "unsafe".len()..]
                    .chars()
                    .next()
                    .map(|c| !is_ident(c))
                    .unwrap_or(true)
            });
            if !hit {
                continue;
            }
            let mut documented = line.comment.contains("SAFETY:");
            let mut budget = SAFETY_CODE_GAP;
            let mut j = idx;
            while !documented && j > 0 {
                j -= 1;
                let above = &file.lines[j];
                if above.comment.contains("SAFETY:") {
                    documented = true;
                    break;
                }
                // comment-only lines extend the contract block for
                // free; code or blank lines burn the gap budget
                let comment_only =
                    !above.comment.trim().is_empty() && above.code.trim().is_empty();
                if !comment_only {
                    if budget == 0 {
                        break;
                    }
                    budget -= 1;
                }
            }
            if !documented {
                out.push(Diagnostic {
                    pass: "unsafe-hygiene",
                    rule: "missing-safety",
                    file: file.path.clone(),
                    line: idx + 1,
                    severity: Severity::Error,
                    message: "`unsafe` without an adjacent `// SAFETY:` contract — \
                              state why the invariants hold"
                        .to_string(),
                });
            }
            if !allowlisted {
                out.push(Diagnostic {
                    pass: "unsafe-hygiene",
                    rule: "not-allowlisted",
                    file: file.path.clone(),
                    line: idx + 1,
                    severity: Severity::Error,
                    message: "`unsafe` outside the crate allowlist \
                              (coordinator/checkpoint.rs) — extend the allowlist in \
                              analysis::passes::unsafe_hygiene if this is deliberate"
                        .to_string(),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use std::collections::BTreeSet;

    fn run_on(path: &str, src: &str) -> Vec<Diagnostic> {
        let file = lex(path, src);
        let ctx = Context { declared_names: BTreeSet::new() };
        let mut out = Vec::new();
        UnsafeHygiene.run(&file, &ctx, &mut out);
        out
    }

    #[test]
    fn tripping_fixture_flags_undocumented_and_off_allowlist() {
        let diags = run_on(
            "rust/src/attention/gemm.rs",
            "fn f(v: &[f32]) -> f32 {\n\
             \x20   unsafe { *v.get_unchecked(0) }\n\
             }\n",
        );
        let rules: BTreeSet<&str> = diags.iter().map(|d| d.rule).collect();
        assert!(rules.contains("missing-safety"), "{diags:?}");
        assert!(rules.contains("not-allowlisted"), "{diags:?}");
        assert!(diags.iter().all(|d| d.line == 2));
    }

    #[test]
    fn near_miss_fixture_stays_clean() {
        // `unsafe` in a comment, in a string, inside a larger
        // identifier (the deny attribute), and below #[cfg(test)]
        let diags = run_on(
            "rust/src/coordinator/checkpoint.rs",
            "#![deny(unsafe_op_in_unsafe_fn)]\n\
             // unsafe would be needed for get_unchecked\n\
             fn f() {\n\
             \x20   let doc = \"unsafe { } in a string\";\n\
             \x20   let _ = doc;\n\
             }\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   fn t() { let x = [0u8; 4]; let _ = unsafe { std::mem::transmute::<_, f32>(x) }; }\n\
             }\n",
        );
        assert!(diags.is_empty(), "near-miss fixture tripped: {diags:?}");
    }

    #[test]
    fn documented_allowlisted_unsafe_is_clean() {
        let diags = run_on(
            "rust/src/coordinator/checkpoint.rs",
            "fn f(bytes: &[u8]) -> f32 {\n\
             \x20   // SAFETY: the caller guarantees `bytes` holds at least four\n\
             \x20   // bytes of a little-endian f32 (checked by the header parser).\n\
             \x20   unsafe { read_f32(bytes) }\n\
             }\n",
        );
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn long_contract_block_plus_binding_line_still_counts() {
        // the checkpoint.rs shape: a many-line SAFETY comment, then a
        // `let` binding line, then the `unsafe` expression
        let diags = run_on(
            "rust/src/coordinator/checkpoint.rs",
            "fn f(data: &[f32]) -> &[u8] {\n\
             \x20   // SAFETY: `data` is a live &[f32], so the pointer is valid\n\
             \x20   // for len*4 bytes, u8 has no alignment requirement, and\n\
             \x20   // every byte of an f32 is initialized plain-old-data.\n\
             \x20   // The borrow outlives the produced slice.\n\
             \x20   let bytes: &[u8] =\n\
             \x20       unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };\n\
             \x20   bytes\n\
             }\n",
        );
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn safety_comment_too_far_above_does_not_count() {
        let diags = run_on(
            "rust/src/coordinator/checkpoint.rs",
            "// SAFETY: too far away to bind to the site below\n\
             fn a() {}\n\
             fn b() {}\n\
             fn c() {}\n\
             fn f(bytes: &[u8]) -> f32 {\n\
             \x20   unsafe { read_f32(bytes) }\n\
             }\n",
        );
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, "missing-safety");
    }
}
