//! `deprecated-shim` — the legacy kernel entry points are callable
//! from tests only.
//!
//! PR 5 unified the ~10 parallel kernel entry points behind
//! `attention::api`; the old free functions survive as deprecated
//! shims doubling as migration oracles.  Non-test code must go through
//! the API.  This supersedes the `verify.sh` awk gate, which stripped
//! everything from the *first* `#[cfg(test)]` line — wrong for a
//! second test module, a `cfg(test)` inside a string, or a call above
//! a mid-file test item.
//!
//! Exemptions mirror the old gate: `fn name(` definition lines,
//! `.name(` method calls (the `Backend` trait methods share the free
//! functions' names — a leading dot marks the new API), and
//! `attention/api.rs` itself (the shims' implementation target).

use crate::analysis::engine::{Context, Diagnostic, Pass, Severity};
use crate::analysis::lexer::SourceFile;
use crate::analysis::passes::find_token;

/// The deprecated free functions (see `attention::flash`,
/// `attention::dense`, `decode::step`, `decode::spec`).
const DEPRECATED: &[&str] = &[
    "flashmask_forward",
    "flashmask_forward_grouped",
    "flashmask_forward_grouped_parallel",
    "flashmask_backward",
    "dense_forward",
    "dense_forward_grouped",
    "dense_forward_grouped_parallel",
    "decode_step",
    "decode_step_group",
    "verify_rows",
    "verify_rows_group",
    "forward_single_head",
];

pub struct DeprecatedShim;

impl Pass for DeprecatedShim {
    fn name(&self) -> &'static str {
        "deprecated-shim"
    }

    fn description(&self) -> &'static str {
        "deprecated kernel entry points are called from tests only (use attention::api)"
    }

    fn applies(&self, path: &str) -> bool {
        // integration tests (rust/tests/) are whole-file test code with
        // no #[cfg(test)] marker — they are the shims' migration
        // oracles, exempt like in-module test regions
        !path.ends_with("attention/api.rs") && !path.contains("/tests/")
    }

    fn run(&self, file: &SourceFile, _ctx: &Context, out: &mut Vec<Diagnostic>) {
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = &line.code;
            for name in DEPRECATED {
                let tok = format!("{name}(");
                for pos in find_token(code, &tok) {
                    let before = code[..pos].trim_end();
                    // `fn name(` / `pub fn name(` — the definition
                    if before.ends_with("fn") {
                        continue;
                    }
                    // `.name(` — a Backend trait method, the new API
                    if before.ends_with('.') {
                        continue;
                    }
                    out.push(Diagnostic {
                        pass: "deprecated-shim",
                        rule: "call",
                        file: file.path.clone(),
                        line: idx + 1,
                        severity: Severity::Error,
                        message: format!(
                            "non-test call to deprecated `{name}` — migrate to \
                             attention::api (DESIGN.md §Public API)"
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use std::collections::BTreeSet;

    fn run_on(src: &str) -> Vec<Diagnostic> {
        let file = lex("rust/src/somewhere.rs", src);
        let ctx = Context { declared_names: BTreeSet::new() };
        let mut out = Vec::new();
        DeprecatedShim.run(&file, &ctx, &mut out);
        out
    }

    #[test]
    fn tripping_fixture_flags_live_calls() {
        let diags = run_on(
            "fn caller() {\n\
             \x20   let o = flashmask_forward(&q, &k, &v, n, d, &mask, 64, 64, true);\n\
             \x20   let r = flash::decode_step_group(&q, 2, &cache);\n\
             }\n",
        );
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().any(|d| d.message.contains("flashmask_forward")));
        assert!(diags.iter().any(|d| d.message.contains("decode_step_group")));
    }

    #[test]
    fn near_miss_fixture_stays_clean() {
        let diags = run_on(
            "//! Shims over [`flashmask_forward`] live here; dense_forward(q) in docs.\n\
             pub fn flashmask_forward(q: &[f32]) {}\n\
             fn new_api(b: &dyn Backend) {\n\
             \x20   let s = \"decode_step(q) inside a string\";\n\
             \x20   b.decode_step(pack, stats, scratch);\n\
             \x20   b . verify_rows(pack);\n\
             }\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   fn t() { let _ = verify_rows_group(&q, 2); }\n\
             }\n",
        );
        assert!(diags.is_empty(), "near-miss fixture tripped: {diags:?}");
    }

    #[test]
    fn second_test_module_is_still_exempt() {
        // the old awk gate only stripped from the FIRST #[cfg(test)];
        // a live call *between* two test modules must still trip
        let diags = run_on(
            "#[cfg(test)]\n\
             mod early_tests { fn t() { decode_step(&q); } }\n\
             fn live() { decode_step(&q); }\n\
             #[cfg(test)]\n\
             mod late_tests { fn t() { decode_step(&q); } }\n",
        );
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 3);
    }

    #[test]
    fn api_rs_and_integration_tests_are_exempt() {
        assert!(!DeprecatedShim.applies("rust/src/attention/api.rs"));
        assert!(!DeprecatedShim.applies("rust/tests/api_misuse.rs"));
        assert!(DeprecatedShim.applies("rust/src/attention/flash.rs"));
        assert!(DeprecatedShim.applies("rust/benches/bench_decode.rs"));
    }
}
