//! `direct-print` — library code logs through `telemetry::log`.
//!
//! Ad-hoc `println!`/`eprintln!` bypass the leveled logger and its
//! test capture.  Exempt (their stdout/stderr *is* the product): the
//! CLI binary, the report/table printers, and `telemetry::log` itself
//! (the logger's stderr sink).  Supersedes the `verify.sh` print grep,
//! which could not tell a call from a mention in a comment or string.

use crate::analysis::engine::{Context, Diagnostic, Pass, Severity};
use crate::analysis::lexer::SourceFile;
use crate::analysis::passes::find_token;

/// Files whose direct prints are the product, not stray logging.
const EXEMPT: &[&str] = &[
    "rust/src/main.rs",
    "rust/src/reports.rs",
    "rust/src/util/table.rs",
    "rust/src/telemetry/log.rs",
];

pub struct DirectPrint;

impl Pass for DirectPrint {
    fn name(&self) -> &'static str {
        "direct-print"
    }

    fn description(&self) -> &'static str {
        "library code must log via telemetry::log, not println!/eprintln!"
    }

    fn applies(&self, path: &str) -> bool {
        // library sources only — benches/examples/tests print tables
        // by design, exactly like the old gate's `find rust/src` scope
        (path.contains("rust/src/") || path.starts_with("src/"))
            && !EXEMPT.iter().any(|e| path.ends_with(e.trim_start_matches("rust/")))
    }

    fn run(&self, file: &SourceFile, _ctx: &Context, out: &mut Vec<Diagnostic>) {
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for tok in ["println!", "eprintln!", "print!", "eprint!"] {
                if !find_token(&line.code, tok).is_empty() {
                    out.push(Diagnostic {
                        pass: "direct-print",
                        rule: "print",
                        file: file.path.clone(),
                        line: idx + 1,
                        severity: Severity::Error,
                        message: format!(
                            "`{tok}` from library code — route through telemetry::log \
                             (DESIGN.md §Telemetry)"
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use std::collections::BTreeSet;

    fn run_on(src: &str) -> Vec<Diagnostic> {
        let file = lex("rust/src/server/engine.rs", src);
        let ctx = Context { declared_names: BTreeSet::new() };
        let mut out = Vec::new();
        DirectPrint.run(&file, &ctx, &mut out);
        out
    }

    #[test]
    fn tripping_fixture_flags_prints() {
        let diags =
            run_on("fn f() {\n    println!(\"x\");\n    eprintln!(\"y = {}\", 2);\n}\n");
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert_eq!(diags[0].line, 2);
        assert_eq!(diags[1].line, 3);
    }

    #[test]
    fn near_miss_fixture_stays_clean() {
        let diags = run_on(
            "// println! would bypass the logger\n\
             fn f() {\n\
             \x20   let doc = \"use println!(\\\"x\\\") in examples\";\n\
             \x20   crate::telemetry::log::info(\"serve\", doc);\n\
             }\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   fn t() { println!(\"test output is fine\"); }\n\
             }\n",
        );
        assert!(diags.is_empty(), "near-miss fixture tripped: {diags:?}");
    }

    #[test]
    fn exempt_files_do_not_apply() {
        assert!(!DirectPrint.applies("rust/src/main.rs"));
        assert!(!DirectPrint.applies("rust/src/reports.rs"));
        assert!(!DirectPrint.applies("rust/src/util/table.rs"));
        assert!(!DirectPrint.applies("rust/src/telemetry/log.rs"));
        assert!(DirectPrint.applies("rust/src/telemetry/metrics.rs"));
        assert!(!DirectPrint.applies("rust/benches/bench_train.rs"));
    }
}
