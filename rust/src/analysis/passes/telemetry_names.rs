//! `telemetry-names` — metric/span/log-target literals must be
//! declared in [`crate::telemetry::names`].
//!
//! A typo'd literal (`"decode.page_total"`) silently forks a metric
//! series; this pass kills that statically.  Checked call shapes:
//!
//! * `counter("…")` / `gauge("…")` / `histogram("…")` /
//!   `observe_ms("…")` / `span("…")` — the literal must be a declared
//!   name and follow the dotted `layer.noun[.verb]` scheme;
//! * `add("…", …)` — only when the literal **contains a dot**: that is
//!   a `Registry::add` metric name.  Dotless `add` literals are
//!   `SpanGuard::add` attribute keys, scoped to their span and
//!   deliberately unregistered;
//! * `log::info(/warn(/error(/debug("…", …)` — the target literal
//!   must be a declared single-word target.
//!
//! Call sites that already use a `names::` const produce no literal
//! and pass vacuously — the migration plus this pass pin the registry
//! closed.  The declared set is parsed from the linted tree's
//! `telemetry/names.rs` (falling back to the built-in registry), so
//! adding a name and its call site in one commit lints clean.

use crate::analysis::engine::{Context, Diagnostic, Pass, Severity};
use crate::analysis::lexer::SourceFile;
use crate::analysis::passes::find_token;

/// Call tokens whose first argument is a metric/span name.
const NAME_CALLS: &[&str] = &["counter(", "gauge(", "histogram(", "observe_ms(", "span("];

/// `log::`-qualified level helpers whose first argument is a target.
const LOG_CALLS: &[&str] = &["info(", "warn(", "error(", "debug("];

pub struct TelemetryNames;

impl Pass for TelemetryNames {
    fn name(&self) -> &'static str {
        "telemetry-names"
    }

    fn description(&self) -> &'static str {
        "metric/span/log-target literals are declared in telemetry::names"
    }

    fn applies(&self, path: &str) -> bool {
        // library sources only, minus the registry itself (it is the
        // declaration site) and the metrics/trace plumbing that takes
        // caller-supplied names by reference
        (path.contains("rust/src/") || path.starts_with("src/"))
            && !path.ends_with("telemetry/names.rs")
    }

    fn run(&self, file: &SourceFile, ctx: &Context, out: &mut Vec<Diagnostic>) {
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = &line.code;
            for tok in NAME_CALLS {
                for pos in find_token(code, tok) {
                    if let Some(lit) = literal_after(file, idx, char_col(code, pos + tok.len())) {
                        check_metric(file, idx, &lit, ctx, out);
                    }
                }
            }
            for tok in LOG_CALLS {
                for pos in find_token(code, tok) {
                    if !code[..pos].ends_with("log::") {
                        continue;
                    }
                    if let Some(lit) = literal_after(file, idx, char_col(code, pos + tok.len())) {
                        check_target(file, idx, &lit, ctx, out);
                    }
                }
            }
            // Registry::add("layer.metric", δ): a literal first
            // argument with a dot is a metric name (SpanGuard::add
            // attribute keys are dotless)
            for pos in find_token(code, "add(") {
                if let Some(lit) = literal_after(file, idx, char_col(code, pos + "add(".len())) {
                    if lit.contains('.') {
                        check_metric(file, idx, &lit, ctx, out);
                    }
                }
            }
        }
    }
}

/// Byte offset → char column (the lexer records char columns).
fn char_col(code: &str, byte_pos: usize) -> usize {
    code[..byte_pos].chars().count()
}

/// The string literal opening at or after `(line, col)`, skipping
/// whitespace — across line breaks, so `log::warn(\n "router", …)`
/// still resolves.  `None` when the first argument is not a literal
/// (a `names::` const — nothing to check).
fn literal_after(file: &SourceFile, line: usize, col: usize) -> Option<String> {
    let mut li = line;
    let mut ci = col;
    // look at most a few lines ahead: arguments broken further than
    // that are not a formatting style this codebase uses
    for _ in 0..4 {
        let l = file.lines.get(li)?;
        for (c_idx, c) in l.code.chars().enumerate().skip(ci) {
            if c.is_whitespace() {
                continue;
            }
            if c == '"' {
                return file.string_at(li + 1, c_idx).map(|s| s.text.clone());
            }
            return None;
        }
        li += 1;
        ci = 0;
    }
    None
}

fn scheme_ok_metric(n: &str) -> bool {
    n.contains('.')
        && n.split('.').all(|seg| {
            !seg.is_empty()
                && seg.chars().next().map(|c| c.is_ascii_lowercase()).unwrap_or(false)
                && seg.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        })
}

fn check_metric(
    file: &SourceFile,
    idx: usize,
    lit: &str,
    ctx: &Context,
    out: &mut Vec<Diagnostic>,
) {
    if !ctx.declared_names.contains(lit) {
        out.push(Diagnostic {
            pass: "telemetry-names",
            rule: "undeclared",
            file: file.path.clone(),
            line: idx + 1,
            severity: Severity::Error,
            message: format!(
                "telemetry name \"{lit}\" is not declared in telemetry::names — \
                 declare it there and use the const"
            ),
        });
    }
    if !scheme_ok_metric(lit) {
        out.push(Diagnostic {
            pass: "telemetry-names",
            rule: "scheme",
            file: file.path.clone(),
            line: idx + 1,
            severity: Severity::Warning,
            message: format!(
                "telemetry name \"{lit}\" breaks the dotted lowercase \
                 `layer.noun[.verb]` scheme"
            ),
        });
    }
}

fn check_target(
    file: &SourceFile,
    idx: usize,
    lit: &str,
    ctx: &Context,
    out: &mut Vec<Diagnostic>,
) {
    if !ctx.declared_names.contains(lit) {
        out.push(Diagnostic {
            pass: "telemetry-names",
            rule: "undeclared",
            file: file.path.clone(),
            line: idx + 1,
            severity: Severity::Error,
            message: format!(
                "log target \"{lit}\" is not declared in telemetry::names — \
                 declare a TARGET_* const and use it"
            ),
        });
    }
    if !lit.chars().all(|c| c.is_ascii_lowercase()) || lit.is_empty() {
        out.push(Diagnostic {
            pass: "telemetry-names",
            rule: "scheme",
            file: file.path.clone(),
            line: idx + 1,
            severity: Severity::Warning,
            message: format!("log target \"{lit}\" must be a single lowercase word"),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use std::collections::BTreeSet;

    fn ctx() -> Context {
        Context {
            declared_names: ["decode.steps", "serve.ttft_ms", "router"]
                .into_iter()
                .map(String::from)
                .collect::<BTreeSet<_>>(),
        }
    }

    fn run_on(src: &str) -> Vec<Diagnostic> {
        let file = lex("rust/src/server/engine.rs", src);
        let mut out = Vec::new();
        TelemetryNames.run(&file, &ctx(), &mut out);
        out
    }

    #[test]
    fn tripping_fixture_flags_undeclared_and_misscheme() {
        let diags = run_on(
            "fn f(reg: &Registry) {\n\
             \x20   reg.add(\"decode.stepz\", 1);\n\
             \x20   reg.observe_ms(\"serve.ttft_ms\", 1.0);\n\
             \x20   reg.histogram(\"Serve.TTFT\");\n\
             \x20   let _sp = trace::span(\"decode.step2\");\n\
             \x20   log::warn(\"rooter\", \"m\".to_string());\n\
             }\n",
        );
        // decode.stepz: undeclared; Serve.TTFT: undeclared + scheme;
        // decode.step2: undeclared; rooter: undeclared
        let undeclared = diags.iter().filter(|d| d.rule == "undeclared").count();
        let scheme = diags.iter().filter(|d| d.rule == "scheme").count();
        assert_eq!(undeclared, 4, "{diags:?}");
        assert_eq!(scheme, 1, "{diags:?}");
        assert!(!diags.iter().any(|d| d.line == 3), "declared serve.ttft_ms must pass");
    }

    #[test]
    fn near_miss_fixture_stays_clean() {
        let diags = run_on(
            "// counter(\"not.a.call\") in a comment\n\
             fn f(reg: &Registry, sp: &SpanGuard) {\n\
             \x20   let doc = \"histogram(\\\"fake.name\\\") inside a string\";\n\
             \x20   reg.add(names::DECODE_STEPS, 1);\n\
             \x20   sp.add(\"tokens\", 7);\n\
             \x20   let h = docgen::sparsity_histogram(doc.len());\n\
             }\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   fn t(reg: &Registry) { reg.add(\"x.y\", 1); }\n\
             }\n",
        );
        assert!(diags.is_empty(), "near-miss fixture tripped: {diags:?}");
    }

    #[test]
    fn literal_on_the_next_line_is_still_checked() {
        let diags = run_on("fn f() {\n    log::warn(\n        \"router\",\n        m,\n    );\n    log::info(\n        \"nope\",\n        m,\n    );\n}\n");
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].message.contains("nope"));
    }

    #[test]
    fn names_rs_is_the_declaration_site_and_exempt() {
        assert!(!TelemetryNames.applies("rust/src/telemetry/names.rs"));
        assert!(TelemetryNames.applies("rust/src/telemetry/metrics.rs"));
        assert!(!TelemetryNames.applies("rust/benches/bench_train.rs"));
    }
}
