//! Request queue for masked-attention inference.

use crate::attention::HeadLayout;
use crate::mask::FlashMask;
use std::collections::VecDeque;
use std::time::Instant;

/// One prefill attention request: Q (`[layout.q_heads, n, d]`) and K/V
/// (`[layout.kv_heads, n, d]`) plus its FlashMask.  Under GQA each KV
/// head serves a group of query heads — the request carries the
/// [`HeadLayout`] end to end so the scheduler can batch on it and the
/// decode path can share KV pages across the group.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub n: usize,
    pub d: usize,
    pub layout: HeadLayout,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub mask: FlashMask,
    pub arrived: Instant,
}

impl Request {
    /// MHA convenience: `heads` query heads, each owning its KV head.
    #[allow(clippy::too_many_arguments)]
    pub fn new(id: u64, heads: usize, n: usize, d: usize, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>, mask: FlashMask) -> Request {
        Request::with_layout(id, HeadLayout::mha(heads), n, d, q, k, v, mask)
    }

    /// Grouped layout: `q` is `[layout.q_heads, n, d]`, `k`/`v` are
    /// `[layout.kv_heads, n, d]`.
    #[allow(clippy::too_many_arguments)]
    pub fn with_layout(
        id: u64,
        layout: HeadLayout,
        n: usize,
        d: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        mask: FlashMask,
    ) -> Request {
        assert_eq!(q.len(), layout.q_heads * n * d, "q must be [q_heads, n, d]");
        assert_eq!(k.len(), layout.kv_heads * n * d, "k must be [kv_heads, n, d]");
        assert_eq!(v.len(), layout.kv_heads * n * d, "v must be [kv_heads, n, d]");
        assert_eq!(mask.n(), n);
        Request { id, n, d, layout, q, k, v, mask, arrived: Instant::now() }
    }

    /// Head `h`'s `[n, d]` view of a head-major buffer (query-head index
    /// for `q`-shaped buffers, KV-head index for `k`/`v`-shaped ones).
    pub fn head<'a>(&self, slice: &'a [f32], h: usize) -> &'a [f32] {
        debug_assert_eq!(slice.len() % (self.n * self.d), 0);
        debug_assert!((h + 1) * self.n * self.d <= slice.len());
        &slice[h * self.n * self.d..(h + 1) * self.n * self.d]
    }

    /// Reinterpret this prefill request as a decode request: rows
    /// `0..prompt_len` become the cached prompt, the remainder is
    /// decoded token by token against the paged KV cache (one page
    /// chain per KV head).
    pub fn into_decode(self, prompt_len: usize) -> crate::decode::DecodeRequest {
        let mut req = crate::decode::DecodeRequest::with_layout(
            self.id, self.layout, self.n, self.d, prompt_len, self.q, self.k, self.v, self.mask,
        );
        req.arrived = self.arrived; // preserve queueing latency accounting
        req
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub o: Vec<f32>,
    pub queue_ms: f64,
    pub compute_ms: f64,
    pub sparsity: f64,
}

/// FIFO request queue with admission checks.
#[derive(Default)]
pub struct RequestQueue {
    items: VecDeque<Request>,
    next_id: u64,
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue::default()
    }

    /// Admit a request; validates the mask before queueing.
    pub fn push(&mut self, mut req: Request) -> anyhow::Result<u64> {
        req.mask.validate()?;
        req.id = self.next_id;
        self.next_id += 1;
        let id = req.id;
        self.items.push_back(req);
        Ok(id)
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.items.pop_front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Peek at the shape key of the front request (for batch grouping):
    /// requests batch together only when layout, n and d all match.
    pub fn front_shape(&self) -> Option<(HeadLayout, usize, usize)> {
        self.items.front().map(|r| (r.layout, r.n, r.d))
    }

    pub fn peek_front(&self) -> Option<&Request> {
        self.items.front()
    }

    /// Re-insert at the back preserving id/arrival (scheduler internal).
    pub(crate) fn push_back_internal(&mut self, r: Request) {
        self.items.push_back(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;

    fn req(n: usize) -> Request {
        let d = 4;
        Request::new(0, 1, n, d, vec![0.0; n * d], vec![0.0; n * d], vec![0.0; n * d], builders::causal(n))
    }

    #[test]
    fn fifo_order_and_ids() {
        let mut q = RequestQueue::new();
        let a = q.push(req(16)).unwrap();
        let b = q.push(req(16)).unwrap();
        assert!(a < b);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, a);
        assert_eq!(q.pop().unwrap().id, b);
        assert!(q.is_empty());
    }

    #[test]
    fn rejects_invalid_mask() {
        let mut q = RequestQueue::new();
        let mut r = req(16);
        r.mask.lts[0] = 99; // out of range
        assert!(q.push(r).is_err());
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_qkv_len() {
        let n = 16;
        Request::new(0, 1, n, 4, vec![0.0; 3], vec![0.0; n * 4], vec![0.0; n * 4], builders::causal(n));
    }

    #[test]
    fn head_slices_the_right_rows() {
        let (heads, n, d) = (3, 4, 2);
        let q: Vec<f32> = (0..heads * n * d).map(|x| x as f32).collect();
        let r = Request::new(
            0,
            heads,
            n,
            d,
            q.clone(),
            vec![0.0; heads * n * d],
            vec![0.0; heads * n * d],
            builders::causal(n),
        );
        for h in 0..heads {
            assert_eq!(r.head(&q, h), &q[h * n * d..(h + 1) * n * d]);
        }
        assert_eq!(r.head(&q, 1)[0], (n * d) as f32);
    }

    #[test]
    fn into_decode_preserves_identity_and_arrival() {
        let r = req(16);
        let arrived = r.arrived;
        let dec = r.into_decode(4);
        assert_eq!(dec.prompt_len, 4);
        assert_eq!(dec.gen_len(), 12);
        assert_eq!(dec.arrived, arrived);
    }

    #[test]
    fn grouped_request_carries_layout_through_decode() {
        let (n, d) = (16, 4);
        let layout = HeadLayout::new(4, 2);
        let r = Request::with_layout(
            0,
            layout,
            n,
            d,
            vec![0.0; layout.q_heads * n * d],
            vec![0.0; layout.kv_heads * n * d],
            vec![0.0; layout.kv_heads * n * d],
            builders::causal(n),
        );
        assert_eq!(r.head(&r.q, 3).len(), n * d);
        assert_eq!(r.head(&r.k, 1).len(), n * d);
        let dec = r.into_decode(4);
        assert_eq!(dec.layout, layout);
        assert_eq!(dec.k.len(), layout.kv_heads * n * d);
    }

    #[test]
    #[should_panic(expected = "kv_heads")]
    fn grouped_request_rejects_q_shaped_kv() {
        let (n, d) = (8, 2);
        let layout = HeadLayout::new(4, 2);
        Request::with_layout(
            0,
            layout,
            n,
            d,
            vec![0.0; layout.q_heads * n * d],
            vec![0.0; layout.q_heads * n * d], // wrong: q-shaped KV
            vec![0.0; layout.kv_heads * n * d],
            builders::causal(n),
        );
    }
}
