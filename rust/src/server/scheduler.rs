//! Dynamic batch scheduler for masked-attention serving.
//!
//! Groups queued requests that share a `(layout, n, d)` shape into one
//! execution batch (bounded by `max_batch` and `max_wait_ms`), so the
//! engine amortizes per-call overhead — the same consideration that
//! drives the paper's FlashInfer padded-batch discussion (appendix
//! B.2).  The head layout is part of the batch key: a GQA request and
//! its same-`n` MHA twin execute through different kernel groupings, so
//! they must not share a plan.

use super::queue::{Request, RequestQueue};
use crate::attention::HeadLayout;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    pub max_batch: usize,
    /// Form a partial batch anyway once the oldest request has waited
    /// this long.
    pub max_wait_ms: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 8, max_wait_ms: 5.0 }
    }
}

/// One batch the engine should execute together.
#[derive(Debug)]
pub struct BatchPlan {
    pub requests: Vec<Request>,
    pub layout: HeadLayout,
    pub n: usize,
    pub d: usize,
}

impl BatchPlan {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

pub struct Scheduler {
    pub cfg: SchedulerConfig,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler { cfg }
    }

    /// Pull the next batch: the longest shape-homogeneous prefix of the
    /// queue, capped at `max_batch`.  Returns `None` when the queue is
    /// empty or the front batch should keep waiting for more arrivals.
    pub fn next_batch(&self, queue: &mut RequestQueue, now: Instant) -> Option<BatchPlan> {
        let (layout, n, d) = queue.front_shape()?;
        // count the homogeneous prefix without draining yet
        let mut count = 0;
        {
            let mut probe: Vec<Request> = Vec::new();
            while let Some(r) = queue.pop() {
                if (r.layout, r.n, r.d) == (layout, n, d) && count < self.cfg.max_batch {
                    count += 1;
                    probe.push(r);
                } else {
                    // push back the non-matching request and stop
                    let mut rest = vec![r];
                    while let Some(x) = queue.pop() {
                        rest.push(x);
                    }
                    for p in probe.drain(..) {
                        // keep original order: matching prefix first
                        queue.push_raw(p);
                    }
                    for x in rest {
                        queue.push_raw(x);
                    }
                    break;
                }
            }
            if !probe.is_empty() {
                // queue fully drained into probe
                for p in probe {
                    queue.push_raw(p);
                }
            }
        }
        if count == 0 {
            return None;
        }
        // batching policy: wait for a full batch unless the oldest
        // request is past its deadline
        let oldest_wait = {
            let front = queue.peek_front().unwrap();
            now.duration_since(front.arrived).as_secs_f64() * 1e3
        };
        if count < self.cfg.max_batch && oldest_wait < self.cfg.max_wait_ms {
            return None;
        }
        let mut requests = Vec::with_capacity(count);
        for _ in 0..count {
            requests.push(queue.pop().unwrap());
        }
        Some(BatchPlan { requests, layout, n, d })
    }

    /// Admission for the decode path: pull up to `max_admit` requests in
    /// FIFO order *regardless of shape*.  Continuous batching steps
    /// ragged sequences side by side, so the same-`(heads, n, d)`
    /// restriction of [`Scheduler::next_batch`] does not apply, and
    /// there is no reason to hold requests back waiting for shape
    /// twins — the batcher admits as capacity allows.
    pub fn drain_for_decode(&self, queue: &mut RequestQueue, max_admit: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < max_admit {
            match queue.pop() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

impl RequestQueue {
    /// Re-insert preserving arrival metadata (scheduler internal).
    pub(crate) fn push_raw(&mut self, r: Request) {
        // bypass validation: the request was validated on admission
        self.push_back_internal(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;
    use std::time::Duration;

    fn req(n: usize, heads: usize) -> Request {
        let d = 4;
        Request::new(
            0,
            heads,
            n,
            d,
            vec![0.0; heads * n * d],
            vec![0.0; heads * n * d],
            vec![0.0; heads * n * d],
            builders::causal(n),
        )
    }

    #[test]
    fn batches_homogeneous_prefix() {
        let mut q = RequestQueue::new();
        for _ in 0..3 {
            q.push(req(16, 1)).unwrap();
        }
        q.push(req(32, 1)).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 0.0 });
        let b = s.next_batch(&mut q, Instant::now()).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b.n, 16);
        assert_eq!(q.len(), 1); // the 32-length request remains
    }

    #[test]
    fn respects_max_batch() {
        let mut q = RequestQueue::new();
        for _ in 0..10 {
            q.push(req(16, 1)).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig { max_batch: 4, max_wait_ms: 0.0 });
        let b = s.next_batch(&mut q, Instant::now()).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn waits_for_full_batch_until_deadline() {
        let mut q = RequestQueue::new();
        q.push(req(16, 1)).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 4, max_wait_ms: 50.0 });
        // fresh request: hold
        assert!(s.next_batch(&mut q, Instant::now()).is_none());
        assert_eq!(q.len(), 1);
        // past deadline: flush partial batch
        let later = Instant::now() + Duration::from_millis(60);
        let b = s.next_batch(&mut q, later).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn lone_request_flushes_exactly_at_deadline_boundary() {
        // the max_wait_ms partial-batch path: a lone request must be
        // held while fresh and dispatched as a batch of one the moment
        // its wait deadline passes — even though max_batch is never met
        let mut q = RequestQueue::new();
        q.push(req(16, 2)).unwrap();
        let arrived = q.peek_front().unwrap().arrived;
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 25.0 });
        // just under the deadline: keep waiting, queue untouched
        let early = arrived + Duration::from_millis(24);
        assert!(s.next_batch(&mut q, early).is_none());
        assert_eq!(q.len(), 1);
        // past the deadline: the partial batch flushes
        let late = arrived + Duration::from_millis(26);
        let b = s.next_batch(&mut q, late).expect("deadline must flush the partial batch");
        assert_eq!(b.len(), 1);
        assert_eq!((b.layout, b.n), (HeadLayout::mha(2), 16));
        assert!(q.is_empty());
    }

    #[test]
    fn partial_batch_flush_leaves_other_shapes_queued() {
        // deadline flush dispatches only the homogeneous prefix; the
        // mismatched tail keeps its place for the next round
        let mut q = RequestQueue::new();
        q.push(req(16, 1)).unwrap();
        q.push(req(16, 1)).unwrap();
        q.push(req(64, 1)).unwrap();
        let arrived = q.peek_front().unwrap().arrived;
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 10.0 });
        let b = s.next_batch(&mut q, arrived + Duration::from_millis(11)).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b.n, 16);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_front().unwrap().n, 64);
    }

    #[test]
    fn drain_for_decode_ignores_shape_and_caps() {
        // the decode path has no same-n restriction: mixed shapes drain
        // together in FIFO order, capped at max_admit
        let mut q = RequestQueue::new();
        let a = q.push(req(16, 1)).unwrap();
        let b = q.push(req(64, 2)).unwrap();
        let c = q.push(req(32, 1)).unwrap();
        let s = Scheduler::new(SchedulerConfig::default());
        let drained = s.drain_for_decode(&mut q, 2);
        assert_eq!(drained.iter().map(|r| r.id).collect::<Vec<_>>(), vec![a, b]);
        assert_eq!(drained[0].n, 16);
        assert_eq!(drained[1].n, 64);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_front().unwrap().id, c);
        assert!(s.drain_for_decode(&mut q, 8).len() == 1);
        assert!(s.drain_for_decode(&mut q, 8).is_empty());
    }

    #[test]
    fn layout_is_part_of_the_batch_key() {
        // a GQA request between two same-n MHA twins must split the
        // batch: grouped and ungrouped layouts execute through different
        // kernel groupings
        let (n, d) = (16, 4);
        let gqa = |id: u64| {
            let layout = HeadLayout::new(2, 1);
            Request::with_layout(
                id,
                layout,
                n,
                d,
                vec![0.0; layout.q_heads * n * d],
                vec![0.0; layout.kv_heads * n * d],
                vec![0.0; layout.kv_heads * n * d],
                builders::causal(n),
            )
        };
        let mut q = RequestQueue::new();
        q.push(req(n, 2)).unwrap();
        q.push(gqa(0)).unwrap();
        q.push(gqa(0)).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 0.0 });
        let first = s.next_batch(&mut q, Instant::now()).unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(first.layout, HeadLayout::mha(2));
        let second = s.next_batch(&mut q, Instant::now()).unwrap();
        assert_eq!(second.len(), 2);
        assert_eq!(second.layout, HeadLayout::new(2, 1));
        assert!(q.is_empty());
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut q = RequestQueue::new();
        let s = Scheduler::new(SchedulerConfig::default());
        assert!(s.next_batch(&mut q, Instant::now()).is_none());
    }

    #[test]
    fn preserves_order_after_probe() {
        let mut q = RequestQueue::new();
        let a = q.push(req(16, 1)).unwrap();
        let b = q.push(req(16, 1)).unwrap();
        q.push(req(32, 1)).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 0.0 });
        let batch = s.next_batch(&mut q, Instant::now()).unwrap();
        assert_eq!(batch.requests[0].id, a);
        assert_eq!(batch.requests[1].id, b);
    }
}
