//! Serving engine: executes batch plans through the pluggable
//! [`Backend`] trait (`attention::api`) with a content-keyed
//! [`PlanCache`], per-request latency tracking, and *explicit*
//! capability-driven fallbacks — when the configured backend cannot run
//! an operation (e.g. the PJRT artifact has no grouped or decode
//! kernel) the engine records the missing capability in
//! [`ServeReport::fallbacks`] and logs it, then routes the work to the
//! CPU backend.

use super::queue::{Request, Response};
use super::scheduler::BatchPlan;
use crate::attention::api::{
    AttnProblem, Backend, Capabilities, Capability, CpuBackend, KvViews, PjrtBackend, PlanCache,
    QViews,
};
use crate::decode::{BatcherConfig, BatcherReport, ContinuousBatcher, DecodeRequest};
use crate::runtime::Executable;
use crate::telemetry::{log, metrics, names, trace, Histogram};
use anyhow::Result;
use std::time::Instant;

/// Which backend executes the attention.
pub enum EngineKind {
    /// The rust CPU blocked engine (always available).
    Cpu { threads: usize },
    /// The AOT-compiled Pallas kernel via PJRT (requires artifacts and a
    /// matching `(heads, n, d)` signature).
    Pjrt(Box<Executable>),
}

pub struct ServeEngine {
    backend: Box<dyn Backend>,
    threads: usize,
    pub tile: (usize, usize),
    /// Content-keyed plan cache: requests sharing a mask/shape (every
    /// layer of a model, repeated prompts) reuse classification and
    /// packing buffers instead of recompiling per request.
    plans: PlanCache,
    pub completed: Vec<Response>,
    started: Instant,
    tokens: usize,
    fallbacks: u64,
    /// This engine's decode-latency distributions (the report's TTFT /
    /// inter-token percentiles); every sample is mirrored into the
    /// global registry under `serve.ttft_ms` / `serve.itl_ms`.
    ttft: Histogram,
    itl: Histogram,
}

/// Aggregate serving statistics (the numbers a deployment dashboards).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub throughput_tok_s: f64,
    pub mean_queue_ms: f64,
    pub p50_compute_ms: f64,
    pub p99_compute_ms: f64,
    pub mean_sparsity: f64,
    /// Operations the configured backend could not run and the engine
    /// re-routed (each one was logged with the missing capability).
    pub fallbacks: u64,
    /// Plan-cache lookups served from cache.
    pub plan_hits: u64,
    /// Plan-cache lookups that compiled a fresh plan.
    pub plan_misses: u64,
    /// Decode-path time-to-first-token percentiles from the engine's
    /// telemetry histogram (0 when no decode ran).  Log2 buckets, so
    /// values are upper bounds within one power of two (DESIGN.md
    /// §Telemetry).
    pub p50_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    /// Decode-path inter-token-latency percentiles over *per-token*
    /// gap samples — every consecutive generated-token pair contributes
    /// one sample, so individual stalls land in the tail instead of
    /// being averaged away per sequence (0 when no multi-token
    /// sequence retired).
    pub p50_itl_ms: f64,
    pub p99_itl_ms: f64,
}

impl ServeEngine {
    pub fn new(kind: EngineKind, tile: (usize, usize)) -> ServeEngine {
        match kind {
            EngineKind::Cpu { threads } => {
                ServeEngine::with_backend(Box::new(CpuBackend), threads.max(1), tile)
            }
            EngineKind::Pjrt(exe) => {
                ServeEngine::with_backend(Box::new(PjrtBackend::new(*exe)), 1, tile)
            }
        }
    }

    /// Plug in any [`Backend`] implementation (tests use stub backends;
    /// deployments can bring their own accelerators).
    pub fn with_backend(
        backend: Box<dyn Backend>,
        threads: usize,
        tile: (usize, usize),
    ) -> ServeEngine {
        ServeEngine {
            backend,
            threads: threads.max(1),
            tile,
            plans: PlanCache::default(),
            completed: Vec::new(),
            started: Instant::now(),
            tokens: 0,
            fallbacks: 0,
            ttft: Histogram::new(),
            itl: Histogram::new(),
        }
    }

    /// The configured backend's capability surface.
    pub fn capabilities(&self) -> Capabilities {
        self.backend.capabilities()
    }

    fn note_fallback(&mut self, missing: Capability) {
        self.fallbacks += 1;
        metrics::global().add(names::SERVE_FALLBACKS, 1);
        log::warn(
            names::TARGET_SERVE,
            format!(
                "backend '{}' lacks capability '{missing}'; falling back to the CPU path",
                self.backend.name()
            ),
        );
    }

    /// Execute one batch plan; responses are appended to `completed`.
    pub fn execute(&mut self, plan: BatchPlan) -> Result<()> {
        let now = Instant::now();
        let caps = self.backend.capabilities();
        let reg = metrics::global();
        for req in plan.requests {
            let sp = trace::span(names::SERVE_REQUEST);
            sp.add("tokens", req.n as u64);
            let t0 = Instant::now();
            let o = self.run_prefill(&req, caps)?;
            let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
            drop(sp);
            let queue_ms = now.duration_since(req.arrived).as_secs_f64() * 1e3;
            reg.add(names::SERVE_REQUESTS, 1);
            reg.add(names::SERVE_TOKENS, req.n as u64);
            reg.observe_ms(names::SERVE_COMPUTE_MS, compute_ms);
            reg.observe_ms(names::SERVE_QUEUE_MS, queue_ms);
            self.tokens += req.n;
            self.completed.push(Response {
                id: req.id,
                o,
                queue_ms,
                compute_ms,
                sparsity: req.mask.block_sparsity(self.tile.0, self.tile.1),
            });
        }
        Ok(())
    }

    /// One request's prefill through the capability-dispatched backend.
    fn run_prefill(&mut self, req: &Request, caps: Capabilities) -> Result<Vec<f32>> {
        let problem = AttnProblem::new(req.n, req.d)
            .layout(req.layout)
            .mask(&req.mask)
            .tile(self.tile.0.min(req.n), self.tile.1.min(req.n))
            .threads(self.threads);
        let q = QViews::new(&req.q, req.layout.q_heads, req.n, req.d)?;
        let kv = KvViews::new(&req.k, &req.v, req.layout.kv_heads, req.n, req.d)?;
        let supported = if req.layout.is_mha() { caps.prefill } else { caps.prefill_grouped };
        let out = if supported {
            let plan = self.plans.get_or_build(&problem)?;
            if req.layout.is_mha() {
                self.backend.prefill(&plan, q, kv)?
            } else {
                self.backend.prefill_grouped(&plan, q, kv)?
            }
        } else if !req.layout.is_mha() && caps.prefill {
            // explicit grouped fallback: the backend's artifact is
            // compiled for an MHA signature, so each KV head is
            // replicated across its query group host-side (semantically
            // identical — the GQA residency win stays host-side until a
            // grouped artifact exists, DESIGN.md §Head layouts)
            self.note_fallback(Capability::PrefillGrouped);
            let per = req.n * req.d;
            let mut k_rep = Vec::with_capacity(req.layout.q_heads * per);
            let mut v_rep = Vec::with_capacity(req.layout.q_heads * per);
            for qh in 0..req.layout.q_heads {
                let kh = req.layout.kv_head_of(qh);
                k_rep.extend_from_slice(&req.k[kh * per..(kh + 1) * per]);
                v_rep.extend_from_slice(&req.v[kh * per..(kh + 1) * per]);
            }
            let mha = problem.heads(req.layout.q_heads, req.layout.q_heads);
            let plan = self.plans.get_or_build(&mha)?;
            let kv_rep = KvViews::new(&k_rep, &v_rep, req.layout.q_heads, req.n, req.d)?;
            self.backend.prefill(&plan, q, kv_rep)?
        } else {
            // the backend cannot prefill this request at all
            self.note_fallback(if req.layout.is_mha() {
                Capability::Prefill
            } else {
                Capability::PrefillGrouped
            });
            let plan = self.plans.get_or_build(&problem)?;
            CpuBackend.prefill_grouped(&plan, q, kv)?
        };
        let mut o = Vec::with_capacity(req.layout.q_heads * req.n * req.d);
        for part in out.outs {
            o.extend(part.o);
        }
        Ok(o)
    }

    /// Decode entry point.  The paged-cache step/verify kernels are
    /// CPU-resident (no AOT decode artifact is compiled yet, DESIGN.md
    /// §Decode): a backend without the `decode` capability has the gap
    /// *recorded* in [`ServeReport::fallbacks`] and logged — never a
    /// silent downgrade — before the continuous batcher runs on the CPU
    /// backend.  Retired sequences land in `completed` like prefill
    /// responses: `o` holds the generated rows and `sparsity` reports
    /// the fraction of cache pages skipped.
    ///
    /// `cfg.spec` selects speculative decoding (draft → tree-mask
    /// verify → commit/rollback); outputs are token-identical to
    /// sequential decode under greedy acceptance, so callers opt in
    /// purely on throughput grounds.  The returned [`BatcherReport`]
    /// carries drafted/accepted token counts.
    pub fn execute_decode(
        &mut self,
        reqs: Vec<DecodeRequest>,
        cfg: BatcherConfig,
    ) -> Result<BatcherReport> {
        if !self.backend.capabilities().decode {
            self.note_fallback(Capability::DecodeStep);
        }
        let sp = trace::span(names::SERVE_DECODE_BATCH);
        sp.add("sequences", reqs.len() as u64);
        let mut batcher = ContinuousBatcher::new(cfg);
        for r in reqs {
            batcher.submit(r)?;
        }
        let report = batcher.run()?;
        drop(sp);
        let reg = metrics::global();
        for resp in batcher.take_finished() {
            self.ttft.record_ms(resp.ttft_ms);
            reg.observe_ms(names::SERVE_TTFT_MS, resp.ttft_ms);
            for &gap in &resp.itl_gaps_ms {
                self.itl.record_ms(gap);
                reg.observe_ms(names::SERVE_ITL_MS, gap);
            }
            reg.add(names::SERVE_REQUESTS, 1);
            reg.add(names::SERVE_TOKENS, (resp.n - resp.prompt_len) as u64);
            self.tokens += resp.n - resp.prompt_len;
            self.completed.push(Response {
                id: resp.id,
                o: resp.o,
                queue_ms: resp.queue_ms,
                compute_ms: resp.decode_ms,
                sparsity: resp.stats.skip_fraction(),
            });
        }
        Ok(report)
    }

    pub fn report(&self) -> ServeReport {
        let n = self.completed.len().max(1);
        let mut compute: Vec<f64> = self.completed.iter().map(|r| r.compute_ms).collect();
        compute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| compute.get(((compute.len() as f64 - 1.0) * p) as usize).copied().unwrap_or(0.0);
        ServeReport {
            requests: self.completed.len(),
            throughput_tok_s: self.tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
            mean_queue_ms: self.completed.iter().map(|r| r.queue_ms).sum::<f64>() / n as f64,
            p50_compute_ms: pct(0.5),
            p99_compute_ms: pct(0.99),
            mean_sparsity: self.completed.iter().map(|r| r.sparsity).sum::<f64>() / n as f64,
            fallbacks: self.fallbacks,
            plan_hits: self.plans.hits(),
            plan_misses: self.plans.misses(),
            p50_ttft_ms: self.ttft.quantile_ms(0.50),
            p99_ttft_ms: self.ttft.quantile_ms(0.99),
            p50_itl_ms: self.itl.quantile_ms(0.50),
            p99_itl_ms: self.itl.quantile_ms(0.99),
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::api::{AttnError, ExecutionPlan, PrefillOutput};
    use crate::attention::{dense, HeadLayout};
    use crate::mask::builders;
    use crate::server::queue::RequestQueue;
    use crate::server::scheduler::{Scheduler, SchedulerConfig};
    use crate::util::rng::Rng;

    fn rand_req(n: usize, heads: usize, d: usize, seed: u64) -> Request {
        let mut rng = Rng::new(seed);
        let mut mk = || (0..heads * n * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
        Request::new(0, heads, n, d, mk(), mk(), mk(), builders::causal_document(n, &[n / 2, n / 2]))
    }

    #[test]
    fn cpu_engine_matches_dense_per_head() {
        let (n, heads, d) = (64, 2, 8);
        let req = rand_req(n, heads, d, 1);
        let mut q = RequestQueue::new();
        q.push(req.clone()).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 2 }, (16, 16));
        let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
        eng.execute(plan).unwrap();
        let resp = &eng.completed[0];
        let bias = req.mask.dense_bias();
        for h in 0..heads {
            let r = h * n * d..(h + 1) * n * d;
            let want = dense::dense_forward(
                &req.q[r.clone()], &req.k[r.clone()], &req.v[r.clone()],
                n, d, &bias, 1.0 / (d as f32).sqrt(),
            );
            for (a, b) in resp.o[r].iter().zip(&want.o) {
                assert!((a - b).abs() < 3e-5);
            }
        }
        // no fallbacks on the all-capable CPU backend
        assert_eq!(eng.report().fallbacks, 0);
    }

    #[test]
    fn repeated_masks_hit_the_plan_cache() {
        // six requests over two distinct (mask, shape) contents: the
        // engine compiles two plans and serves four calls from cache
        let (n, heads, d) = (48, 1, 8);
        let mut q = RequestQueue::new();
        for i in 0..6 {
            let mut r = rand_req(n, heads, d, 10 + i);
            if i % 2 == 1 {
                r.mask = builders::causal(n);
            }
            q.push(r).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig { max_batch: 8, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        while let Some(plan) = s.next_batch(&mut q, std::time::Instant::now()) {
            eng.execute(plan).unwrap();
        }
        let rep = eng.report();
        assert_eq!(rep.requests, 6);
        assert_eq!(rep.plan_misses, 2, "two distinct plans");
        assert_eq!(rep.plan_hits, 4, "four cache hits");
    }

    /// A backend that can do nothing — every operation must fall back
    /// to the CPU path, counted and with correct results.
    struct NullBackend;

    impl Backend for NullBackend {
        fn name(&self) -> &'static str {
            "null"
        }

        fn capabilities(&self) -> Capabilities {
            Capabilities::default()
        }

        fn prefill_grouped(
            &self,
            _plan: &ExecutionPlan,
            _q: QViews<'_>,
            _kv: KvViews<'_>,
        ) -> Result<PrefillOutput, AttnError> {
            Err(AttnError::Unsupported {
                backend: "null",
                capability: Capability::PrefillGrouped,
            })
        }
    }

    #[test]
    fn incapable_backend_falls_back_to_cpu_and_is_counted() {
        let (n, heads, d) = (48, 2, 8);
        let req = rand_req(n, heads, d, 3);
        let mut q = RequestQueue::new();
        q.push(req.clone()).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::with_backend(Box::new(NullBackend), 1, (16, 16));
        let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
        eng.execute(plan).unwrap();
        // the fallback still computes the right answer
        let resp = &eng.completed[0];
        let bias = req.mask.dense_bias();
        for h in 0..heads {
            let r = h * n * d..(h + 1) * n * d;
            let want = dense::dense_forward(
                &req.q[r.clone()], &req.k[r.clone()], &req.v[r.clone()],
                n, d, &bias, 1.0 / (d as f32).sqrt(),
            );
            for (a, b) in resp.o[r].iter().zip(&want.o) {
                assert!((a - b).abs() < 3e-5);
            }
        }
        assert_eq!(eng.report().fallbacks, 1, "prefill fallback must be recorded");
    }

    #[test]
    fn decode_fallback_is_recorded_not_silent() {
        // satellite: a backend without the decode capability must have
        // the gap counted in ServeReport.fallbacks (and logged), while
        // the CPU batcher still produces the tokens
        let (n, d, prompt) = (32, 8, 8);
        let req = rand_req(n, 1, d, 9);
        let mut eng = ServeEngine::with_backend(Box::new(NullBackend), 1, (16, 16));
        let report = eng
            .execute_decode(
                vec![req.into_decode(prompt)],
                BatcherConfig {
                    page_size: 8,
                    d,
                    max_pages: 64,
                    max_active: 2,
                    skip: true,
                    spec: crate::decode::SpecPolicy::Off,
                    prefix_cache: false,
                },
            )
            .unwrap();
        assert_eq!(report.sequences, 1);
        assert_eq!(report.tokens, (n - prompt) as u64);
        assert_eq!(eng.report().fallbacks, 1, "decode fallback must be recorded");
        // the CPU engine kind needs no fallback for decode
        let req2 = rand_req(n, 1, d, 10);
        let mut cpu = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        cpu.execute_decode(
            vec![req2.into_decode(prompt)],
            BatcherConfig {
                page_size: 8,
                d,
                max_pages: 64,
                max_active: 2,
                skip: true,
                spec: crate::decode::SpecPolicy::Off,
                prefix_cache: false,
            },
        )
        .unwrap();
        assert_eq!(cpu.report().fallbacks, 0);
    }

    #[test]
    fn fallback_warning_is_logged() {
        // satellite: the old eprintln! fallback warning now goes through
        // telemetry::log, so tests can capture and assert it
        let cap = crate::telemetry::log::capture();
        let (n, heads, d) = (32, 1, 8);
        let req = rand_req(n, heads, d, 11);
        let mut q = RequestQueue::new();
        q.push(req).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::with_backend(Box::new(NullBackend), 1, (16, 16));
        let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
        eng.execute(plan).unwrap();
        let records = cap.take();
        let warning = records
            .iter()
            .find(|r| r.target == "serve" && r.level == crate::telemetry::log::Level::Warn)
            .expect("fallback must emit a serve warning");
        assert!(
            warning.msg.contains("falling back to the CPU path"),
            "unexpected fallback message: {}",
            warning.msg
        );
        assert!(
            warning.msg.contains("'null'"),
            "warning must name the incapable backend: {}",
            warning.msg
        );
    }

    /// GQA request plus its MHA twin (same Q, KV replicated per group).
    fn rand_gqa_pair(n: usize, d: usize, layout: HeadLayout, seed: u64) -> (Request, Request) {
        let mut rng = Rng::new(seed);
        let mut mk = |len: usize| (0..len).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
        let mask = builders::causal_document(n, &[n / 2, n - n / 2]);
        let q = mk(layout.q_heads * n * d);
        let k = mk(layout.kv_heads * n * d);
        let v = mk(layout.kv_heads * n * d);
        let mut k_rep = Vec::with_capacity(layout.q_heads * n * d);
        let mut v_rep = Vec::with_capacity(layout.q_heads * n * d);
        for qh in 0..layout.q_heads {
            let kh = layout.kv_head_of(qh);
            k_rep.extend_from_slice(&k[kh * n * d..(kh + 1) * n * d]);
            v_rep.extend_from_slice(&v[kh * n * d..(kh + 1) * n * d]);
        }
        (
            Request::with_layout(0, layout, n, d, q.clone(), k, v, mask.clone()),
            Request::new(0, layout.q_heads, n, d, q, k_rep, v_rep, mask),
        )
    }

    #[test]
    fn gqa_prefill_through_engine_matches_replicated_mha() {
        let (n, d) = (64, 8);
        let layout = HeadLayout::new(4, 2);
        let (gqa, mha) = rand_gqa_pair(n, d, layout, 7);
        let run = |r: Request| {
            let mut q = RequestQueue::new();
            q.push(r).unwrap();
            let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 2 }, (16, 16));
            let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
            eng.execute(plan).unwrap();
            eng.completed.pop().unwrap()
        };
        let a = run(gqa);
        let b = run(mha);
        assert_eq!(a.o, b.o, "GQA prefill diverged from replicated MHA");
    }

    #[test]
    fn gqa_decode_through_engine_matches_replicated_mha() {
        let (n, d, prompt) = (48, 8, 8);
        let layout = HeadLayout::new(4, 2);
        let (gqa, mha) = rand_gqa_pair(n, d, layout, 8);
        let run = |r: Request| {
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
            let report = eng
                .execute_decode(
                    vec![r.into_decode(prompt)],
                    BatcherConfig {
                        page_size: 8,
                        d,
                        max_pages: 256,
                        max_active: 2,
                        skip: true,
                        spec: crate::decode::SpecPolicy::Off,
                        prefix_cache: false,
                    },
                )
                .unwrap();
            (report, eng.completed.pop().unwrap())
        };
        let (rep_g, a) = run(gqa);
        let (rep_m, b) = run(mha);
        assert_eq!(a.o, b.o, "GQA decode diverged from replicated MHA");
        // shared KV pages: the grouped run holds group× fewer pages
        assert_eq!(rep_m.peak_pages, layout.group() * rep_g.peak_pages);
        assert_eq!(rep_m.resident_kv_bytes, layout.group() * rep_g.resident_kv_bytes);
    }

    #[test]
    fn decode_entry_matches_prefill_through_full_pipeline() {
        // queue -> drain_for_decode -> into_decode -> execute_decode:
        // generated rows must equal the prefill engine's rows for the
        // same requests, despite heterogeneous sequence lengths
        let (heads, d) = (2, 8);
        let mut q = RequestQueue::new();
        let originals: Vec<Request> =
            [(32usize, 1u64), (64, 2), (48, 3)].iter().map(|&(n, s)| rand_req(n, heads, d, s)).collect();
        for r in &originals {
            q.push(r.clone()).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig::default());
        let drained = s.drain_for_decode(&mut q, 8);
        assert_eq!(drained.len(), 3);
        let prompt = 8;
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        let report = eng
            .execute_decode(
                drained.into_iter().map(|r| r.into_decode(prompt)).collect(),
                crate::decode::BatcherConfig {
                    page_size: 16,
                    d,
                    max_pages: 256,
                    max_active: 4,
                    skip: true,
                    spec: crate::decode::SpecPolicy::Off,
                    prefix_cache: false,
                },
            )
            .unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, (32 - 8) + (64 - 8) + (48 - 8));
        assert!(report.pages_skip_fraction > 0.0, "doc masks should skip pages");
        assert_eq!(eng.completed.len(), 3);
        // completed is in retirement order (shortest first) — match by id
        for resp in &eng.completed {
            let req = &originals[resp.id as usize];
            let n = req.n;
            let bias = req.mask.dense_bias();
            let gen = (n - prompt) * d;
            for h in 0..heads {
                let want = dense::dense_forward(
                    req.head(&req.q, h),
                    req.head(&req.k, h),
                    req.head(&req.v, h),
                    n,
                    d,
                    &bias,
                    1.0 / (d as f32).sqrt(),
                );
                let got = &resp.o[h * gen..(h + 1) * gen];
                for (a, b) in got.iter().zip(&want.o[prompt * d..]) {
                    assert!((a - b).abs() < 1e-4, "n={n} h={h}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn speculative_decode_through_engine_matches_sequential() {
        // ServeEngine::execute_decode with a speculative config must
        // produce byte-for-byte the tokens and (to 1e-4) the rows of a
        // sequential run, while the report shows real draft activity
        use crate::decode::{BatcherConfig, SpecPolicy};
        let (heads, d, prompt) = (2, 8, 8);
        let originals: Vec<Request> = [(32usize, 11u64), (64, 12), (48, 13)]
            .iter()
            .enumerate()
            .map(|(i, &(n, s))| {
                let mut r = rand_req(n, heads, d, s);
                r.id = i as u64;
                r
            })
            .collect();
        let run = |spec: SpecPolicy| {
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
            let report = eng
                .execute_decode(
                    originals.iter().map(|r| r.clone().into_decode(prompt)).collect(),
                    BatcherConfig {
                        page_size: 16,
                        d,
                        max_pages: 256,
                        max_active: 4,
                        skip: true,
                        spec,
                        prefix_cache: false,
                    },
                )
                .unwrap();
            let mut done = eng.completed;
            done.sort_by_key(|r| r.id);
            (report, done)
        };
        let (seq_report, seq) = run(SpecPolicy::Off);
        let (spec_report, spec) =
            run(SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 2, seed: 5 });
        assert_eq!(seq_report.tokens, spec_report.tokens);
        assert_eq!(seq_report.drafted_tokens, 0);
        assert!(spec_report.drafted_tokens > 0);
        assert!(spec_report.accept_rate() > 0.5);
        for (a, b) in seq.iter().zip(&spec) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.o.len(), b.o.len());
            for (x, y) in a.o.iter().zip(&b.o) {
                assert!((x - y).abs() < 1e-4, "req {}: {x} vs {y}", a.id);
            }
        }
    }

    #[test]
    fn serve_loop_processes_all_and_reports() {
        let mut q = RequestQueue::new();
        for i in 0..6 {
            q.push(rand_req(32, 1, 8, i)).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig { max_batch: 4, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        while let Some(plan) = s.next_batch(&mut q, std::time::Instant::now()) {
            eng.execute(plan).unwrap();
        }
        assert_eq!(eng.completed.len(), 6);
        let rep = eng.report();
        assert_eq!(rep.requests, 6);
        assert!(rep.throughput_tok_s > 0.0);
        assert!(rep.p99_compute_ms >= rep.p50_compute_ms);
        assert!((0.0..=1.0).contains(&rep.mean_sparsity));
        // all six requests share one mask content and shape
        assert_eq!(rep.plan_misses, 1);
        assert_eq!(rep.plan_hits, 5);
    }
}
