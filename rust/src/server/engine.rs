//! Serving engine: executes batch plans on the CPU blocked engine or on
//! the AOT `attn_fwd` PJRT artifact, with per-request latency tracking.

use super::queue::{Request, Response};
use super::scheduler::BatchPlan;
use crate::attention::{flash, AttnConfig};
use crate::decode::{BatcherConfig, BatcherReport, ContinuousBatcher, DecodeRequest};
use crate::mask::BlockTable;
use crate::runtime::{Executable, HostTensor};
use anyhow::Result;
use std::time::Instant;

/// Which backend executes the attention.
pub enum EngineKind {
    /// The rust CPU blocked engine (always available).
    Cpu { threads: usize },
    /// The AOT-compiled Pallas kernel via PJRT (requires artifacts and a
    /// matching `(heads, n, d)` signature).
    Pjrt(Box<Executable>),
}

pub struct ServeEngine {
    kind: EngineKind,
    pub tile: (usize, usize),
    pub completed: Vec<Response>,
    started: Instant,
    tokens: usize,
}

/// Aggregate serving statistics (the numbers a deployment dashboards).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub throughput_tok_s: f64,
    pub mean_queue_ms: f64,
    pub p50_compute_ms: f64,
    pub p99_compute_ms: f64,
    pub mean_sparsity: f64,
}

impl ServeEngine {
    pub fn new(kind: EngineKind, tile: (usize, usize)) -> ServeEngine {
        ServeEngine { kind, tile, completed: Vec::new(), started: Instant::now(), tokens: 0 }
    }

    /// Execute one batch plan; responses are appended to `completed`.
    pub fn execute(&mut self, plan: BatchPlan) -> Result<()> {
        let now = Instant::now();
        match &self.kind {
            EngineKind::Cpu { threads } => {
                let threads = *threads;
                for req in plan.requests {
                    let t0 = Instant::now();
                    let o = cpu_attention(&req, self.tile, threads);
                    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
                    self.tokens += req.n;
                    self.completed.push(Response {
                        id: req.id,
                        o,
                        queue_ms: now.duration_since(req.arrived).as_secs_f64() * 1e3,
                        compute_ms,
                        sparsity: req.mask.block_sparsity(self.tile.0, self.tile.1),
                    });
                }
            }
            EngineKind::Pjrt(exe) => {
                for req in plan.requests {
                    let t0 = Instant::now();
                    let shape4 = vec![1, req.layout.q_heads, req.n, req.d];
                    // the AOT artifact is compiled for an MHA signature:
                    // expand grouped K/V by replicating each KV head
                    // across its query group (semantically identical —
                    // the GQA residency win stays host-side until a
                    // grouped decode artifact exists, DESIGN.md §Head
                    // layouts)
                    let expand = |src: &[f32]| -> Vec<f32> {
                        if req.layout.is_mha() {
                            return src.to_vec();
                        }
                        let per = req.n * req.d;
                        let mut out = Vec::with_capacity(req.layout.q_heads * per);
                        for qh in 0..req.layout.q_heads {
                            let kh = req.layout.kv_head_of(qh);
                            out.extend_from_slice(&src[kh * per..(kh + 1) * per]);
                        }
                        out
                    };
                    let vec_t = |v: &Vec<i32>| HostTensor::I32 { shape: vec![1, req.n], data: v.clone() };
                    let out = exe.run(&[
                        HostTensor::F32 { shape: shape4.clone(), data: req.q.clone() },
                        HostTensor::F32 { shape: shape4.clone(), data: expand(&req.k) },
                        HostTensor::F32 { shape: shape4, data: expand(&req.v) },
                        vec_t(&req.mask.lts),
                        vec_t(&req.mask.lte),
                        vec_t(&req.mask.uts),
                        vec_t(&req.mask.ute),
                    ])?;
                    let compute_ms = t0.elapsed().as_secs_f64() * 1e3;
                    self.tokens += req.n;
                    self.completed.push(Response {
                        id: req.id,
                        o: out[0].as_f32()?.to_vec(),
                        queue_ms: now.duration_since(req.arrived).as_secs_f64() * 1e3,
                        compute_ms,
                        sparsity: req.mask.block_sparsity(self.tile.0, self.tile.1),
                    });
                }
            }
        }
        Ok(())
    }

    /// Decode entry point — [`EngineKind`]-agnostic: the paged-cache
    /// step kernel is CPU-resident for now (no AOT decode artifact is
    /// compiled yet, DESIGN.md §Decode), so both engine kinds route
    /// decode through the continuous batcher.  Retired sequences land
    /// in `completed` like prefill responses: `o` holds the generated
    /// rows and `sparsity` reports the fraction of cache pages skipped.
    ///
    /// `cfg.spec` selects speculative decoding (draft → tree-mask
    /// verify → commit/rollback); outputs are token-identical to
    /// sequential decode under greedy acceptance, so callers opt in
    /// purely on throughput grounds.  The returned [`BatcherReport`]
    /// carries drafted/accepted token counts.
    pub fn execute_decode(
        &mut self,
        reqs: Vec<DecodeRequest>,
        cfg: BatcherConfig,
    ) -> Result<BatcherReport> {
        let mut batcher = ContinuousBatcher::new(cfg);
        for r in reqs {
            batcher.submit(r)?;
        }
        let report = batcher.run()?;
        for resp in batcher.take_finished() {
            self.tokens += resp.n - resp.prompt_len;
            self.completed.push(Response {
                id: resp.id,
                o: resp.o,
                queue_ms: resp.queue_ms,
                compute_ms: resp.decode_ms,
                sparsity: resp.stats.skip_fraction(),
            });
        }
        Ok(report)
    }

    pub fn report(&self) -> ServeReport {
        let n = self.completed.len().max(1);
        let mut compute: Vec<f64> = self.completed.iter().map(|r| r.compute_ms).collect();
        compute.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| compute.get(((compute.len() as f64 - 1.0) * p) as usize).copied().unwrap_or(0.0);
        ServeReport {
            requests: self.completed.len(),
            throughput_tok_s: self.tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
            mean_queue_ms: self.completed.iter().map(|r| r.queue_ms).sum::<f64>() / n as f64,
            p50_compute_ms: pct(0.5),
            p99_compute_ms: pct(0.99),
            mean_sparsity: self.completed.iter().map(|r| r.sparsity).sum::<f64>() / n as f64,
        }
    }
}

fn cpu_attention(req: &Request, tile: (usize, usize), threads: usize) -> Vec<f32> {
    let cfg = AttnConfig::new(tile.0.min(req.n), tile.1.min(req.n), req.d);
    let table = BlockTable::build(&req.mask, cfg.bc);
    // the grouped parallel kernel builds the Eq. 4 interval schedule
    // once for the whole request and packs each KV head's K once, then
    // partitions (query head × row block) items across threads with
    // cost-weighted chunks — a 1-head 128K-context request saturates
    // every core where head-only parallelism pinned it to one, and an
    // MQA request still reuses a single packed K across all its heads
    let (outs, _) = flash::flashmask_forward_grouped_parallel(
        &req.q,
        &req.k,
        &req.v,
        req.n,
        req.d,
        req.layout,
        &req.mask,
        &table,
        cfg,
        true,
        threads.max(1),
    );
    let mut o = Vec::with_capacity(req.layout.q_heads * req.n * req.d);
    for part in outs {
        o.extend(part.o);
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{dense, HeadLayout};
    use crate::mask::builders;
    use crate::server::queue::RequestQueue;
    use crate::server::scheduler::{Scheduler, SchedulerConfig};
    use crate::util::rng::Rng;

    fn rand_req(n: usize, heads: usize, d: usize, seed: u64) -> Request {
        let mut rng = Rng::new(seed);
        let mut mk = || (0..heads * n * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
        Request::new(0, heads, n, d, mk(), mk(), mk(), builders::causal_document(n, &[n / 2, n / 2]))
    }

    #[test]
    fn cpu_engine_matches_dense_per_head() {
        let (n, heads, d) = (64, 2, 8);
        let req = rand_req(n, heads, d, 1);
        let mut q = RequestQueue::new();
        q.push(req.clone()).unwrap();
        let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 2 }, (16, 16));
        let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
        eng.execute(plan).unwrap();
        let resp = &eng.completed[0];
        let bias = req.mask.dense_bias();
        for h in 0..heads {
            let r = h * n * d..(h + 1) * n * d;
            let want = dense::dense_forward(
                &req.q[r.clone()], &req.k[r.clone()], &req.v[r.clone()],
                n, d, &bias, 1.0 / (d as f32).sqrt(),
            );
            for (a, b) in resp.o[r].iter().zip(&want.o) {
                assert!((a - b).abs() < 3e-5);
            }
        }
    }

    /// GQA request plus its MHA twin (same Q, KV replicated per group).
    fn rand_gqa_pair(n: usize, d: usize, layout: HeadLayout, seed: u64) -> (Request, Request) {
        let mut rng = Rng::new(seed);
        let mut mk = |len: usize| (0..len).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
        let mask = builders::causal_document(n, &[n / 2, n - n / 2]);
        let q = mk(layout.q_heads * n * d);
        let k = mk(layout.kv_heads * n * d);
        let v = mk(layout.kv_heads * n * d);
        let mut k_rep = Vec::with_capacity(layout.q_heads * n * d);
        let mut v_rep = Vec::with_capacity(layout.q_heads * n * d);
        for qh in 0..layout.q_heads {
            let kh = layout.kv_head_of(qh);
            k_rep.extend_from_slice(&k[kh * n * d..(kh + 1) * n * d]);
            v_rep.extend_from_slice(&v[kh * n * d..(kh + 1) * n * d]);
        }
        (
            Request::with_layout(0, layout, n, d, q.clone(), k, v, mask.clone()),
            Request::new(0, layout.q_heads, n, d, q, k_rep, v_rep, mask),
        )
    }

    #[test]
    fn gqa_prefill_through_engine_matches_replicated_mha() {
        let (n, d) = (64, 8);
        let layout = HeadLayout::new(4, 2);
        let (gqa, mha) = rand_gqa_pair(n, d, layout, 7);
        let run = |r: Request| {
            let mut q = RequestQueue::new();
            q.push(r).unwrap();
            let s = Scheduler::new(SchedulerConfig { max_batch: 1, max_wait_ms: 0.0 });
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 2 }, (16, 16));
            let plan = s.next_batch(&mut q, std::time::Instant::now()).unwrap();
            eng.execute(plan).unwrap();
            eng.completed.pop().unwrap()
        };
        let a = run(gqa);
        let b = run(mha);
        assert_eq!(a.o, b.o, "GQA prefill diverged from replicated MHA");
    }

    #[test]
    fn gqa_decode_through_engine_matches_replicated_mha() {
        let (n, d, prompt) = (48, 8, 8);
        let layout = HeadLayout::new(4, 2);
        let (gqa, mha) = rand_gqa_pair(n, d, layout, 8);
        let run = |r: Request| {
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
            let report = eng
                .execute_decode(
                    vec![r.into_decode(prompt)],
                    BatcherConfig {
                        page_size: 8,
                        d,
                        max_pages: 256,
                        max_active: 2,
                        skip: true,
                        spec: crate::decode::SpecPolicy::Off,
                    },
                )
                .unwrap();
            (report, eng.completed.pop().unwrap())
        };
        let (rep_g, a) = run(gqa);
        let (rep_m, b) = run(mha);
        assert_eq!(a.o, b.o, "GQA decode diverged from replicated MHA");
        // shared KV pages: the grouped run holds group× fewer pages
        assert_eq!(rep_m.peak_pages, layout.group() * rep_g.peak_pages);
        assert_eq!(rep_m.resident_kv_bytes, layout.group() * rep_g.resident_kv_bytes);
    }

    #[test]
    fn decode_entry_matches_prefill_through_full_pipeline() {
        // queue -> drain_for_decode -> into_decode -> execute_decode:
        // generated rows must equal the prefill engine's rows for the
        // same requests, despite heterogeneous sequence lengths
        let (heads, d) = (2, 8);
        let mut q = RequestQueue::new();
        let originals: Vec<Request> =
            [(32usize, 1u64), (64, 2), (48, 3)].iter().map(|&(n, s)| rand_req(n, heads, d, s)).collect();
        for r in &originals {
            q.push(r.clone()).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig::default());
        let drained = s.drain_for_decode(&mut q, 8);
        assert_eq!(drained.len(), 3);
        let prompt = 8;
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        let report = eng
            .execute_decode(
                drained.into_iter().map(|r| r.into_decode(prompt)).collect(),
                crate::decode::BatcherConfig {
                    page_size: 16,
                    d,
                    max_pages: 256,
                    max_active: 4,
                    skip: true,
                    spec: crate::decode::SpecPolicy::Off,
                },
            )
            .unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, (32 - 8) + (64 - 8) + (48 - 8));
        assert!(report.pages_skip_fraction > 0.0, "doc masks should skip pages");
        assert_eq!(eng.completed.len(), 3);
        // completed is in retirement order (shortest first) — match by id
        for resp in &eng.completed {
            let req = &originals[resp.id as usize];
            let n = req.n;
            let bias = req.mask.dense_bias();
            let gen = (n - prompt) * d;
            for h in 0..heads {
                let want = dense::dense_forward(
                    req.head(&req.q, h),
                    req.head(&req.k, h),
                    req.head(&req.v, h),
                    n,
                    d,
                    &bias,
                    1.0 / (d as f32).sqrt(),
                );
                let got = &resp.o[h * gen..(h + 1) * gen];
                for (a, b) in got.iter().zip(&want.o[prompt * d..]) {
                    assert!((a - b).abs() < 1e-4, "n={n} h={h}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn speculative_decode_through_engine_matches_sequential() {
        // ServeEngine::execute_decode with a speculative config must
        // produce byte-for-byte the tokens and (to 1e-4) the rows of a
        // sequential run, while the report shows real draft activity
        use crate::decode::{BatcherConfig, SpecPolicy};
        let (heads, d, prompt) = (2, 8, 8);
        let originals: Vec<Request> = [(32usize, 11u64), (64, 12), (48, 13)]
            .iter()
            .enumerate()
            .map(|(i, &(n, s))| {
                let mut r = rand_req(n, heads, d, s);
                r.id = i as u64;
                r
            })
            .collect();
        let run = |spec: SpecPolicy| {
            let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
            let report = eng
                .execute_decode(
                    originals.iter().map(|r| r.clone().into_decode(prompt)).collect(),
                    BatcherConfig {
                        page_size: 16,
                        d,
                        max_pages: 256,
                        max_active: 4,
                        skip: true,
                        spec,
                    },
                )
                .unwrap();
            let mut done = eng.completed;
            done.sort_by_key(|r| r.id);
            (report, done)
        };
        let (seq_report, seq) = run(SpecPolicy::Off);
        let (spec_report, spec) =
            run(SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 2, seed: 5 });
        assert_eq!(seq_report.tokens, spec_report.tokens);
        assert_eq!(seq_report.drafted_tokens, 0);
        assert!(spec_report.drafted_tokens > 0);
        assert!(spec_report.accept_rate() > 0.5);
        for (a, b) in seq.iter().zip(&spec) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.o.len(), b.o.len());
            for (x, y) in a.o.iter().zip(&b.o) {
                assert!((x - y).abs() < 1e-4, "req {}: {x} vs {y}", a.id);
            }
        }
    }

    #[test]
    fn serve_loop_processes_all_and_reports() {
        let mut q = RequestQueue::new();
        for i in 0..6 {
            q.push(rand_req(32, 1, 8, i)).unwrap();
        }
        let s = Scheduler::new(SchedulerConfig { max_batch: 4, max_wait_ms: 0.0 });
        let mut eng = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
        while let Some(plan) = s.next_batch(&mut q, std::time::Instant::now()) {
            eng.execute(plan).unwrap();
        }
        assert_eq!(eng.completed.len(), 6);
        let rep = eng.report();
        assert_eq!(rep.requests, 6);
        assert!(rep.throughput_tok_s > 0.0);
        assert!(rep.p99_compute_ms >= rep.p50_compute_ms);
        assert!((0.0..=1.0).contains(&rep.mean_sparsity));
    }
}
