//! Async-style streaming serve router with token-budget admission.
//!
//! [`ContinuousBatcher`] is a synchronous admit/step-all/retire loop
//! whose admission is strict FIFO on bare *page counts*: it admits the
//! head-of-line request whenever its prompt pages fit, over-committing
//! the pool against decode growth and paying for it later with
//! preemption (evict + full re-decode).  This module is the serving
//! front end on top of the same [`DecodeSession`] machinery — an event
//! loop (one [`Router::tick`] per decode iteration; a single thread
//! simulates the async runtime, so no new runtime dependency) that
//! schedules the way production routers do (TGI's
//! `Infer`/`batching_task`):
//!
//! * **Token-budget admission in waves.**  Waiting requests are
//!   admitted in prefill waves bounded by
//!   [`RouterConfig::max_batch_prefill_tokens`] (prompt tokens per
//!   wave — bounds the decode stall a wave causes) and
//!   [`RouterConfig::max_batch_total_tokens`] (worst-case token
//!   residency of the running batch — bounds per-token latency and,
//!   set at or below the pool's token capacity, makes admission
//!   reservation-safe: the router also reserves every sequence's
//!   worst-case page demand, so it never has to preempt to keep its
//!   own promises).  [`RouterConfig::waiting_served_ratio`] and
//!   [`RouterConfig::max_waiting_tokens`] arbitrate *when* decode is
//!   paused for a wave: under decode pressure a wave must be worth the
//!   stall (at least `active × ratio` requests), unless
//!   `max_waiting_tokens` decode iterations have passed since the last
//!   wave, which forces admission so queued requests cannot starve.
//! * **Per-request streaming.**  [`Router::submit`] returns the
//!   receiving end of an unbounded [`std::sync::mpsc`] channel; the
//!   event loop emits [`StreamEvent`]s as the live batch decodes —
//!   `Admitted`, one `Token` per committed token (a speculative verify
//!   pass delivers its accepted prefix as a burst), `Preempted` when
//!   pool pressure evicts the session (progress is re-streamed from
//!   token 0 after readmission), and finally `Done` with the full
//!   response.  A dropped receiver is the cancellation signal: the
//!   next failed send retires the session mid-flight and releases its
//!   pages.
//! * **Mid-flight filter/concatenate.**  Finished and cancelled
//!   sessions are filtered out of the live batch the iteration they
//!   complete, and admission waves concatenate onto it — no
//!   end-of-batch barrier.
//!
//! The load side lives here too: [`poisson_arrivals_ms`] builds a
//! seeded open-loop Poisson arrival trace and [`replay_arrivals`]
//! replays it against any serving loop (`bench_serve` drives both this
//! router and the strict-FIFO batcher through it for the head-to-head
//! TTFT comparison).

use crate::decode::{
    BatcherConfig, DecodeRequest, DecodeResponse, DecodeSession, DecodeStats, PagePool,
    PrefixCache, PrefixStats, StepOutcome,
};
use crate::telemetry::{log, metrics, names, trace, Gauge, Histogram};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving configuration: the decode substrate plus TGI's four
/// admission knobs.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Decode substrate (page pool geometry, `max_active` slot cap,
    /// page skipping, speculation policy).
    pub batcher: BatcherConfig,
    /// Prompt tokens prefilled per admission wave.  A wave stalls
    /// every running sequence for its whole prefill, so this bounds
    /// the worst-case inter-token hiccup admission can inject.
    /// Requests whose prompt alone exceeds it are rejected at submit.
    pub max_batch_prefill_tokens: usize,
    /// Worst-case token residency (`Σ n` over running sequences) the
    /// router will admit.  Set at or below the pool's token capacity
    /// (`max_pages × page_size / kv_heads`-worth of sequences) it
    /// makes admission reservation-safe and decode preemption-free —
    /// the trade the batcher's eager page-count admission refuses.
    pub max_batch_total_tokens: usize,
    /// Minimum admission wave size under decode pressure, as a
    /// fraction of the running batch: a wave must carry at least
    /// `⌊active × ratio⌋` requests to be worth pausing decode for.
    /// `0.0` admits eagerly whenever anything fits.
    pub waiting_served_ratio: f64,
    /// Decode iterations allowed since the last wave before admission
    /// is forced despite `waiting_served_ratio` — the starvation
    /// valve for queued requests under a long-running batch.
    pub max_waiting_tokens: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            batcher: BatcherConfig::default(),
            max_batch_prefill_tokens: 4096,
            // BatcherConfig::default is 4096 pages × 16 tokens
            max_batch_total_tokens: 65_536,
            // TGI defaults for the two pacing knobs
            waiting_served_ratio: 1.2,
            max_waiting_tokens: 20,
        }
    }
}

/// One event on a request's stream, in emission order.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// The prompt was prefilled; decode begins.
    Admitted,
    /// Generated token `index` (0-based) committed.  Indices are
    /// consecutive within one admission; tokens committed together by
    /// a speculative verify pass arrive as a burst of events.
    Token { index: usize },
    /// Pool pressure evicted the session.  Progress is discarded
    /// (decode is deterministic, the retry reproduces it) and the
    /// request re-queued: after readmission tokens are re-streamed
    /// from index 0.
    Preempted,
    /// Terminal event: the full response, after which the channel
    /// closes.
    Done(Box<DecodeResponse>),
}

/// Aggregate router statistics.
#[derive(Clone, Debug)]
pub struct RouterReport {
    /// Sequences retired (cancelled ones excluded).
    pub sequences: usize,
    /// Useful generated tokens across retired sequences (preempted and
    /// cancelled work uncounted).
    pub tokens: u64,
    pub tokens_per_s: f64,
    pub preemptions: u64,
    /// Requests dropped because their stream receiver was gone.
    pub cancelled: u64,
    /// Prefills that failed inside a wave after planning (rolled back
    /// and re-queued) — defensive seam, see `ContinuousBatcher`'s
    /// `admit_one`.
    pub prefill_rejects: u64,
    /// Admission waves that prefilled at least one request.
    pub waves: u64,
    /// Waves admitted only because `max_waiting_tokens` expired (the
    /// ratio gate alone would have kept waiting).
    pub forced_waves: u64,
    pub peak_pages: usize,
    /// Fraction of cache pages skipped across retired sequences.
    pub pages_skip_fraction: f64,
    pub drafted_tokens: u64,
    pub accepted_tokens: u64,
    /// Time-to-first-token percentiles across retired sequences
    /// (arrival → first token; log2 buckets, DESIGN.md §Telemetry).
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    /// Inter-token-latency percentiles over *per-token* gap samples.
    pub itl_p50_ms: f64,
    pub itl_p99_ms: f64,
    /// Prefix-cache lookups that attached a shared prompt prefix
    /// (0 with `prefix_cache` off).
    pub prefix_hits: u64,
    /// Prefix-cache lookups that found nothing reusable.
    pub prefix_misses: u64,
    /// Pages attached as shared prefixes instead of being recomputed.
    pub prefix_shared_pages: u64,
    /// Shared pages cloned before a write (copy-on-write events).
    pub cow_copies: u64,
}

/// Streaming serve router: an event loop over [`DecodeSession`]s with
/// token-budget wave admission.  Drive it with [`tick`](Self::tick)
/// (one decode iteration) or [`run`](Self::run) (to completion).
pub struct Router {
    pub cfg: RouterConfig,
    pool: PagePool,
    /// Content-addressed prompt-prefix index (`Some` iff
    /// `cfg.batcher.prefix_cache`) — shared with every wave's prefill,
    /// so a shared-system-prompt burst attaches one resident copy of
    /// the prompt instead of N (see `ContinuousBatcher`'s field twin).
    prefix: Option<PrefixCache>,
    waiting: VecDeque<DecodeRequest>,
    active: Vec<DecodeSession>,
    /// Sender side of each live request's stream.  Requests submitted
    /// detached have no entry and can never be cancelled.
    streams: HashMap<u64, Sender<StreamEvent>>,
    /// Tokens already streamed per active session (reset on
    /// preemption: the retry re-streams from 0).
    streamed: HashMap<u64, usize>,
    finished: Vec<DecodeResponse>,
    agg: DecodeStats,
    preemptions: u64,
    cancelled: u64,
    prefill_rejects: u64,
    waves: u64,
    forced_waves: u64,
    /// Decode iterations since the last admission wave — TGI's
    /// `waiting_tokens` counter, compared against `max_waiting_tokens`.
    ticks_since_wave: usize,
    decoded_tokens: u64,
    started: Instant,
    /// This router's latency distributions (the report's percentiles)…
    ttft: Histogram,
    itl: Histogram,
    /// …mirrored into the process-wide registry (handles resolved once
    /// so the hot loop never takes the registry lock).
    g_ttft: Arc<Histogram>,
    g_itl: Arc<Histogram>,
    g_active: Arc<Gauge>,
    g_waiting: Arc<Gauge>,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        assert!(cfg.batcher.max_active >= 1, "max_active must be >= 1");
        assert!(
            cfg.waiting_served_ratio.is_finite() && cfg.waiting_served_ratio >= 0.0,
            "waiting_served_ratio must be a finite non-negative fraction"
        );
        let reg = metrics::global();
        Router {
            cfg,
            pool: PagePool::new(cfg.batcher.page_size, cfg.batcher.d, cfg.batcher.max_pages),
            prefix: cfg.batcher.prefix_cache.then(PrefixCache::new),
            waiting: VecDeque::new(),
            active: Vec::new(),
            streams: HashMap::new(),
            streamed: HashMap::new(),
            finished: Vec::new(),
            agg: DecodeStats::default(),
            preemptions: 0,
            cancelled: 0,
            prefill_rejects: 0,
            waves: 0,
            forced_waves: 0,
            ticks_since_wave: 0,
            decoded_tokens: 0,
            started: Instant::now(),
            ttft: Histogram::new(),
            itl: Histogram::new(),
            g_ttft: reg.histogram(names::ROUTER_TTFT_MS),
            g_itl: reg.histogram(names::ROUTER_ITL_MS),
            g_active: reg.gauge(names::ROUTER_ACTIVE_PEAK),
            g_waiting: reg.gauge(names::ROUTER_WAITING_PEAK),
        }
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Prefix-cache counters so far (zeroes when sharing is off).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|c| c.stats).unwrap_or_default()
    }

    /// Drop every prefix-cache entry, releasing the cache's page
    /// references (live sessions keep shared pages alive).  Call before
    /// asserting a fully drained pool, or to return donated residency
    /// when a workload phase ends.
    pub fn release_prefix_cache(&mut self) {
        if let Some(cache) = &mut self.prefix {
            cache.release_all(&mut self.pool);
        }
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    fn is_live(&self, id: u64) -> bool {
        self.streams.contains_key(&id)
            || self.waiting.iter().any(|r| r.id == id)
            || self.active.iter().any(|s| s.req.id == id)
    }

    /// Reject requests no configuration of this router could ever
    /// serve: they would wait forever, not just long.
    fn validate(&self, req: &DecodeRequest) -> Result<()> {
        req.mask.validate()?;
        ensure!(
            req.d == self.cfg.batcher.d,
            "head dim {} != pool row width {}",
            req.d,
            self.cfg.batcher.d
        );
        let worst = req.pages_needed(self.cfg.batcher.page_size);
        ensure!(
            worst <= self.cfg.batcher.max_pages,
            "request {} needs up to {worst} pages, pool holds {}",
            req.id,
            self.cfg.batcher.max_pages
        );
        // budget feasibility: a prompt that alone exceeds the per-wave
        // prefill budget, or a sequence that alone exceeds the running
        // token budget, can never be admitted
        ensure!(
            req.prompt_len.max(1) <= self.cfg.max_batch_prefill_tokens,
            "request {} prompt ({} tokens) exceeds max_batch_prefill_tokens ({})",
            req.id,
            req.prompt_len,
            self.cfg.max_batch_prefill_tokens
        );
        ensure!(
            req.n <= self.cfg.max_batch_total_tokens,
            "request {} needs {} total tokens, max_batch_total_tokens is {}",
            req.id,
            req.n,
            self.cfg.max_batch_total_tokens
        );
        ensure!(!self.is_live(req.id), "request id {} is already live", req.id);
        Ok(())
    }

    /// Queue a request and return the receiving end of its stream.
    /// Dropping the receiver cancels the request: the router retires
    /// the session at its next failed send and releases its pages.
    pub fn submit(&mut self, req: DecodeRequest) -> Result<Receiver<StreamEvent>> {
        self.validate(&req)?;
        let (tx, rx) = channel();
        self.streams.insert(req.id, tx);
        self.waiting.push_back(req);
        self.g_waiting.set_max(self.waiting.len() as u64);
        Ok(rx)
    }

    /// Queue a request without a stream (throughput callers that only
    /// want [`take_finished`](Self::take_finished)).  Detached
    /// requests cannot be cancelled.
    pub fn submit_detached(&mut self, req: DecodeRequest) -> Result<()> {
        self.validate(&req)?;
        self.waiting.push_back(req);
        self.g_waiting.set_max(self.waiting.len() as u64);
        Ok(())
    }

    /// Send an event on `id`'s stream.  `false` means the receiver is
    /// gone (client hang-up) and the caller must cancel the request;
    /// detached requests have no stream and always report delivered.
    fn emit(&self, id: u64, ev: StreamEvent) -> bool {
        match self.streams.get(&id) {
            Some(tx) => tx.send(ev).is_ok(),
            None => true,
        }
    }

    /// Forget a request whose receiver hung up: close its stream and
    /// count the cancellation (its pages are already released).
    fn cancel(&mut self, id: u64) {
        self.streams.remove(&id);
        self.streamed.remove(&id);
        self.cancelled += 1;
        metrics::global().add(names::ROUTER_CANCELLED, 1);
        log::info(names::TARGET_ROUTER, format!("request {id}: stream dropped, cancelled"));
    }

    /// Plan and run one admission wave if it clears the pacing gates.
    /// Returns `true` when the wave consumed at least one waiting
    /// request (admitted or cancelled) — `false` means decode should
    /// proceed undisturbed.
    fn admit_wave(&mut self) -> Result<bool> {
        if self.waiting.is_empty() {
            return Ok(false);
        }
        let forced = self.ticks_since_wave >= self.cfg.max_waiting_tokens;
        // pacing gate: under decode pressure a wave must be worth the
        // prefill stall it injects, unless starvation forces it
        let ratio_min = if self.active.is_empty() {
            1
        } else {
            (((self.active.len() as f64) * self.cfg.waiting_served_ratio).floor() as usize).max(1)
        };
        let min_size = if forced { 1 } else { ratio_min };

        // plan the wave: the longest FIFO prefix within all budgets.
        // Pool feasibility reserves every sequence's *worst-case* page
        // demand (active remainder + wave), so an admitted sequence can
        // always decode to completion — budget admission trades prefill
        // latency for a preemption-free decode plateau.
        let ps = self.cfg.batcher.page_size;
        let mut prefill_tokens = 0usize;
        let mut total_tokens: usize = self.active.iter().map(|s| s.req.n).sum();
        let reserved: usize =
            self.active.iter().map(|s| s.req.pages_needed(ps) - s.pages_held()).sum();
        let mut pages_left = self.pool.available().saturating_sub(reserved);
        let mut wave: Vec<DecodeRequest> = Vec::new();
        // aligned-prefix hash chains of the wave planned so far: a later
        // candidate whose prompt shares a cached — or earlier-wave-member —
        // prefix reserves only its *new* pages.  Hash equality here is a
        // reservation estimate (prefill byte-checks before attaching); a
        // collision at worst under-reserves and falls into the handled
        // prefill-reject path.
        let mut wave_hashes: Vec<(usize, Vec<u64>)> = Vec::new();
        while self.active.len() + wave.len() < self.cfg.batcher.max_active {
            let Some(req) = self.waiting.front() else { break };
            let cost = req.prompt_len.max(1);
            let mut shared = 0usize;
            if let Some(cache) = &self.prefix {
                let hashes = req.prefix_hashes(ps);
                if !hashes.is_empty() {
                    let kv = req.layout.kv_heads;
                    shared =
                        kv * cache.peek(&self.pool, kv, &hashes, &req.k, &req.v, req.n);
                    for (kv2, h2) in &wave_hashes {
                        if *kv2 == kv {
                            let common =
                                hashes.iter().zip(h2.iter()).take_while(|(a, b)| a == b).count();
                            shared = shared.max(kv * common);
                        }
                    }
                    wave_hashes.push((kv, hashes));
                }
            }
            let worst = req.pages_needed(ps) - shared;
            if prefill_tokens + cost > self.cfg.max_batch_prefill_tokens
                || total_tokens + req.n > self.cfg.max_batch_total_tokens
                || worst > pages_left
            {
                break;
            }
            prefill_tokens += cost;
            total_tokens += req.n;
            pages_left -= worst;
            wave.push(self.waiting.pop_front().unwrap());
        }
        if wave.len() < min_size {
            // not worth stalling decode: restore FIFO order and wait
            for req in wave.into_iter().rev() {
                self.waiting.push_front(req);
            }
            return Ok(false);
        }
        let was_forced = forced && wave.len() < ratio_min;

        let sp = trace::span(names::ROUTER_WAVE);
        sp.add("requests", wave.len() as u64);
        sp.add("prefill_tokens", prefill_tokens as u64);
        let reg = metrics::global();
        self.waves += 1;
        reg.add(names::ROUTER_WAVES, 1);
        if was_forced {
            self.forced_waves += 1;
            reg.add(names::ROUTER_FORCED_WAVES, 1);
        }
        for req in wave {
            let id = req.id;
            let mut session = DecodeSession::new(req, ps);
            if let Some(proposer) = self.cfg.batcher.spec.build(id) {
                session.set_speculation(
                    proposer,
                    self.cfg.batcher.spec.k(),
                    self.cfg.batcher.spec.adaptive(),
                );
            }
            if !session.prefill(&mut self.pool, self.prefix.as_mut()) {
                // defensive seam (cf. ContinuousBatcher::admit_one):
                // the reservation above makes this unreachable from
                // safe configs, but a failed prefill must still roll
                // back and re-queue, never silently enter the batch
                self.prefill_rejects += 1;
                reg.add(names::ROUTER_PREFILL_REJECTS, 1);
                log::warn(
                    names::TARGET_ROUTER,
                    format!("request {id}: prefill failed inside the wave; re-queued"),
                );
                self.waiting.push_front(session.preempt(&mut self.pool));
                break;
            }
            if !self.emit(id, StreamEvent::Admitted) {
                // the client hung up while the request queued: release
                // the prompt pages before paying any decode work
                let _ = session.preempt(&mut self.pool);
                self.cancel(id);
                continue;
            }
            self.streamed.insert(id, 0);
            self.active.push(session);
        }
        self.ticks_since_wave = 0;
        self.g_active.set_max(self.active.len() as u64);
        Ok(true)
    }

    /// One event-loop iteration: run an admission wave if due, step
    /// every active session one decode iteration, stream newly
    /// committed tokens, and filter finished/cancelled sessions out of
    /// the live batch.  Returns `false` when no work remains.
    pub fn tick(&mut self) -> Result<bool> {
        if self.active.is_empty() && self.waiting.is_empty() {
            return Ok(false);
        }
        let waved = self.admit_wave()?;
        if !waved {
            self.ticks_since_wave += 1;
        }
        if self.active.is_empty() {
            if self.waiting.is_empty() {
                return Ok(false);
            }
            // an idle router admits unconditionally (wave minimum is 1
            // and every budget was single-request-checked at submit),
            // so reaching here without progress is a bug, not
            // backpressure
            ensure!(
                waved,
                "request {} cannot be admitted into an idle router",
                self.waiting.front().map(|r| r.id).unwrap_or(0)
            );
            return Ok(true);
        }

        let mut i = 0;
        while i < self.active.len() {
            let id = self.active[i].req.id;
            let before = self.active[i].pos;
            let outcome = if self.active[i].speculative() {
                self.active[i].try_speculate(&mut self.pool, self.cfg.batcher.skip)
            } else {
                self.active[i].try_step(&mut self.pool, self.cfg.batcher.skip)
            };
            match outcome {
                StepOutcome::NoPage => {
                    // reservation admission makes this unreachable, but
                    // the batcher's newest-first preemption is kept as
                    // the defensive fallback: fail soft, not loud
                    if self.active.len() == 1 {
                        bail!(
                            "session {id} stalled alone on an exhausted pool ({} pages)",
                            self.pool.capacity()
                        );
                    }
                    let victim = self.active.len() - 1;
                    let s = self.active.remove(victim);
                    let vid = s.req.id;
                    self.preemptions += 1;
                    metrics::global().add(names::ROUTER_PREEMPTIONS, 1);
                    self.decoded_tokens -= (s.pos - s.req.prompt_len) as u64;
                    self.streamed.remove(&vid);
                    let req = s.preempt(&mut self.pool);
                    if self.emit(vid, StreamEvent::Preempted) {
                        self.waiting.push_front(req);
                    } else {
                        self.cancel(vid);
                    }
                }
                StepOutcome::Stepped | StepOutcome::Finished => {
                    self.decoded_tokens += (self.active[i].pos - before) as u64;
                    // stream every token this iteration committed
                    let gen = self.active[i].pos - self.active[i].req.prompt_len;
                    let from = self.streamed.get(&id).copied().unwrap_or(0);
                    let mut delivered = true;
                    for index in from..gen {
                        if !self.emit(id, StreamEvent::Token { index }) {
                            delivered = false;
                            break;
                        }
                    }
                    if !delivered {
                        // client hung up mid-decode: filter the session
                        // out of the live batch and release its pages
                        let s = self.active.remove(i);
                        self.decoded_tokens -= (s.pos - s.req.prompt_len) as u64;
                        let _ = s.preempt(&mut self.pool);
                        self.cancel(id);
                        continue; // slot i now holds the next session
                    }
                    self.streamed.insert(id, gen);
                    if outcome == StepOutcome::Finished {
                        let s = self.active.remove(i);
                        self.agg.merge(&s.stats);
                        s.stats.publish();
                        let resp = s.retire(&mut self.pool);
                        self.ttft.record_ms(resp.ttft_ms);
                        self.g_ttft.record_ms(resp.ttft_ms);
                        for &gap in &resp.itl_gaps_ms {
                            self.itl.record_ms(gap);
                            self.g_itl.record_ms(gap);
                        }
                        self.streamed.remove(&id);
                        let _ = self.emit(id, StreamEvent::Done(Box::new(resp.clone())));
                        self.streams.remove(&id);
                        self.finished.push(resp);
                        continue;
                    }
                    i += 1;
                }
            }
        }
        Ok(!(self.active.is_empty() && self.waiting.is_empty()))
    }

    /// Drive the event loop until every submitted request has retired
    /// or been cancelled.
    pub fn run(&mut self) -> Result<RouterReport> {
        while self.tick()? {}
        Ok(self.report())
    }

    /// Completed responses, in retirement order.
    pub fn take_finished(&mut self) -> Vec<DecodeResponse> {
        std::mem::take(&mut self.finished)
    }

    pub fn report(&self) -> RouterReport {
        RouterReport {
            sequences: self.finished.len(),
            tokens: self.decoded_tokens,
            tokens_per_s: self.decoded_tokens as f64
                / self.started.elapsed().as_secs_f64().max(1e-9),
            preemptions: self.preemptions,
            cancelled: self.cancelled,
            prefill_rejects: self.prefill_rejects,
            waves: self.waves,
            forced_waves: self.forced_waves,
            peak_pages: self.pool.stats.peak_in_use,
            pages_skip_fraction: self.agg.skip_fraction(),
            drafted_tokens: self.agg.drafted,
            accepted_tokens: self.agg.accepted,
            ttft_p50_ms: self.ttft.quantile_ms(0.50),
            ttft_p99_ms: self.ttft.quantile_ms(0.99),
            itl_p50_ms: self.itl.quantile_ms(0.50),
            itl_p99_ms: self.itl.quantile_ms(0.99),
            prefix_hits: self.prefix_stats().hits,
            prefix_misses: self.prefix_stats().misses,
            prefix_shared_pages: self.prefix_stats().shared_pages,
            cow_copies: self.pool.stats.cow_copies,
        }
    }
}

/// Cumulative Poisson arrival times in ms: exponential inter-arrival
/// gaps at `rate_per_s`, inverse-CDF sampled from the seeded
/// generator — the standard memoryless open-loop load model.
pub fn poisson_arrivals_ms(rate_per_s: f64, count: usize, rng: &mut Rng) -> Vec<f64> {
    assert!(rate_per_s > 0.0 && rate_per_s.is_finite());
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            let u = 1.0 - rng.f64(); // (0, 1]: ln never sees 0
            t += -u.ln() * 1e3 / rate_per_s;
            t
        })
        .collect()
}

/// Replay a timed arrival trace against a serving loop.
///
/// `step(Some(req))` submits a request the moment its arrival time
/// passes (its `arrived` stamp is refreshed to the true submission
/// instant, so TTFT measures real queueing); `step(None)` runs one
/// scheduler iteration and reports whether work remains.  The loop
/// sleeps only when the system is idle and the next arrival is in the
/// future.  Returns the replay's wall-clock milliseconds.
pub fn replay_arrivals<F>(reqs: Vec<DecodeRequest>, due_ms: &[f64], mut step: F) -> Result<f64>
where
    F: FnMut(Option<DecodeRequest>) -> Result<bool>,
{
    assert_eq!(reqs.len(), due_ms.len(), "one arrival time per request");
    let t0 = Instant::now();
    let mut pending: VecDeque<DecodeRequest> = VecDeque::from(reqs);
    let mut next = 0usize;
    loop {
        if !pending.is_empty() && t0.elapsed().as_secs_f64() * 1e3 >= due_ms[next] {
            let mut req = pending.pop_front().unwrap();
            next += 1;
            req.arrived = Instant::now();
            step(Some(req))?;
            continue;
        }
        let more = step(None)?;
        if !more {
            if pending.is_empty() {
                break;
            }
            let wait_ms = (due_ms[next] - t0.elapsed().as_secs_f64() * 1e3).max(0.0);
            std::thread::sleep(Duration::from_micros((wait_ms * 1e3) as u64 + 1));
        }
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::{ContinuousBatcher, SpecPolicy};
    use crate::mask::builders;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }

    fn request(id: u64, n: usize, d: usize, prompt: usize, seed: u64) -> DecodeRequest {
        let mut rng = Rng::new(seed);
        DecodeRequest::new(
            id,
            1,
            n,
            d,
            prompt,
            rand_vec(n * d, &mut rng),
            rand_vec(n * d, &mut rng),
            rand_vec(n * d, &mut rng),
            builders::causal(n),
        )
    }

    fn cfg(page_size: usize, d: usize, max_pages: usize, max_active: usize) -> RouterConfig {
        RouterConfig {
            batcher: BatcherConfig {
                page_size,
                d,
                max_pages,
                max_active,
                skip: true,
                spec: SpecPolicy::Off,
                prefix_cache: false,
            },
            max_batch_prefill_tokens: 4096,
            max_batch_total_tokens: max_pages * page_size,
            waiting_served_ratio: 1.2,
            max_waiting_tokens: 20,
        }
    }

    /// Drain a stream and assert its ordering contract: `Admitted`
    /// first, consecutive `Token` indices from 0 (restarting after
    /// each `Preempted`), one terminal `Done`.
    fn drain_stream(rx: &Receiver<StreamEvent>) -> (usize, Option<DecodeResponse>) {
        let mut expect = 0usize;
        let mut admitted = false;
        let mut done = None;
        while let Ok(ev) = rx.try_recv() {
            assert!(done.is_none(), "no events may follow Done");
            match ev {
                StreamEvent::Admitted => {
                    assert!(!admitted, "Admitted must not repeat without a Preempted");
                    admitted = true;
                }
                StreamEvent::Token { index } => {
                    assert!(admitted, "tokens require admission");
                    assert_eq!(index, expect, "token indices must be consecutive");
                    expect += 1;
                }
                StreamEvent::Preempted => {
                    assert!(admitted);
                    admitted = false;
                    expect = 0;
                }
                StreamEvent::Done(resp) => done = Some(*resp),
            }
        }
        (expect, done)
    }

    #[test]
    fn router_streams_tokens_and_matches_batcher_outputs() {
        // the router is a scheduler, not a kernel: its retired outputs
        // must be byte-identical to the strict-FIFO batcher's for the
        // same requests, and every stream must follow the contract
        let d = 8;
        let reqs: Vec<DecodeRequest> = [(0u64, 40usize, 8usize), (1, 64, 16), (2, 96, 0)]
            .iter()
            .map(|&(id, n, p)| request(id, n, d, p, 7000 + id))
            .collect();

        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 16,
            d,
            max_pages: 64,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        let mut r = Router::new(cfg(16, d, 64, 4));
        let mut rxs = Vec::new();
        for req in &reqs {
            b.submit(req.clone()).unwrap();
            rxs.push(r.submit(req.clone()).unwrap());
        }
        b.run().unwrap();
        let report = r.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.cancelled, 0);
        assert_eq!(report.tokens, (40 - 8) + (64 - 16) + 96);
        assert!(report.waves >= 1);
        assert_eq!(r.pool().in_use(), 0);

        let mut from_batcher = b.take_finished();
        let mut from_router = r.take_finished();
        from_batcher.sort_by_key(|x| x.id);
        from_router.sort_by_key(|x| x.id);
        for (a, c) in from_batcher.iter().zip(&from_router) {
            assert_eq!(a.id, c.id);
            assert_eq!(a.o, c.o, "req {}: router output diverged from batcher", a.id);
        }
        for (req, rx) in reqs.iter().zip(&rxs) {
            let (tokens, done) = drain_stream(rx);
            assert_eq!(tokens, req.gen_len(), "req {}: one Token event per token", req.id);
            let resp = done.expect("stream must end with Done");
            assert_eq!(resp.id, req.id);
            assert_eq!(resp.itl_gaps_ms.len(), req.gen_len() - 1);
        }
    }

    #[test]
    fn infeasible_budget_requests_rejected_at_submit() {
        let d = 4;
        let mut c = cfg(8, d, 64, 4);
        c.max_batch_prefill_tokens = 8;
        c.max_batch_total_tokens = 64;
        let mut r = Router::new(c);
        // prompt alone exceeds the per-wave prefill budget
        let err = r.submit(request(0, 32, d, 16, 1)).unwrap_err();
        assert!(err.to_string().contains("max_batch_prefill_tokens"), "{err}");
        // total sequence length alone exceeds the running token budget
        let err = r.submit(request(1, 96, d, 4, 2)).unwrap_err();
        assert!(err.to_string().contains("max_batch_total_tokens"), "{err}");
        // a feasible request still passes, and duplicate ids do not
        let rx = r.submit(request(2, 32, d, 4, 3)).unwrap();
        assert!(r.submit(request(2, 32, d, 4, 4)).is_err(), "duplicate live id");
        let report = r.run().unwrap();
        assert_eq!(report.sequences, 1);
        drop(rx);
    }

    #[test]
    fn waiting_served_ratio_pauses_prefill_until_forced() {
        // decode pressure: with 2 running and ratio 2.0 a 1-request
        // wave is not worth the stall — admission must wait until
        // max_waiting_tokens decode iterations force it
        let d = 4;
        let mut c = cfg(8, d, 64, 8);
        c.waiting_served_ratio = 2.0;
        c.max_waiting_tokens = 4;
        let mut r = Router::new(c);
        let mut rxs = Vec::new();
        for id in 0..2u64 {
            rxs.push(r.submit(request(id, 64, d, 32, 8000 + id)).unwrap());
        }
        assert!(r.tick().unwrap()); // first wave admits both
        assert_eq!(r.active_len(), 2);
        assert_eq!(r.report().waves, 1);
        rxs.push(r.submit(request(2, 64, d, 32, 8002)).unwrap());
        // ratio gate: floor(2 × 2.0) = 4 > 1 waiting, so decode runs
        // undisturbed while the starvation counter climbs…
        for _ in 0..4 {
            assert!(r.tick().unwrap());
            assert_eq!(r.active_len(), 2, "wave must pause under the ratio gate");
            assert_eq!(r.waiting_len(), 1);
        }
        // …and the max_waiting_tokens valve forces the admission
        assert!(r.tick().unwrap());
        assert_eq!(r.active_len(), 3, "forced wave must admit the straggler");
        let report = r.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.waves, 2);
        assert_eq!(report.forced_waves, 1);
        for rx in &rxs {
            let (_, done) = drain_stream(rx);
            assert!(done.is_some());
        }
    }

    #[test]
    fn dropped_receiver_cancels_mid_decode_and_releases_pages() {
        // the client hangs up mid-decode: the session must be filtered
        // out of the live batch, its pages released, the other request
        // unaffected
        let d = 4;
        let mut r = Router::new(cfg(8, d, 64, 4));
        let rx0 = r.submit(request(0, 48, d, 16, 9000)).unwrap();
        let rx1 = r.submit(request(1, 48, d, 16, 9001)).unwrap();
        for _ in 0..4 {
            assert!(r.tick().unwrap());
        }
        assert_eq!(r.active_len(), 2);
        drop(rx0);
        let report = r.run().unwrap();
        assert_eq!(report.cancelled, 1);
        assert_eq!(report.sequences, 1, "only the surviving request retires");
        assert_eq!(r.pool().in_use(), 0, "cancelled session must release its pages");
        assert!(r.pool().conserved());
        let (tokens, done) = drain_stream(&rx1);
        assert_eq!(tokens, 32);
        assert_eq!(done.unwrap().id, 1);
        // cancelled work is uncounted, like preempted work
        assert_eq!(report.tokens, 32);
    }

    #[test]
    fn total_token_budget_bounds_concurrency_without_preemption() {
        // max_batch_total_tokens 128 with n=64 sequences: at most two
        // run at once, everything completes, and reservation admission
        // never needs to preempt
        let d = 4;
        let mut c = cfg(8, d, 64, 8);
        c.max_batch_total_tokens = 128;
        let mut r = Router::new(c);
        let mut rxs = Vec::new();
        for id in 0..5u64 {
            rxs.push(r.submit(request(id, 64, d, 8, 9100 + id)).unwrap());
        }
        loop {
            if !r.tick().unwrap() {
                break;
            }
            assert!(r.active_len() <= 2, "token budget must cap concurrency");
        }
        let report = r.report();
        assert_eq!(report.sequences, 5);
        assert_eq!(report.preemptions, 0, "reservation admission never preempts");
        assert_eq!(r.pool().in_use(), 0);
        for rx in &rxs {
            let (tokens, done) = drain_stream(rx);
            assert_eq!(tokens, 64 - 8);
            assert!(done.is_some());
        }
    }

    #[test]
    fn detached_requests_complete_without_streams() {
        let d = 4;
        let mut r = Router::new(cfg(8, d, 64, 4));
        for id in 0..3u64 {
            r.submit_detached(request(id, 32, d, 8, 9200 + id)).unwrap();
        }
        let report = r.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.cancelled, 0);
        assert_eq!(r.take_finished().len(), 3);
    }

    #[test]
    fn shared_prompt_burst_admits_more_sessions_with_prefix_cache() {
        // acceptance criterion: a burst of requests sharing a system
        // prompt admits strictly more concurrent sessions with the
        // prefix cache on than off at equal pool size — with zero
        // preemptions either way and identical streamed outputs.
        // Geometry: prompt 32 = 4 pages, n = 40 → pages_needed = 5; a
        // 12-page pool reserves ⌊12/5⌋ = 2 sessions without sharing,
        // but with sharing the 2nd..6th reserve only 1 new page each.
        let d = 4;
        let base = request(0, 40, d, 32, 9400);
        let run = |prefix_cache: bool| {
            let mut c = cfg(8, d, 12, 8);
            c.batcher.prefix_cache = prefix_cache;
            // token budgets are deliberately slack: page reservation is
            // the binding constraint this test compares across modes
            c.max_batch_total_tokens = 4096;
            let mut r = Router::new(c);
            let mut rxs = Vec::new();
            for id in 0..6u64 {
                let mut req = base.clone();
                req.id = id;
                rxs.push(r.submit(req).unwrap());
            }
            let mut max_active = 0;
            loop {
                if !r.tick().unwrap() {
                    break;
                }
                max_active = max_active.max(r.active_len());
            }
            let report = r.report();
            let mut done = Vec::new();
            for rx in &rxs {
                let (tokens, resp) = drain_stream(rx);
                assert_eq!(tokens, 8, "every stream must carry all 8 tokens");
                done.push(resp.expect("stream must end with Done"));
            }
            done.sort_by_key(|x| x.id);
            r.release_prefix_cache();
            assert_eq!(r.pool().in_use(), 0);
            assert!(r.pool().conserved());
            (report, max_active, done)
        };
        let (off, off_max, off_done) = run(false);
        let (on, on_max, on_done) = run(true);
        assert_eq!(off.preemptions, 0, "reservation admission never preempts");
        assert_eq!(on.preemptions, 0, "sharing must not introduce preemption");
        assert_eq!(off.sequences, 6);
        assert_eq!(on.sequences, 6);
        assert!(
            on_max > off_max,
            "sharing must admit strictly more concurrent sessions: {on_max} vs {off_max}"
        );
        assert_eq!(off_max, 2, "worst-case reservation caps the no-sharing burst");
        assert_eq!(off.prefix_hits, 0);
        assert!(on.prefix_hits >= 1, "the shared prompt must hit the cache");
        assert!(on.prefix_shared_pages >= 4);
        for (x, y) in off_done.iter().zip(&on_done) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.o, y.o, "req {}: sharing changed streamed outputs", x.id);
        }
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_rate_scaled() {
        let mut rng = Rng::new(42);
        let arr = poisson_arrivals_ms(100.0, 500, &mut rng);
        assert_eq!(arr.len(), 500);
        assert!(arr.windows(2).all(|w| w[1] >= w[0]), "arrival times must be monotone");
        // mean inter-arrival ≈ 10ms at 100 req/s; the seeded sample
        // mean stays within a loose statistical band
        let mean = arr.last().unwrap() / 500.0;
        assert!((5.0..20.0).contains(&mean), "mean gap {mean}ms");
    }

    #[test]
    fn replay_drives_router_under_poisson_load() {
        // end-to-end: a seeded Poisson trace replayed against the
        // router; every request must retire with a full stream
        let d = 4;
        let reqs: Vec<DecodeRequest> =
            (0..6u64).map(|id| request(id, 40, d, 8, 9300 + id)).collect();
        let mut rng = Rng::new(7);
        let due = poisson_arrivals_ms(2000.0, reqs.len(), &mut rng);
        let mut r = Router::new(cfg(8, d, 64, 4));
        let mut rxs = Vec::new();
        let wall_ms = replay_arrivals(reqs, &due, |cmd| match cmd {
            Some(req) => {
                rxs.push(r.submit(req)?);
                Ok(true)
            }
            None => r.tick(),
        })
        .unwrap();
        assert!(wall_ms > 0.0);
        let report = r.report();
        assert_eq!(report.sequences, 6);
        assert_eq!(report.cancelled, 0);
        assert!(report.ttft_p99_ms >= report.ttft_p50_ms);
        for rx in &rxs {
            let (tokens, done) = drain_stream(rx);
            assert_eq!(tokens, 32);
            assert!(done.is_some());
        }
    }
}
