//! Inference serving substrate (paper appendix B).
//!
//! FlashMask is "equally effective during the inference stage": the
//! paper benchmarks prefill attention against FlashInfer.  This module
//! provides the L3 serving pieces a deployment would need around that
//! kernel: a [`queue`] of masked-attention requests, a [`scheduler`]
//! that forms batches with compatible shapes/masks (prefill) or drains
//! shape-heterogeneous requests for continuous batching (decode, see
//! [`crate::decode`]), and an [`engine`] that executes them (CPU engine
//! or the AOT `attn_fwd` artifact via PJRT) and reports per-request
//! latency plus aggregate throughput.
//!
//! On top of the decode stack sits the [`router`]: an async-style
//! streaming front end with TGI-style token-budget admission
//! (`max_batch_prefill_tokens` / `max_batch_total_tokens` /
//! `waiting_served_ratio` / `max_waiting_tokens`), per-request
//! [`std::sync::mpsc`] token streams, and a Poisson load
//! generator + trace replayer for latency benchmarking (DESIGN.md
//! §Serving).

pub mod engine;
pub mod queue;
pub mod router;
pub mod scheduler;

pub use engine::{EngineKind, ServeEngine, ServeReport};
pub use queue::{Request, RequestQueue, Response};
pub use router::{
    poisson_arrivals_ms, replay_arrivals, Router, RouterConfig, RouterReport, StreamEvent,
};
pub use scheduler::{BatchPlan, Scheduler, SchedulerConfig};
