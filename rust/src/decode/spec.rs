//! Speculative decoding: draft → tree-mask verify → commit/rollback.
//!
//! Sequential decode pays one full pass over the KV cache per token.
//! Speculative decoding proposes a *tree* of `k` draft tokens and
//! scores every drafted row in **one** pass over the cache pages
//! ([`verify_rows`]), then commits the longest accepted root path and
//! rolls the cache back past the rejected remainder — the
//! FlashAttention-2 observation that batching query rows through a
//! single online-softmax pass is where decode throughput lives,
//! made exact for arbitrary FlashMask-masked models:
//!
//! * Draft columns are masked by [`crate::mask::builders::tree_mask`]
//!   — ancestor visibility as LTS/LTE column intervals — and whole
//!   pages the draft cannot see are skipped by the same
//!   [`IncrementalMaskView`] classifier the sequential step uses.
//! * Committed columns are masked by the request's *base* mask
//!   evaluated at each node's **logical** position `t0 + depth(node)`
//!   (the position the node would hold if its root path were committed
//!   sequentially), so row-dependent masks — sliding windows, document
//!   packing, KV eviction — stay exact under speculation.
//!
//! **Exactness guarantee** (the decode analogue of the paper's §4.4):
//! acceptance is greedy — a draft node is accepted iff its proposed
//! token rows equal the teacher-forced truth rows bitwise — so the
//! committed cache is always byte-identical to sequential decode's
//! cache, and accepted output rows match the sequential step kernel to
//! float-accumulation order.  `tests/decode_oracle.rs` pins sequential,
//! speculative (k = 1..4) and full prefill to each other for every
//! causal benchmark mask family.

// lint: allow-file(hot-path-panic:index) — draft/tree indices are
// bounded by the preorder tree layout (`parents[j] < j`, len == kd) and
// the same page geometry as the sequential step; decode_oracle.rs pins
// every path bitwise against sequential decode.

use super::kvcache::{PagePool, PagedKv};
use super::session::DecodeRequest;
use super::step::DecodeStats;
use crate::attention::gemm;
use crate::mask::{BlockClass, FlashMask, IncrementalMaskView, TokenTree};
use crate::util::rng::Rng;

const NEG_INF: f32 = f32::NEG_INFINITY;

/// The head-major Q/K/V rows of the teacher-forced token at position
/// `t` — the "truth token" a greedy sampler would emit.  Q rows are
/// `[q_heads * d]`, K/V rows are `[kv_heads * d]` (the grouped layout's
/// shared KV heads).
pub fn token_rows(req: &DecodeRequest, t: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert!(t < req.n);
    let (n, d) = (req.n, req.d);
    let mut q = Vec::with_capacity(req.layout.q_heads * d);
    let mut k = Vec::with_capacity(req.layout.kv_heads * d);
    let mut v = Vec::with_capacity(req.layout.kv_heads * d);
    for h in 0..req.layout.q_heads {
        let base = h * n * d + t * d;
        q.extend_from_slice(&req.q[base..base + d]);
    }
    for h in 0..req.layout.kv_heads {
        let base = h * n * d + t * d;
        k.extend_from_slice(&req.k[base..base + d]);
        v.extend_from_slice(&req.v[base..base + d]);
    }
    (q, k, v)
}

/// A proposed draft: a preorder [`TokenTree`] plus, per node, the
/// head-major Q (`[q_heads * d]`) and K/V (`[kv_heads * d]`) rows of
/// the proposed token.
#[derive(Clone, Debug)]
pub struct DraftTree {
    pub tree: TokenTree,
    pub q: Vec<Vec<f32>>,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl DraftTree {
    pub fn len(&self) -> usize {
        self.tree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Head `h`'s `[d]` slice of node `node`'s row set.
    pub fn head_row<'a>(rows: &'a [Vec<f32>], node: usize, h: usize, d: usize) -> &'a [f32] {
        &rows[node][h * d..(h + 1) * d]
    }
}

/// A draft-token source.  `budget` bounds the accepted-path length
/// (`tree.max_path_len() <= budget`), so a proposal can never commit
/// past the sequence end.  Returning `None` means "no credible draft":
/// the session takes one plain sequential step without paying for a
/// verify pass (a returned tree is never empty).
pub trait DraftProposer {
    fn propose(&mut self, req: &DecodeRequest, t0: usize, budget: usize) -> Option<DraftTree>;
}

/// Deterministic n-gram self-drafting: look the last committed token up
/// in the committed history (bitwise match of its head-0 K row) and
/// propose the rows that followed the most recent earlier occurrence as
/// a chain — the classic "prompt lookup" drafter.  Never reads past
/// `t0`, so it has no oracle knowledge; on repetitive data (structured
/// corpora) acceptance is high, and when the history has no match it
/// returns `None` so the session pays only a plain sequential step.
#[derive(Clone, Copy, Debug, Default)]
pub struct SelfDraftProposer;

impl DraftProposer for SelfDraftProposer {
    fn propose(&mut self, req: &DecodeRequest, t0: usize, budget: usize) -> Option<DraftTree> {
        debug_assert!(budget >= 1);
        let d = req.d;
        if t0 < 2 {
            return None; // no history to look anything up in
        }
        let last = t0 - 1;
        let key = &req.k[last * d..(last + 1) * d]; // head-0 K row
        let p = (0..last).rev().find(|&p| req.k[p * d..(p + 1) * d] == *key)?;
        let mut qs = Vec::new();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for j in 0..budget {
            let cont = p + 1 + j;
            if cont >= t0 {
                break; // history exhausted — never peek at the future
            }
            let (q, k, v) = token_rows(req, cont);
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        debug_assert!(!qs.is_empty(), "p + 1 < t0 by construction");
        Some(DraftTree { tree: TokenTree::chain(qs.len()), q: qs, k: ks, v: vs })
    }
}

/// Benchmark/test drafter with oracle knowledge of the teacher-forced
/// continuation: each path node is the truth token with probability
/// `accept_rate`, otherwise a perturbed (guaranteed-rejected) token.
/// `branch > 1` adds rejected sibling candidates at the root so the
/// verify pass exercises genuine tree masks.  Deterministic per seed.
#[derive(Clone, Debug)]
pub struct OracleProposer {
    pub accept_rate: f64,
    pub branch: usize,
    rng: Rng,
}

impl OracleProposer {
    pub fn new(accept_rate: f64, branch: usize, seed: u64) -> OracleProposer {
        debug_assert!((0.0..=1.0).contains(&accept_rate));
        debug_assert!(branch >= 1);
        OracleProposer { accept_rate, branch, rng: Rng::new(seed) }
    }
}

fn perturb(mut rows: (Vec<f32>, Vec<f32>, Vec<f32>)) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    for x in rows.0.iter_mut().chain(rows.1.iter_mut()).chain(rows.2.iter_mut()) {
        *x += 1.0;
    }
    rows
}

impl DraftProposer for OracleProposer {
    fn propose(&mut self, req: &DecodeRequest, t0: usize, budget: usize) -> Option<DraftTree> {
        debug_assert!(budget >= 1);
        // preorder: the real candidate chain first (one whole subtree),
        // then the rejected sibling roots
        let chain = budget.min(req.n - t0);
        let mut parents: Vec<Option<usize>> = Vec::new();
        let mut qs = Vec::new();
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for j in 0..chain {
            parents.push(if j == 0 { None } else { Some(j - 1) });
            let truth = token_rows(req, t0 + j);
            let (q, k, v) =
                if self.rng.f64() < self.accept_rate { truth } else { perturb(truth) };
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        for _ in 1..self.branch {
            parents.push(None);
            let (q, k, v) = perturb(token_rows(req, t0));
            qs.push(q);
            ks.push(k);
            vs.push(v);
        }
        Some(DraftTree {
            // lint: allow(hot-path-panic:expect) — parents is built preorder two lines up; a malformed layout is a bug in this function, not input
            tree: TokenTree::from_parents(parents).expect("oracle layout is preorder"),
            q: qs,
            k: ks,
            v: vs,
        })
    }
}

/// Draft source selector for the adaptive [`SpecPolicy`] variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DraftKind {
    /// N-gram self-drafting (no oracle knowledge).
    Ngram,
    /// Teacher-forced oracle drafter (bench/test).
    Oracle { accept_rate: f64, branch: usize, seed: u64 },
}

impl DraftKind {
    fn build(&self, session_id: u64) -> Box<dyn DraftProposer> {
        match *self {
            DraftKind::Ngram => Box::new(SelfDraftProposer),
            DraftKind::Oracle { accept_rate, branch, seed } => Box::new(OracleProposer::new(
                accept_rate,
                branch,
                seed ^ session_id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )),
        }
    }
}

/// How a decode session speculates.  `Copy` so it can live in
/// [`super::session::BatcherConfig`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpecPolicy {
    /// Sequential decode, one token per step.
    Off,
    /// N-gram self-drafting chains of up to `k` tokens.
    SelfDraft { k: usize },
    /// Oracle drafter (bench/test): truth continuation with probability
    /// `accept_rate`, `branch` root candidates, deterministic per seed.
    Oracle { k: usize, accept_rate: f64, branch: usize, seed: u64 },
    /// Dynamic draft budget (ROADMAP "dynamic k"): drafts come from
    /// `draft`, but the per-pass budget follows a rolling window of
    /// acceptance ([`SpecBudget`]), shrinking toward 1 when drafts keep
    /// missing and growing back toward `k_max` when they land.
    Adaptive { k_max: usize, draft: DraftKind },
}

impl Default for SpecPolicy {
    fn default() -> Self {
        SpecPolicy::Off
    }
}

impl SpecPolicy {
    /// Draft budget ceiling; `<= 1` means speculation is a no-op.
    pub fn k(&self) -> usize {
        match self {
            SpecPolicy::Off => 0,
            SpecPolicy::SelfDraft { k } => *k,
            SpecPolicy::Oracle { k, .. } => *k,
            SpecPolicy::Adaptive { k_max, .. } => *k_max,
        }
    }

    /// Does the budget adapt to observed acceptance?
    pub fn adaptive(&self) -> bool {
        matches!(self, SpecPolicy::Adaptive { .. })
    }

    /// Instantiate the per-session proposer (`None` when off or the
    /// budget is degenerate).  The session id decorrelates oracle
    /// streams across sequences.
    pub fn build(&self, session_id: u64) -> Option<Box<dyn DraftProposer>> {
        if self.k() <= 1 {
            return None;
        }
        match *self {
            SpecPolicy::Off => None,
            SpecPolicy::SelfDraft { .. } => Some(DraftKind::Ngram.build(session_id)),
            SpecPolicy::Oracle { accept_rate, branch, seed, .. } => {
                Some(DraftKind::Oracle { accept_rate, branch, seed }.build(session_id))
            }
            SpecPolicy::Adaptive { draft, .. } => Some(draft.build(session_id)),
        }
    }
}

/// Rolling-window controller for the speculative draft budget — the
/// ROADMAP "dynamic k" follow-up.  Each verify pass reports how much of
/// its budget the greedy acceptor committed; the next pass's budget
/// tracks the windowed acceptance rate, shrinking toward 1 (sequential
/// decode, no verify passes paid for) when drafts keep missing and
/// growing back toward `k_max` when they land.  Once collapsed to 1 the
/// controller re-probes with a 2-token draft every
/// [`SpecBudget::PROBE_EVERY`] sequential steps, so a burst of misses
/// cannot disable speculation forever.
#[derive(Clone, Debug)]
pub struct SpecBudget {
    k_max: usize,
    adaptive: bool,
    /// Per-pass accepted/budget rates, most recent last.
    window: std::collections::VecDeque<f64>,
    k: usize,
    dry_steps: u32,
}

impl SpecBudget {
    /// Verify passes remembered by the rolling acceptance window.
    pub const WINDOW: usize = 8;
    /// Sequential steps between 2-token probes once collapsed to k=1.
    pub const PROBE_EVERY: u32 = 32;

    /// Fixed budget: `record`/`note_sequential` are no-ops.
    pub fn fixed(k: usize) -> SpecBudget {
        SpecBudget {
            k_max: k,
            adaptive: false,
            window: std::collections::VecDeque::new(),
            k,
            dry_steps: 0,
        }
    }

    /// Adaptive budget starting (optimistically) at `k_max`.
    pub fn adaptive(k_max: usize) -> SpecBudget {
        SpecBudget { adaptive: true, ..SpecBudget::fixed(k_max) }
    }

    /// Draft budget for the next verify pass.
    pub fn current(&self) -> usize {
        self.k
    }

    pub fn k_max(&self) -> usize {
        self.k_max
    }

    /// Record one verify pass: `accepted` of `budget` drafted-path
    /// tokens committed.  The budget moves one step per pass toward the
    /// window's target, so a single outlier pass cannot swing it end to
    /// end.
    pub fn record(&mut self, accepted: usize, budget: usize) {
        self.dry_steps = 0;
        if !self.adaptive || budget == 0 {
            return;
        }
        let rate = accepted.min(budget) as f64 / budget as f64;
        self.window.push_back(rate);
        if self.window.len() > Self::WINDOW {
            self.window.pop_front();
        }
        let mean = self.window.iter().sum::<f64>() / self.window.len() as f64;
        let target = (1 + ((self.k_max - 1) as f64 * mean).round() as usize).clamp(1, self.k_max);
        self.k = match self.k.cmp(&target) {
            std::cmp::Ordering::Less => self.k + 1,
            std::cmp::Ordering::Greater => self.k - 1,
            std::cmp::Ordering::Equal => self.k,
        };
    }

    /// Record one sequential (non-speculative) step; once the budget has
    /// collapsed to 1 this drives the periodic re-probe.
    pub fn note_sequential(&mut self) {
        if !self.adaptive || self.k > 1 {
            return;
        }
        self.dry_steps += 1;
        if self.dry_steps >= Self::PROBE_EVERY {
            self.dry_steps = 0;
            self.k = 2.min(self.k_max);
        }
    }
}

/// Is cache column `col` visible to draft node `node`?  The element
/// test the verify kernel applies on partially-visible pages:
/// committed columns use the base mask at the node's logical row;
/// drafted columns additionally require tree ancestry, with the base
/// mask evaluated at *both* logical positions (so e.g. a sliding
/// window narrower than the draft still masks distant ancestors,
/// exactly as sequential decode would).
pub fn spec_visible(
    base: &FlashMask,
    tree: &TokenTree,
    t0: usize,
    node: usize,
    col: usize,
) -> bool {
    let lr = t0 + tree.depth(node);
    if col < t0 {
        return base.allowed(lr, col);
    }
    let cnode = col - t0;
    if cnode >= tree.len() {
        return false;
    }
    tree.is_ancestor_or_self(cnode, node) && base.allowed(lr, t0 + tree.depth(cnode))
}

/// Score all `k` drafted rows of one head in a single pass over the
/// cache pages.  Single-query-head convenience over
/// [`verify_rows_group`] — the MHA case.
///
/// Deprecated shim over `attention::api` (see
/// [`api::Backend::verify`](crate::attention::api::Backend::verify)).
#[deprecated(
    note = "use attention::api — CpuBackend::verify with a VerifyStep argument pack (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn verify_rows(
    q_rows: &[f32],
    cache: &PagedKv,
    pool: &PagePool,
    base: &FlashMask,
    base_view: &IncrementalMaskView,
    tree: &TokenTree,
    tree_mask: &FlashMask,
    tree_view: &IncrementalMaskView,
    t0: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    verify_shim(
        q_rows, 1, cache, pool, base, base_view, tree, tree_mask, tree_view, t0, scale, skip,
        stats, scratch,
    )
}

/// Shared body of the two deprecated verify entry points.
#[allow(clippy::too_many_arguments)]
fn verify_shim(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    base: &FlashMask,
    base_view: &IncrementalMaskView,
    tree: &TokenTree,
    tree_mask: &FlashMask,
    tree_view: &IncrementalMaskView,
    t0: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    use crate::attention::api::{Backend, CpuBackend, VerifyStep};
    CpuBackend
        .verify(
            VerifyStep {
                q_rows,
                group,
                cache,
                pool,
                base,
                base_view,
                tree,
                tree_mask,
                tree_view,
                t0,
                scale,
                skip,
            },
            stats,
            scratch,
        )
        // lint: allow(hot-path-panic:expect) — deprecated shim: the backend revalidates the pack; the api path returns the typed error instead
        .expect("verify_rows: CPU backend rejected a validated verify pass")
}

/// Score all drafted rows of a whole query *group* sharing one KV
/// head's cache (GQA) in a single pass over the cache pages.  `q_rows`
/// is `[group, tree.len(), d]` (query-head-major); `cache` must already
/// hold the `t0` committed rows plus the `tree.len()` drafted K/V rows.
/// Returns the `[group, tree.len(), d]` output rows in the same order.
///
/// Page skipping is two-tiered, both through the Eq. 4 classifier:
/// fully-committed pages classify against the *base* mask at the
/// node's logical row (so sliding-window/document/eviction skips carry
/// over from sequential decode unchanged); pages touching the draft
/// region classify against the *tree* mask (non-ancestor subtrees and
/// causal-future pages are skipped), degraded to element-wise checking
/// when visible, because the tree view cannot see the base mask's
/// row-dependent constraints at drafted columns.  Classification *and*
/// the element-wise visibility tests are per-KV-column decisions, so
/// they run once per node and are reused by every query head in the
/// group — `pages_total` / `pages_skipped` / `mask_evals` count KV-head
/// work and shrink by the group factor, while per-query-row MACs are
/// unchanged.  `skip=false` is the dense baseline that visits and
/// element-masks every page.
///
/// Deprecated shim over `attention::api` (see
/// [`api::Backend::verify`](crate::attention::api::Backend::verify)).
#[deprecated(
    note = "use attention::api — CpuBackend::verify with a VerifyStep argument pack (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn verify_rows_group(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    base: &FlashMask,
    base_view: &IncrementalMaskView,
    tree: &TokenTree,
    tree_mask: &FlashMask,
    tree_view: &IncrementalMaskView,
    t0: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    verify_shim(
        q_rows, group, cache, pool, base, base_view, tree, tree_mask, tree_view, t0, scale,
        skip, stats, scratch,
    )
}

/// The verify kernel body (see [`verify_rows_group`] for the contract)
/// — called through [`crate::attention::api::CpuBackend`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn verify_rows_group_impl(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    base: &FlashMask,
    base_view: &IncrementalMaskView,
    tree: &TokenTree,
    tree_mask: &FlashMask,
    tree_view: &IncrementalMaskView,
    t0: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    let sp = crate::telemetry::trace::span(crate::telemetry::names::DECODE_VERIFY);
    sp.add("drafted", tree.len() as u64);
    let d = pool.d();
    let ps = pool.page_size();
    let kd = tree.len();
    debug_assert!(group >= 1);
    debug_assert_eq!(q_rows.len(), group * kd * d);
    debug_assert_eq!(cache.len(), t0 + kd, "append draft K/V before verifying");
    debug_assert_eq!(base_view.page_size(), ps);
    debug_assert_eq!(tree_view.page_size(), ps);
    debug_assert_eq!(tree_mask.n(), t0 + kd);

    let mut o = vec![0f32; group * kd * d];
    let mut m_run = vec![NEG_INF; group * kd];
    let mut l_run = vec![0f32; group * kd];
    // per-(head, node) score rows for the current page:
    // s[(g*kd + i)*ps + c]
    if scratch.len() < group * kd * ps {
        scratch.resize(group * kd * ps, 0.0);
    }
    let s = scratch;
    let mut class = vec![BlockClass::FullyMasked; kd];
    let mut active: Vec<usize> = Vec::with_capacity(kd);

    // interval-driven page schedule over the fully-committed region
    // [0, cp): boundary scans shrink the loop to the union of the
    // per-node live ranges (each node's row is its logical position
    // under the base mask).  A page leaves the union only when *every*
    // node classifies it fully masked; the scan classifies each
    // excluded page at most once per node — exactly what the old dense
    // loop paid for it — and early-exits on the first live node at the
    // boundary, so this is never more classification work than the
    // `0..n_pages` scan it replaces.  Excluded pages are bulk-accounted
    // and never enter the hot loop.  Pages from cp on touch the draft
    // region and are always visited (the tree classifier prunes per
    // node inside).
    let np = cache.n_pages();
    let cp = t0 / ps;
    let (mut u_lo, mut u_hi) = (0usize, cp);
    if skip {
        let all_masked = |p: usize| {
            (0..kd).all(|i| {
                base_view.classify_page(base, t0 + tree.depth(i), p) == BlockClass::FullyMasked
            })
        };
        while u_lo < u_hi && all_masked(u_lo) {
            u_lo += 1;
        }
        while u_hi > u_lo && all_masked(u_hi - 1) {
            u_hi -= 1;
        }
    }
    let bulk_skipped = (u_lo + (cp - u_hi)) as u64;
    stats.pages_total += kd as u64 * bulk_skipped;
    stats.pages_skipped += kd as u64 * bulk_skipped;

    for p in (u_lo..u_hi).chain(cp..np) {
        let cols = cache.page_cols(p, ps);
        let col0 = p * ps;
        // pages that end at or before t0 hold only committed rows
        let committed_page = col0 + ps <= t0;
        active.clear();
        for (i, ci) in class.iter_mut().enumerate() {
            stats.pages_total += 1; // once per KV head, not per query head
            *ci = if !skip {
                BlockClass::PartiallyMasked
            } else if committed_page {
                // exact: same classifier, same row, as sequential decode
                base_view.classify_page(base, t0 + tree.depth(i), p)
            } else {
                match tree_view.classify_page(tree_mask, t0 + i, p) {
                    BlockClass::FullyMasked => BlockClass::FullyMasked,
                    // visible draft columns still need the base mask at
                    // logical positions — stay element-wise
                    _ => BlockClass::PartiallyMasked,
                }
            };
            if *ci == BlockClass::FullyMasked {
                stats.pages_skipped += 1;
            } else {
                active.push(i);
            }
        }
        if active.is_empty() {
            continue; // no surviving row touches this page's K/V memory
        }
        let kp = pool.page_k(cache.page_id(p));
        let vp = pool.page_v(cache.page_id(p));

        // s_{g,i} = q_{g,i} · K_pᵀ * scale for every surviving node,
        // column-outer so each loaded K row is reused across all draft
        // rows of all query heads in the group (the multi-row batching
        // win: one pass over page memory, group*k lane-parallel dot
        // products per K row)
        for c in 0..cols {
            let krow = &kp[c * d..(c + 1) * d];
            for &i in &active {
                for g in 0..group {
                    let row = g * kd + i;
                    let q_row = &q_rows[row * d..(row + 1) * d];
                    s[row * ps + c] = gemm::dot(q_row, krow) * scale;
                }
            }
        }
        stats.macs += (group * active.len() * cols * d) as u64;

        // per-node masking + online softmax (Alg. 1 lines 25-26, Br = 1);
        // visibility is a per-column property, evaluated once per node
        // and applied to every query head in the group
        for &i in &active {
            if class[i] == BlockClass::PartiallyMasked {
                for c in 0..cols {
                    if !spec_visible(base, tree, t0, i, col0 + c) {
                        for g in 0..group {
                            s[(g * kd + i) * ps + c] = NEG_INF;
                        }
                    }
                }
                stats.mask_evals += cols as u64;
                stats.pages_partial += 1;
            } else {
                stats.pages_unmasked += 1;
            }

            for g in 0..group {
                let row = g * kd + i;
                let si = &s[row * ps..row * ps + cols];
                let mut page_max = NEG_INF;
                for &sv in si.iter() {
                    page_max = page_max.max(sv);
                }
                let m_new = m_run[row].max(page_max);
                let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
                let a = if m_run[row].is_finite() { (m_run[row] - m_safe).exp() } else { 0.0 };
                let o_row = &mut o[row * d..(row + 1) * d];
                for ov in o_row.iter_mut() {
                    *ov *= a;
                }
                let mut page_sum = 0f32;
                for (c, &sv) in si.iter().enumerate() {
                    let pexp = (sv - m_safe).exp(); // exp(-inf) == 0 for masked
                    page_sum += pexp;
                    for dd in 0..d {
                        o_row[dd] += pexp * vp[c * d + dd];
                    }
                }
                stats.macs += (cols * d) as u64;
                l_run[row] = a * l_run[row] + page_sum;
                m_run[row] = m_new;
            }
        }
    }

    stats.steps += (group * kd) as u64; // kernel rows evaluated
    for row in 0..group * kd {
        if l_run[row] > 0.0 {
            let inv = 1.0 / l_run[row];
            for ov in o[row * d..(row + 1) * d].iter_mut() {
                *ov *= inv;
            }
        } // fully-masked row stays 0, like the sequential kernel
    }
    o
}

/// Greedy acceptance: walk the draft tree from the roots, at each depth
/// taking the first candidate whose proposed Q/K/V rows equal the
/// teacher-forced truth rows bitwise (the greedy sampler's argmax under
/// teacher forcing *is* the truth token).  Returns the accepted node
/// path, possibly empty.
pub fn greedy_accept_path(req: &DecodeRequest, draft: &DraftTree, t0: usize) -> Vec<usize> {
    let mut path = Vec::new();
    let mut candidates = draft.tree.roots();
    let mut depth = 0;
    while t0 + depth < req.n {
        let (tq, tk, tv) = token_rows(req, t0 + depth);
        let Some(&c) = candidates
            .iter()
            .find(|&&c| draft.q[c] == tq && draft.k[c] == tk && draft.v[c] == tv)
        else {
            break;
        };
        path.push(c);
        depth += 1;
        candidates = draft.tree.children(c);
    }
    path
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::decode::decode_step;
    use crate::mask::builders;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }

    fn single_head_req(n: usize, d: usize, mask: FlashMask, seed: u64) -> DecodeRequest {
        let mut rng = Rng::new(seed);
        DecodeRequest::new(
            7,
            1,
            n,
            d,
            1,
            rand_vec(n * d, &mut rng),
            rand_vec(n * d, &mut rng),
            rand_vec(n * d, &mut rng),
            mask,
        )
    }

    /// Verify a truth chain and compare each row against the sequential
    /// step kernel at the same position.
    fn assert_chain_matches_sequential(mask: FlashMask, n: usize, d: usize, t0: usize, kd: usize) {
        let ps = 8;
        let req = single_head_req(n, d, mask, 21);
        let scale = 1.0 / (d as f32).sqrt();
        let view = IncrementalMaskView::new(&req.mask, ps);

        // sequential: decode rows 0..t0+kd one at a time
        let mut pool = PagePool::new(ps, d, 64);
        let mut cache = PagedKv::new();
        let mut stats = DecodeStats::default();
        let mut scratch = Vec::new();
        let mut seq_rows = Vec::new();
        for t in 0..t0 + kd {
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
            let o = decode_step(
                &req.q[t * d..(t + 1) * d],
                &cache,
                &pool,
                &req.mask,
                &view,
                t,
                scale,
                true,
                &mut stats,
                &mut scratch,
            );
            seq_rows.push(o);
        }

        // speculative: cache holds t0 rows, verify a kd-token truth chain
        let mut pool = PagePool::new(ps, d, 64);
        let mut cache = PagedKv::new();
        for t in 0..t0 {
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
        }
        let tree = TokenTree::chain(kd);
        let mut q_rows = Vec::new();
        for j in 0..kd {
            let t = t0 + j;
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
            q_rows.extend_from_slice(&req.q[t * d..(t + 1) * d]);
        }
        let tm = builders::tree_mask(t0, &tree);
        let tview = IncrementalMaskView::new(&tm, ps);
        let mut vstats = DecodeStats::default();
        let out = verify_rows(
            &q_rows, &cache, &pool, &req.mask, &view, &tree, &tm, &tview, t0, scale, true,
            &mut vstats, &mut scratch,
        );
        for j in 0..kd {
            let want = &seq_rows[t0 + j];
            let got = &out[j * d..(j + 1) * d];
            for dd in 0..d {
                assert!(
                    (got[dd] - want[dd]).abs() < 1e-5,
                    "t0={t0} node {j} dim {dd}: {} vs {}",
                    got[dd],
                    want[dd]
                );
            }
        }
    }

    #[test]
    fn chain_verify_matches_sequential_step() {
        let (n, d) = (48, 4);
        for t0 in [1usize, 7, 16, 30] {
            assert_chain_matches_sequential(builders::causal(n), n, d, t0, 4);
            assert_chain_matches_sequential(builders::sliding_window(n, 6), n, d, t0, 4);
            assert_chain_matches_sequential(
                builders::causal_document(n, &[20, 16, 12]),
                n,
                d,
                t0,
                4,
            );
        }
    }

    #[test]
    fn tree_verify_scores_each_branch_at_its_logical_position() {
        // two root candidates: node 0 continues into a chain, node 3 is
        // an alternative first token.  Both sit at logical position t0,
        // so both must score exactly like a sequential step at t0 with
        // their own K/V appended.
        let (n, d, ps, t0) = (40usize, 4usize, 8usize, 13usize);
        let req = single_head_req(n, d, builders::sliding_window(n, 5), 33);
        let scale = 1.0 / (d as f32).sqrt();
        let view = IncrementalMaskView::new(&req.mask, ps);
        let mut rng = Rng::new(99);
        let alt_k = rand_vec(d, &mut rng);
        let alt_v = rand_vec(d, &mut rng);
        let alt_q = rand_vec(d, &mut rng);

        // oracle for the alternative branch: sequential decode where
        // position t0 holds the alternative token
        let mut pool = PagePool::new(ps, d, 64);
        let mut cache = PagedKv::new();
        let mut stats = DecodeStats::default();
        let mut scratch = Vec::new();
        for t in 0..t0 {
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
        }
        assert!(cache.append(&mut pool, &alt_k, &alt_v));
        let want_alt = decode_step(
            &alt_q, &cache, &pool, &req.mask, &view, t0, scale, true, &mut stats, &mut scratch,
        );

        // speculative cache: truth chain (nodes 0..3) then the branch
        let mut pool = PagePool::new(ps, d, 64);
        let mut cache = PagedKv::new();
        for t in 0..t0 {
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
        }
        let tree =
            TokenTree::from_parents(vec![None, Some(0), Some(1), None]).unwrap();
        let mut q_rows = Vec::new();
        for j in 0..3 {
            let t = t0 + j;
            assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
            q_rows.extend_from_slice(&req.q[t * d..(t + 1) * d]);
        }
        assert!(cache.append(&mut pool, &alt_k, &alt_v));
        q_rows.extend_from_slice(&alt_q);
        let tm = builders::tree_mask(t0, &tree);
        let tview = IncrementalMaskView::new(&tm, ps);
        let mut vstats = DecodeStats::default();
        let out = verify_rows(
            &q_rows, &cache, &pool, &req.mask, &view, &tree, &tm, &tview, t0, scale, true,
            &mut vstats, &mut scratch,
        );
        // the alternative root (node 3, logical position t0) matches its
        // own sequential oracle even though the truth chain occupies the
        // intervening cache slots
        for dd in 0..d {
            assert!(
                (out[3 * d + dd] - want_alt[dd]).abs() < 1e-5,
                "alt branch dim {dd}: {} vs {}",
                out[3 * d + dd],
                want_alt[dd]
            );
        }
    }

    #[test]
    fn verify_skip_is_noop_and_skips_pages_on_window_masks() {
        let (n, d, ps, t0, kd) = (64usize, 4usize, 8usize, 40usize, 4usize);
        let req = single_head_req(n, d, builders::sliding_window(n, 8), 55);
        let scale = 1.0 / (d as f32).sqrt();
        let view = IncrementalMaskView::new(&req.mask, ps);
        let tree = TokenTree::chain(kd);
        let mut run = |skip: bool| {
            let mut pool = PagePool::new(ps, d, 64);
            let mut cache = PagedKv::new();
            for t in 0..t0 {
                assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
            }
            let mut q_rows = Vec::new();
            for j in 0..kd {
                let t = t0 + j;
                assert!(cache.append(&mut pool, &req.k[t * d..(t + 1) * d], &req.v[t * d..(t + 1) * d]));
                q_rows.extend_from_slice(&req.q[t * d..(t + 1) * d]);
            }
            let tm = builders::tree_mask(t0, &tree);
            let tview = IncrementalMaskView::new(&tm, ps);
            let mut stats = DecodeStats::default();
            let mut scratch = Vec::new();
            let out = verify_rows(
                &q_rows, &cache, &pool, &req.mask, &view, &tree, &tm, &tview, t0, scale, skip,
                &mut stats, &mut scratch,
            );
            (out, stats)
        };
        let (a, s_skip) = run(true);
        let (b, s_dense) = run(false);
        assert_eq!(a, b, "page skipping changed verify outputs");
        assert!(s_skip.pages_skipped > 0, "window mask should skip old pages");
        assert_eq!(s_dense.pages_skipped, 0);
        assert!(s_skip.macs < s_dense.macs);
    }

    #[test]
    fn self_draft_proposes_from_history_deterministically() {
        // periodic "tokens": position t repeats t % 4, so the n-gram
        // drafter finds the earlier occurrence and proposes the truth
        let (n, d, period) = (32usize, 4usize, 4usize);
        let mut rng = Rng::new(3);
        let vocab: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..period)
            .map(|_| (rand_vec(d, &mut rng), rand_vec(d, &mut rng), rand_vec(d, &mut rng)))
            .collect();
        let mut q = Vec::new();
        let mut k = Vec::new();
        let mut v = Vec::new();
        for t in 0..n {
            q.extend_from_slice(&vocab[t % period].0);
            k.extend_from_slice(&vocab[t % period].1);
            v.extend_from_slice(&vocab[t % period].2);
        }
        let req = DecodeRequest::new(0, 1, n, d, 1, q, k, v, builders::causal(n));
        let mut p1 = SelfDraftProposer;
        let mut p2 = SelfDraftProposer;
        let t0 = 9;
        let a = p1.propose(&req, t0, 4).expect("periodic history must hit");
        let b = p2.propose(&req, t0, 4).expect("periodic history must hit");
        assert_eq!(a.tree, b.tree, "proposer must be deterministic");
        assert_eq!(a.q, b.q);
        assert_eq!(a.len(), 4);
        // proposals equal the truth continuation => full acceptance
        let path = greedy_accept_path(&req, &a, t0);
        assert_eq!(path.len(), 4);
        // and the proposer never saw positions >= t0: its rows come from
        // history, which happens to equal the truth on periodic data
        for (j, &node) in path.iter().enumerate() {
            let (tq, _, _) = token_rows(&req, t0 + j);
            assert_eq!(a.q[node], tq);
        }
    }

    #[test]
    fn self_draft_declines_without_a_match() {
        // white-noise history: no earlier occurrence of the last token,
        // so the drafter declines instead of forcing a wasted verify
        let req = single_head_req(24, 4, builders::causal(24), 77);
        let mut p = SelfDraftProposer;
        assert!(p.propose(&req, 10, 4).is_none());
        // and with no history at all
        assert!(p.propose(&req, 0, 4).is_none());
        assert!(p.propose(&req, 1, 4).is_none());
    }

    #[test]
    fn adaptive_budget_converges_to_one_on_rejection() {
        // satellite: low acceptance must converge the draft budget to 1
        let mut b = SpecBudget::adaptive(4);
        assert_eq!(b.current(), 4);
        for _ in 0..16 {
            let k = b.current();
            b.record(0, k);
        }
        assert_eq!(b.current(), 1, "rejected drafts must collapse the budget");
        // collapsed budget re-probes after PROBE_EVERY sequential steps
        for _ in 0..SpecBudget::PROBE_EVERY {
            b.note_sequential();
        }
        assert_eq!(b.current(), 2, "probe must reopen a 2-token draft");
        // and sustained acceptance grows it back to k_max
        for _ in 0..32 {
            let k = b.current();
            b.record(k, k);
        }
        assert_eq!(b.current(), 4, "full acceptance must restore k_max");
    }

    #[test]
    fn fixed_budget_ignores_the_window() {
        let mut f = SpecBudget::fixed(4);
        for _ in 0..8 {
            f.record(0, 4);
            f.note_sequential();
        }
        assert_eq!(f.current(), 4);
    }

    #[test]
    fn adaptive_policy_surfaces_k_and_flag() {
        let p = SpecPolicy::Adaptive { k_max: 4, draft: DraftKind::Ngram };
        assert_eq!(p.k(), 4);
        assert!(p.adaptive());
        assert!(p.build(7).is_some());
        assert!(!SpecPolicy::SelfDraft { k: 4 }.adaptive());
        // degenerate ceiling: speculation is a no-op
        assert!(SpecPolicy::Adaptive { k_max: 1, draft: DraftKind::Ngram }.build(7).is_none());
    }

    #[test]
    fn oracle_proposer_accept_rates() {
        let (n, d) = (24usize, 4usize);
        let req = single_head_req(n, d, builders::causal(n), 8);
        let t0 = 5;
        // rate 1: whole chain accepted
        let mut p = OracleProposer::new(1.0, 2, 11);
        let draft = p.propose(&req, t0, 4).unwrap();
        assert_eq!(draft.len(), 4 + 1); // chain + 1 junk sibling
        assert_eq!(draft.tree.roots().len(), 2);
        assert_eq!(greedy_accept_path(&req, &draft, t0).len(), 4);
        // rate 0: nothing accepted
        let mut p = OracleProposer::new(0.0, 1, 11);
        let draft = p.propose(&req, t0, 4).unwrap();
        assert!(greedy_accept_path(&req, &draft, t0).is_empty());
        // budget respected near the end of the sequence
        let mut p = OracleProposer::new(1.0, 1, 11);
        let draft = p.propose(&req, n - 2, 4).unwrap();
        assert!(draft.tree.max_path_len() <= 2);
    }
}
