//! Decode sessions and the continuous-batching scheduler.
//!
//! A [`DecodeSession`] owns one sequence's paged caches (one per *KV*
//! head — under GQA a chain is shared by its whole query group), its
//! FlashMask and the incremental view over it, and steps one token at a
//! time.  The [`ContinuousBatcher`] runs many sessions against the
//! shared [`PagePool`]: each iteration it admits waiting sequences,
//! steps every active sequence by one token, and retires finished ones
//! — sequences of *different lengths* decode side by side, removing the
//! prefill scheduler's same-`n` batching restriction.
//!
//! Under page-pool pressure the batcher preempts the most recently
//! admitted session (its pages are evicted, its request re-queued), so
//! the oldest admitted session always makes progress and the loop
//! terminates.  Sequences are teacher-forced — Q/K/V streams for the
//! whole sequence are given up front — which keeps the decode path
//! byte-comparable to the full-sequence prefill oracle.

use super::kvcache::{prefix_hash_chain, PagePool, PagedKv, PrefixCache, PrefixStats};
use super::spec::{self, DraftProposer, SpecBudget, SpecPolicy};
use super::step::DecodeStats;
use crate::attention::api::{Backend, CpuBackend, DecodeStep, VerifyStep};
use crate::attention::HeadLayout;
use crate::mask::{builders, FlashMask, IncrementalMaskView};
use crate::telemetry::{log, Gauge, Histogram};
use anyhow::{bail, ensure, Result};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// One decode request: teacher-forced Q/K/V streams for the whole
/// sequence, the sequence's FlashMask, and the prompt/generation
/// split.  Q is head-major `[q_heads, n, d]`; K/V are head-major
/// `[kv_heads, n, d]` — under GQA each KV head is shared by a group of
/// `layout.group()` query heads, so cache residency scales with
/// `kv_heads`.  Rows `0..prompt_len` are prefill (their K/V is
/// bulk-loaded into the cache); rows `prompt_len..n` are decoded token
/// by token.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub id: u64,
    pub layout: HeadLayout,
    pub n: usize,
    pub d: usize,
    pub prompt_len: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub mask: FlashMask,
    pub arrived: Instant,
}

impl DecodeRequest {
    /// MHA convenience: `heads` query heads, each owning its KV head
    /// (`q`, `k`, `v` all `[heads, n, d]`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: u64,
        heads: usize,
        n: usize,
        d: usize,
        prompt_len: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        mask: FlashMask,
    ) -> DecodeRequest {
        DecodeRequest::with_layout(id, HeadLayout::mha(heads), n, d, prompt_len, q, k, v, mask)
    }

    /// Grouped layout: `q` is `[layout.q_heads, n, d]`, `k`/`v` are
    /// `[layout.kv_heads, n, d]`.
    #[allow(clippy::too_many_arguments)]
    pub fn with_layout(
        id: u64,
        layout: HeadLayout,
        n: usize,
        d: usize,
        prompt_len: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        mask: FlashMask,
    ) -> DecodeRequest {
        assert_eq!(q.len(), layout.q_heads * n * d, "q must be [q_heads, n, d]");
        assert_eq!(k.len(), layout.kv_heads * n * d, "k must be [kv_heads, n, d]");
        assert_eq!(v.len(), layout.kv_heads * n * d, "v must be [kv_heads, n, d]");
        assert_eq!(mask.n(), n);
        assert!(prompt_len < n, "nothing to decode");
        assert!(
            mask.causal,
            "decode requires a causal mask: a row cannot attend to KV not yet written"
        );
        DecodeRequest { id, layout, n, d, prompt_len, q, k, v, mask, arrived: Instant::now() }
    }

    /// Decode steps this request needs.
    pub fn gen_len(&self) -> usize {
        self.n - self.prompt_len
    }

    /// Worst-case pool pages when fully decoded — one page chain per
    /// *KV* head, the GQA residency win: at group size `g` a sequence
    /// holds `g`× fewer pages than its MHA twin.  Prefix-aware callers
    /// subtract the shared pages a [`PrefixCache`] lookup would attach
    /// (see [`ContinuousBatcher`] fit checks / the router's wave
    /// reservation) — those pages are resident already and cost no new
    /// allocation.
    pub fn pages_needed(&self, page_size: usize) -> usize {
        self.layout.kv_heads * self.n.div_ceil(page_size)
    }

    /// Cumulative content-hash chain over this request's page-aligned
    /// prompt prefix (see [`prefix_hash_chain`]) — the [`PrefixCache`]
    /// key.  Empty when the prompt spans no full page.
    pub fn prefix_hashes(&self, page_size: usize) -> Vec<u64> {
        prefix_hash_chain(
            &self.k,
            &self.v,
            self.layout.kv_heads,
            self.n,
            self.d,
            self.prompt_len,
            page_size,
        )
    }
}

/// Outcome of one [`DecodeSession::try_step`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOutcome {
    /// A fresh page was needed and the pool is exhausted; nothing
    /// changed — preempt someone or wait.
    NoPage,
    /// One token decoded.
    Stepped,
    /// One token decoded and the sequence is complete.
    Finished,
}

/// One active sequence: per-*KV*-head paged caches + decode cursor.
/// Under GQA the session holds `kv_heads` page chains (not `q_heads`),
/// so pool pressure, preemption and rollback all operate on the shared
/// chains — a group-8 session holds 8× fewer pages than its MHA twin.
pub struct DecodeSession {
    pub req: DecodeRequest,
    /// One page chain per KV head.
    caches: Vec<PagedKv>,
    view: IncrementalMaskView,
    scale: f32,
    /// Rows appended to the cache so far (== next row to decode).
    pub pos: usize,
    /// Decoded output rows, one `[gen_len * d]` buffer per *query* head.
    out: Vec<Vec<f32>>,
    /// Score scratch reused across steps (no per-token allocation).
    scratch: Vec<f32>,
    /// Query-group gather buffer reused across steps, same contract.
    q_scratch: Vec<f32>,
    /// Draft source when this session decodes speculatively.
    proposer: Option<Box<dyn DraftProposer>>,
    /// Draft budget (max accepted tokens per verify pass), fixed or
    /// acceptance-adaptive.
    budget: SpecBudget,
    /// The attention backend the session's kernels run on.  Decode is
    /// CPU-resident today ([`CpuBackend`] is the only decode-capable
    /// backend — see `Capabilities::decode`); the field is the seam a
    /// future AOT decode artifact plugs into.
    backend: CpuBackend,
    pub stats: DecodeStats,
    pub admitted: Instant,
    /// Completion instant of every *generated* row, in commit order —
    /// `token_times[0]` is TTFT's right edge and consecutive pairs are
    /// the per-token inter-token gaps (tokens committed by one
    /// speculative verify pass share an instant: the client receives
    /// them as a burst).  Dropped with the session on preemption, so
    /// after a re-decode the timeline reflects the successful run —
    /// consistent with `decode_ms`.
    token_times: Vec<Instant>,
}

impl DecodeSession {
    pub fn new(req: DecodeRequest, page_size: usize) -> DecodeSession {
        // the session's decode plan: built once here, reused for every
        // token stepped and every verify pass (stats.plans_built vs
        // stats.steps is the bench's plan-reuse evidence)
        let view = IncrementalMaskView::new(&req.mask, page_size);
        let scale = 1.0 / (req.d as f32).sqrt();
        let caches = (0..req.layout.kv_heads).map(|_| PagedKv::new()).collect();
        let out = vec![Vec::with_capacity(req.gen_len() * req.d); req.layout.q_heads];
        DecodeSession {
            req,
            caches,
            view,
            scale,
            pos: 0,
            out,
            scratch: Vec::with_capacity(page_size),
            q_scratch: Vec::new(),
            proposer: None,
            budget: SpecBudget::fixed(0),
            backend: CpuBackend,
            stats: DecodeStats { plans_built: 1, ..DecodeStats::default() },
            admitted: Instant::now(),
            token_times: Vec::new(),
        }
    }

    /// Enable speculative decoding: up to `k` tokens are drafted by
    /// `proposer` and verified per [`try_speculate`](Self::try_speculate)
    /// call.  `k <= 1` is sequential decode.  With `adaptive` the
    /// per-pass budget follows a rolling acceptance window
    /// ([`SpecBudget`]), collapsing to 1 when drafts keep missing.
    pub fn set_speculation(&mut self, proposer: Box<dyn DraftProposer>, k: usize, adaptive: bool) {
        self.proposer = Some(proposer);
        self.budget = if adaptive { SpecBudget::adaptive(k) } else { SpecBudget::fixed(k) };
    }

    pub fn speculative(&self) -> bool {
        self.proposer.is_some() && self.budget.k_max() > 1
    }

    /// Current draft budget (== `k` for fixed policies).
    pub fn spec_budget(&self) -> usize {
        self.budget.current()
    }

    fn q_row(&self, h: usize, t: usize) -> std::ops::Range<usize> {
        debug_assert!(h < self.req.layout.q_heads);
        let base = h * self.req.n * self.req.d + t * self.req.d;
        base..base + self.req.d
    }

    fn kv_row(&self, src: &[f32], kh: usize, t: usize) -> std::ops::Range<usize> {
        debug_assert!(src.len() == self.req.layout.kv_heads * self.req.n * self.req.d);
        let base = kh * self.req.n * self.req.d + t * self.req.d;
        base..base + self.req.d
    }

    /// Bulk-load the prompt's K/V into the cache (one chain per KV
    /// head).  Checks page availability up front; returns `false`
    /// (allocating nothing, detaching any shared prefix) when the pool
    /// cannot hold the prompt.
    ///
    /// With a [`PrefixCache`], prefill first looks up the longest
    /// cached page-aligned prefix of the prompt and *attaches* its
    /// pages (refcounted, no copy, no compute) — only the suffix's K/V
    /// rows are materialized, so `stats.prefill_macs` and new-page
    /// demand both shrink by the shared span.  Afterwards the session's
    /// own aligned prefix is registered so later sessions can share it.
    #[must_use]
    pub fn prefill(&mut self, pool: &mut PagePool, mut prefix: Option<&mut PrefixCache>) -> bool {
        debug_assert_eq!(self.pos, 0);
        let ps = pool.page_size();
        let kv_heads = self.req.layout.kv_heads;
        let hashes = if prefix.is_some() { self.req.prefix_hashes(ps) } else { Vec::new() };
        let mut shared_tokens = 0;
        if let Some(cache) = prefix.as_deref_mut() {
            if !hashes.is_empty() {
                if let Some((pages, tokens)) =
                    cache.lookup(pool, kv_heads, &hashes, &self.req.k, &self.req.v, self.req.n)
                {
                    for (kh, c) in self.caches.iter_mut().enumerate() {
                        c.attach_shared(pool, &pages[kh]);
                    }
                    shared_tokens = tokens;
                }
            }
        }
        let needed = kv_heads * (self.req.prompt_len.div_ceil(ps) - shared_tokens / ps);
        if pool.available() < needed {
            // detach the shared prefix again: a rejected prefill must
            // leave the session exactly as constructed
            for c in &mut self.caches {
                c.release(pool, false);
            }
            return false;
        }
        for kh in 0..kv_heads {
            for t in shared_tokens..self.req.prompt_len {
                let kr = self.kv_row(&self.req.k, kh, t);
                let vr = self.kv_row(&self.req.v, kh, t);
                let ok = self.caches[kh].append(pool, &self.req.k[kr], &self.req.v[vr]);
                debug_assert!(ok, "prefill alloc failed despite availability check");
            }
        }
        self.stats.prefill_macs +=
            (kv_heads * (self.req.prompt_len - shared_tokens) * self.req.d) as u64;
        if let Some(cache) = prefix {
            // donate this prompt's aligned prefix (cumulative entries;
            // already-cached lengths are skipped, so a session that just
            // attached a shared prefix re-registers nothing below it)
            cache.register(pool, &hashes, &self.caches);
        }
        self.pos = self.req.prompt_len;
        self.admitted = Instant::now();
        true
    }

    /// Decode one token across all heads: one grouped kernel call per
    /// KV head, scoring that head's whole query group in a single pass
    /// over its pages (classification once per KV head).  Page demand
    /// is checked up front (all KV heads cross page boundaries
    /// together), so a `NoPage` return leaves the session untouched.
    pub fn try_step(&mut self, pool: &mut PagePool, skip: bool) -> StepOutcome {
        debug_assert!(self.pos < self.req.n);
        let t = self.pos;
        let ps = pool.page_size();
        let layout = self.req.layout;
        let d = self.req.d;
        let g = layout.group();
        let new_pages = if t % ps == 0 { layout.kv_heads } else { 0 };
        if pool.available() < new_pages {
            return StepOutcome::NoPage;
        }
        for kh in 0..layout.kv_heads {
            let kr = self.kv_row(&self.req.k, kh, t);
            let vr = self.kv_row(&self.req.v, kh, t);
            let ok = self.caches[kh].append(pool, &self.req.k[kr], &self.req.v[vr]);
            debug_assert!(ok, "step alloc failed despite availability check");
            self.q_scratch.clear();
            for qh in kh * g..(kh + 1) * g {
                let qr = self.q_row(qh, t);
                let row = &self.req.q[qr];
                self.q_scratch.extend_from_slice(row);
            }
            let o = self
                .backend
                .decode_step(
                    DecodeStep {
                        q_rows: &self.q_scratch,
                        group: g,
                        cache: &self.caches[kh],
                        pool,
                        mask: &self.req.mask,
                        view: &self.view,
                        t,
                        scale: self.scale,
                        skip,
                    },
                    &mut self.stats,
                    &mut self.scratch,
                )
                .expect("decode step: backend rejected a request validated at submit");
            if t >= self.req.prompt_len {
                for (j, qh) in (kh * g..(kh + 1) * g).enumerate() {
                    self.out[qh].extend_from_slice(&o[j * d..(j + 1) * d]);
                }
            }
        }
        self.pos += 1;
        if self.pos > self.req.prompt_len {
            self.token_times.push(Instant::now());
        }
        if self.pos == self.req.n {
            StepOutcome::Finished
        } else {
            StepOutcome::Stepped
        }
    }

    /// One speculative iteration: draft up to the current budget's
    /// tokens, verify every drafted row in a single pass over the cache
    /// pages per KV head (the backend's verify kernel under a
    /// [`builders::tree_mask`], the whole query group at once), commit
    /// the longest greedily-accepted root path, and roll the cache back
    /// past the rejected remainder.  Falls back to one sequential
    /// [`try_step`](Self::try_step) when nothing is accepted, so every
    /// call advances at least one token or reports `NoPage`.
    ///
    /// Page demand for the whole draft is checked up front and rejected
    /// drafts are truncated away before returning, so a `NoPage` return
    /// or a later preemption never leaks drafted-but-uncommitted pages.
    pub fn try_speculate(&mut self, pool: &mut PagePool, skip: bool) -> StepOutcome {
        debug_assert!(self.pos < self.req.n);
        let t0 = self.pos;
        let budget = self.budget.current().min(self.req.n - t0);
        if self.proposer.is_none() || budget <= 1 {
            // sequential progress; once an adaptive budget has collapsed
            // to 1 these steps drive its periodic re-probe
            self.budget.note_sequential();
            return self.try_step(pool, skip);
        }
        let Some(draft) = self.proposer.as_mut().unwrap().propose(&self.req, t0, budget) else {
            // no credible draft (e.g. n-gram miss): plain sequential
            // step, no verify pass paid for
            return self.try_step(pool, skip);
        };
        let kd = draft.len();
        assert!(
            draft.tree.max_path_len() <= budget,
            "draft path {} exceeds budget {budget}",
            draft.tree.max_path_len()
        );
        let ps = pool.page_size();
        let layout = self.req.layout;
        let g = layout.group();
        let d = self.req.d;
        let new_pages = layout.kv_heads * ((t0 + kd).div_ceil(ps) - t0.div_ceil(ps));
        if pool.available() < new_pages {
            // the draft doesn't fit (it may transiently need more pages
            // than the submit-time worst case covers, e.g. rejected
            // sibling branches near the sequence end) — try sequential
            // progress instead; only a genuine single-token NoPage
            // escalates to the batcher's preemption path
            return self.try_step(pool, skip);
        }

        // append every drafted K/V row to every KV-head chain (checked
        // above, cannot fail)
        for kh in 0..layout.kv_heads {
            for i in 0..kd {
                let ok = self.caches[kh].append(
                    pool,
                    spec::DraftTree::head_row(&draft.k, i, kh, d),
                    spec::DraftTree::head_row(&draft.v, i, kh, d),
                );
                debug_assert!(ok, "draft alloc failed despite availability check");
            }
        }

        // one verify pass per KV head, all drafted rows of the whole
        // query group at once (page classification and the per-column
        // visibility tests run once per KV head).  The tree mask + view
        // are rebuilt per pass — O(t0 + kd) setup against the pass's
        // O(t0 * kd * d) compute, i.e. ~1/(kd*d) relative — a
        // draft-region-only view would save it but needs page-offset
        // handling (t0 is rarely page-aligned)
        let tm = builders::tree_mask(t0, &draft.tree);
        let tview = IncrementalMaskView::new(&tm, ps);
        // outs[kh] is [group, kd, d], query-head-major within the group
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(layout.kv_heads);
        for kh in 0..layout.kv_heads {
            let mut q_rows = Vec::with_capacity(g * kd * d);
            for qh in kh * g..(kh + 1) * g {
                for i in 0..kd {
                    q_rows.extend_from_slice(spec::DraftTree::head_row(&draft.q, i, qh, d));
                }
            }
            let verified = self
                .backend
                .verify(
                    VerifyStep {
                        q_rows: &q_rows,
                        group: g,
                        cache: &self.caches[kh],
                        pool,
                        base: &self.req.mask,
                        base_view: &self.view,
                        tree: &draft.tree,
                        tree_mask: &tm,
                        tree_view: &tview,
                        t0,
                        scale: self.scale,
                        skip,
                    },
                    &mut self.stats,
                    &mut self.scratch,
                )
                .expect("verify pass: backend rejected a draft validated by the proposer");
            outs.push(verified);
        }
        self.stats.spec_passes += 1;
        self.stats.drafted += kd as u64;

        let path = spec::greedy_accept_path(&self.req, &draft, t0);
        self.budget.record(path.len(), budget);

        // rollback: drop every drafted row (accepted ones are re-applied
        // below from the truth stream, which acceptance proved bitwise
        // equal), returning tail pages of every KV-head chain to the pool
        for c in &mut self.caches {
            c.truncate(pool, t0);
        }
        if path.is_empty() {
            let out = self.try_step(pool, skip);
            // count the fallback only if the sequential step actually
            // ran — a NoPage here is retried after preemption and would
            // otherwise double-count this verify pass's fallback
            if out != StepOutcome::NoPage {
                self.stats.fallback_steps += 1;
            }
            return out;
        }

        // commit the accepted prefix: cache rows + verified outputs
        for (j, &node) in path.iter().enumerate() {
            let t = t0 + j;
            for kh in 0..layout.kv_heads {
                let kr = self.kv_row(&self.req.k, kh, t);
                let vr = self.kv_row(&self.req.v, kh, t);
                let ok = self.caches[kh].append(pool, &self.req.k[kr], &self.req.v[vr]);
                debug_assert!(ok, "commit alloc failed after rollback");
            }
            if t >= self.req.prompt_len {
                for qh in 0..layout.q_heads {
                    let kh = layout.kv_head_of(qh);
                    let row = (qh - kh * g) * kd + node;
                    self.out[qh].extend_from_slice(&outs[kh][row * d..(row + 1) * d]);
                }
            }
        }
        self.stats.accepted += path.len() as u64;
        // the whole accepted prefix commits at one instant: the client
        // receives the burst together, so the burst's internal gaps are
        // ~0 and the next gap spans the following verify pass
        let committed_at = Instant::now();
        let gen_before = self.pos.saturating_sub(self.req.prompt_len);
        self.pos += path.len();
        let gen_after = self.pos.saturating_sub(self.req.prompt_len);
        for _ in gen_before..gen_after {
            self.token_times.push(committed_at);
        }
        if self.pos == self.req.n {
            StepOutcome::Finished
        } else {
            StepOutcome::Stepped
        }
    }

    pub fn finished(&self) -> bool {
        self.pos == self.req.n
    }

    pub fn pages_held(&self) -> usize {
        self.caches.iter().map(|c| c.n_pages()).sum()
    }

    /// Pages only this session references — what preempting it would
    /// physically free.  Shared prefix pages (cache- or co-reader-held)
    /// don't count: evicting this session cannot reclaim them.
    pub fn unique_pages(&self, pool: &PagePool) -> usize {
        self.caches.iter().map(|c| c.unique_pages(pool)).sum()
    }

    /// Release all pages and recover the request (preemption path: the
    /// partial outputs are discarded; decode is deterministic, so the
    /// retry reproduces them).
    pub fn preempt(mut self, pool: &mut PagePool) -> DecodeRequest {
        for c in &mut self.caches {
            c.release(pool, true);
        }
        self.req
    }

    /// Release all pages and assemble the head-major decoded output.
    pub fn retire(mut self, pool: &mut PagePool) -> DecodeResponse {
        debug_assert!(self.finished());
        for c in &mut self.caches {
            c.release(pool, false);
        }
        let now = Instant::now();
        let decode_ms = (now - self.admitted).as_secs_f64() * 1e3;
        let queue_ms = (self.admitted - self.req.arrived).as_secs_f64() * 1e3;
        // a finished session generated >= 1 token, so token_times is
        // non-empty; fall back to `now` defensively rather than panic
        let first = self.token_times.first().copied().unwrap_or(now);
        let ttft_ms = (first - self.req.arrived).as_secs_f64() * 1e3;
        debug_assert_eq!(self.token_times.len(), self.req.gen_len());
        let itl_gaps_ms: Vec<f64> = self
            .token_times
            .windows(2)
            .map(|w| (w[1] - w[0]).as_secs_f64() * 1e3)
            .collect();
        let itl_ms = if itl_gaps_ms.is_empty() {
            0.0
        } else {
            itl_gaps_ms.iter().sum::<f64>() / itl_gaps_ms.len() as f64
        };
        let mut o = Vec::with_capacity(self.req.layout.q_heads * self.req.gen_len() * self.req.d);
        for h in self.out.drain(..) {
            o.extend(h);
        }
        DecodeResponse {
            id: self.req.id,
            layout: self.req.layout,
            n: self.req.n,
            d: self.req.d,
            prompt_len: self.req.prompt_len,
            o,
            queue_ms,
            decode_ms,
            ttft_ms,
            itl_ms,
            itl_gaps_ms,
            stats: self.stats,
        }
    }
}

/// Completed decode: output rows for the generated span, head-major
/// `[layout.q_heads, n - prompt_len, d]`.
#[derive(Clone, Debug)]
pub struct DecodeResponse {
    pub id: u64,
    pub layout: HeadLayout,
    pub n: usize,
    pub d: usize,
    pub prompt_len: usize,
    pub o: Vec<f32>,
    /// Arrival → *final* admission.  A preempted sequence's discarded
    /// runs count as queueing (the work is thrown away and redone), so
    /// under pool pressure this includes wasted decode time.
    pub queue_ms: f64,
    /// Final (successful) admission → retirement.
    pub decode_ms: f64,
    /// Arrival → first generated token (queueing and prompt prefill
    /// included) — the latency a streaming client perceives.
    pub ttft_ms: f64,
    /// Mean gap between consecutive generated tokens (derived from
    /// `itl_gaps_ms`); 0 when only one token was generated.  Summary
    /// only — percentile consumers must use the per-token gaps, a p99
    /// over per-request means structurally hides per-token stalls.
    pub itl_ms: f64,
    /// Every inter-token gap individually: `itl_gaps_ms[i]` is the
    /// wall time between generated tokens `i` and `i+1` (empty when
    /// only one token was generated).  Tokens committed together by a
    /// speculative verify pass have ~0 gaps between them.
    pub itl_gaps_ms: Vec<f64>,
    pub stats: DecodeStats,
}

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Tokens per KV-cache page (also the mask skip granule).
    pub page_size: usize,
    /// Head dimension every request must share (the pool's row width).
    pub d: usize,
    /// Global pool capacity in pages.
    pub max_pages: usize,
    /// Max sequences decoding concurrently.
    pub max_active: usize,
    /// Eq. 4 page skipping; `false` is the dense-cache baseline.
    pub skip: bool,
    /// Speculative decoding policy (draft source + budget) applied to
    /// every admitted session; [`SpecPolicy::Off`] is sequential decode.
    pub spec: SpecPolicy,
    /// Content-addressed prompt-prefix sharing: sessions whose prompts
    /// share page-aligned K/V content attach the same physical pages
    /// (refcounted, copy-on-write) instead of recomputing and re-storing
    /// them, and the admission fit checks count only *new* pages.  Off
    /// by default: the cache pins donated pages past retirement, which
    /// callers expecting a fully drained pool must opt into (release via
    /// [`ContinuousBatcher::release_prefix_cache`]).
    pub prefix_cache: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            page_size: 16,
            d: 64,
            max_pages: 4096,
            max_active: 8,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        }
    }
}

/// Aggregate continuous-batching statistics.
#[derive(Clone, Copy, Debug)]
pub struct BatcherReport {
    pub sequences: usize,
    /// Decoded (generated) tokens, prompt excluded.
    pub tokens: u64,
    pub tokens_per_s: f64,
    /// Fraction of cache pages skipped across retired sequences.
    pub pages_skip_fraction: f64,
    /// Pages considered across all kernel calls — the skip-stat
    /// denominator.  Counted per *KV* head, so at group size `g` it
    /// shrinks by `g` vs. the MHA twin (classification reuse).
    pub pages_total: u64,
    pub preemptions: u64,
    pub evicted_pages: u64,
    pub peak_pages: usize,
    /// Peak KV-cache residency in bytes (`peak_pages` × page bytes,
    /// K and V planes) — the GQA memory win: scales with `kv_heads`.
    pub resident_kv_bytes: usize,
    /// Pages allocated per useful generated token (allocation churn,
    /// re-decodes after preemption included).
    pub pages_per_token: f64,
    /// Draft tokens run through verify passes (0 when sequential).
    pub drafted_tokens: u64,
    /// Draft tokens accepted and committed.
    pub accepted_tokens: u64,
    /// Verify passes that accepted nothing and fell back to one
    /// sequential step.
    pub spec_fallbacks: u64,
    /// Decode plans built across retired sessions (one per session
    /// construction).  Against `tokens` this proves each session built
    /// its incremental mask view / page schedule once and reused it for
    /// every decoded token — the bench_decode plan-reuse column.
    pub plans_built: u64,
    /// p50 time-to-first-token across retired sequences, from the
    /// batcher's telemetry histogram (log2 buckets, so quantiles are
    /// upper bounds within one power of two — DESIGN.md §Telemetry).
    pub ttft_p50_ms: f64,
    pub ttft_p99_ms: f64,
    /// p50 inter-token latency over *per-token* gap samples: every
    /// consecutive generated-token pair of every retired sequence
    /// contributes one sample, so a single stalled gap (a preemption
    /// hiccup, a slow verify pass) surfaces in the tail instead of
    /// being averaged away inside its sequence's mean.
    pub itl_p50_ms: f64,
    pub itl_p99_ms: f64,
    /// Prefills that failed after the fit check (pool drained in
    /// between, e.g. by a caller interleaving its own allocations);
    /// each one was rolled back and its request re-queued.
    pub prefill_rejects: u64,
    /// Prefix-cache lookups that attached a shared prompt prefix.
    pub prefix_hits: u64,
    /// Prefix-cache lookups that found nothing reusable.
    pub prefix_misses: u64,
    /// Pages attached as shared prefixes instead of being recomputed.
    pub prefix_shared_pages: u64,
    /// Shared pages cloned before a write (copy-on-write events).
    pub cow_copies: u64,
    /// K/V prefill MACs actually performed across retired sequences
    /// (`d` per materialized row); rows covered by a shared prefix cost
    /// nothing — the shared-prefix bench's compute-saving column.
    pub prefill_macs: u64,
}

impl BatcherReport {
    /// Accepted / drafted, 0 when nothing was drafted.
    pub fn accept_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }
}

/// Continuous-batching decode scheduler over a shared page pool.
pub struct ContinuousBatcher {
    pub cfg: BatcherConfig,
    pool: PagePool,
    /// Content-addressed prompt-prefix index (`Some` iff
    /// `cfg.prefix_cache`).  The cache holds its own page references,
    /// so donated prefixes outlive their donor sessions; under pool
    /// pressure it is reclaimed LRU-first, before any session is
    /// preempted.
    prefix: Option<PrefixCache>,
    waiting: VecDeque<DecodeRequest>,
    active: Vec<DecodeSession>,
    finished: Vec<DecodeResponse>,
    agg: DecodeStats,
    preemptions: u64,
    decoded_tokens: u64,
    prefill_rejects: u64,
    started: Instant,
    /// This run's latency distributions (the report's percentiles)…
    ttft: Histogram,
    itl: Histogram,
    /// …mirrored into the process-wide registry (handles resolved once
    /// so the retire path never takes the registry lock).
    g_ttft: Arc<Histogram>,
    g_itl: Arc<Histogram>,
    g_peak: Arc<Gauge>,
}

impl ContinuousBatcher {
    pub fn new(cfg: BatcherConfig) -> ContinuousBatcher {
        let reg = crate::telemetry::metrics::global();
        ContinuousBatcher {
            cfg,
            pool: PagePool::new(cfg.page_size, cfg.d, cfg.max_pages),
            prefix: cfg.prefix_cache.then(PrefixCache::new),
            waiting: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            agg: DecodeStats::default(),
            preemptions: 0,
            decoded_tokens: 0,
            prefill_rejects: 0,
            started: Instant::now(),
            ttft: Histogram::new(),
            itl: Histogram::new(),
            g_ttft: reg.histogram(crate::telemetry::names::DECODE_TTFT_MS),
            g_itl: reg.histogram(crate::telemetry::names::DECODE_ITL_MS),
            g_peak: reg.gauge(crate::telemetry::names::DECODE_PEAK_PAGES),
        }
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Prefix-cache counters so far (zeroes when sharing is off).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|c| c.stats).unwrap_or_default()
    }

    /// Drop every prefix-cache entry, releasing the cache's page
    /// references (live sessions keep shared pages alive).  Call before
    /// asserting a fully drained pool, or to return donated residency
    /// when a workload phase ends.
    pub fn release_prefix_cache(&mut self) {
        if let Some(cache) = &mut self.prefix {
            cache.release_all(&mut self.pool);
        }
    }

    /// Shared pages a prefix-cache lookup would attach for `req` right
    /// now (0 with sharing off) — the fit checks' discount.
    fn peek_shared(&self, req: &DecodeRequest) -> usize {
        let Some(cache) = &self.prefix else { return 0 };
        let hashes = req.prefix_hashes(self.cfg.page_size);
        if hashes.is_empty() {
            return 0;
        }
        req.layout.kv_heads
            * cache.peek(&self.pool, req.layout.kv_heads, &hashes, &req.k, &req.v, req.n)
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Admit a request into the waiting queue.  Rejects requests that
    /// could never fit the pool even alone (they would preempt forever).
    pub fn submit(&mut self, req: DecodeRequest) -> Result<()> {
        req.mask.validate()?;
        ensure!(req.d == self.cfg.d, "head dim {} != pool row width {}", req.d, self.cfg.d);
        let worst = req.pages_needed(self.cfg.page_size);
        ensure!(
            worst <= self.cfg.max_pages,
            "request {} needs up to {worst} pages, pool holds {}",
            req.id,
            self.cfg.max_pages
        );
        self.waiting.push_back(req);
        Ok(())
    }

    /// FIFO admission: move waiting sequences into the active set while
    /// slots are open and their prompts fit the pool.
    fn admit(&mut self) {
        while self.active.len() < self.cfg.max_active {
            let Some(req) = self.waiting.pop_front() else { break };
            // fit-check before building the session: constructing the
            // IncrementalMaskView is O(n), too costly to discard every
            // scheduler iteration while the head-of-line request waits.
            // Only *new* pages count — a cached shared prefix is
            // resident already and will be attached, not allocated.
            let prompt_pages = req.layout.kv_heads * req.prompt_len.div_ceil(self.cfg.page_size);
            let mut new_pages = prompt_pages.saturating_sub(self.peek_shared(&req));
            if self.pool.available() < new_pages {
                // before refusing, drop cold cached prefixes: the cache
                // pins donated pages past retirement and must never
                // starve admission when no live session holds them.
                // Re-peek afterwards — reclaim may have evicted exactly
                // the prefix the request would have attached.
                let want = new_pages - self.pool.available();
                if let Some(cache) = &mut self.prefix {
                    cache.reclaim(&mut self.pool, want);
                }
                new_pages = prompt_pages.saturating_sub(self.peek_shared(&req));
                if self.pool.available() < new_pages {
                    // head-of-line waits for pages; no bypass, keep FIFO
                    self.waiting.push_front(req);
                    break;
                }
            }
            if !self.admit_one(req) {
                break;
            }
        }
    }

    /// Build a session for `req` and prefill its prompt.  On prefill
    /// failure the request is re-queued at the *front* of the waiting
    /// queue (FIFO preserved) and `false` is returned — `prefill`
    /// allocates nothing when the prompt no longer fits, so there is no
    /// partial page chain to roll back beyond the empty one `preempt`
    /// releases.  The failure is a real release-mode path, not an
    /// assertable invariant: callers that interleave their own
    /// allocations between `admit`'s fit check and this call (the serve
    /// router's wave admission, tests that drain the pool) must get the
    /// request back, not a silently pageless session in the active set.
    fn admit_one(&mut self, req: DecodeRequest) -> bool {
        let mut session = DecodeSession::new(req, self.cfg.page_size);
        if let Some(proposer) = self.cfg.spec.build(session.req.id) {
            session.set_speculation(proposer, self.cfg.spec.k(), self.cfg.spec.adaptive());
        }
        if !session.prefill(&mut self.pool, self.prefix.as_mut()) {
            self.prefill_rejects += 1;
            log::warn(
                crate::telemetry::names::TARGET_DECODE,
                format!(
                    "request {}: pool drained between fit check and prefill; re-queued",
                    session.req.id
                ),
            );
            self.waiting.push_front(session.preempt(&mut self.pool));
            return false;
        }
        self.active.push(session);
        true
    }

    /// Preemption victim: the active session (index 0 exempt) whose
    /// chains hold the most *unique* pages — the cost-to-recompute
    /// order.  Preempting a mostly-shared session frees almost nothing
    /// (its pages survive under the cache or other readers) yet still
    /// discards its decode progress; the most-unique session returns
    /// the most physical pages per token of discarded work.  Ties
    /// break toward the highest index (newest admission), matching the
    /// pre-sharing policy.
    fn preemption_victim(&self) -> usize {
        debug_assert!(self.active.len() > 1);
        let mut victim = 1;
        let mut best = self.active[1].unique_pages(&self.pool);
        for idx in 2..self.active.len() {
            let unique = self.active[idx].unique_pages(&self.pool);
            if unique >= best {
                best = unique;
                victim = idx;
            }
        }
        victim
    }

    /// One scheduler iteration: admit, step every active sequence one
    /// token (preempting the newest session on page exhaustion), retire
    /// finished sequences.  Returns `false` when all work is done.
    pub fn step(&mut self) -> Result<bool> {
        self.admit();
        if self.active.is_empty() {
            if let Some(req) = self.waiting.front() {
                // nothing active frees pages, so this can never succeed
                bail!(
                    "request {} prompt needs more pages than the whole pool ({} available)",
                    req.id,
                    self.pool.available()
                );
            }
            return Ok(false);
        }
        let mut i = 0;
        while i < self.active.len() {
            // speculative sessions may commit several tokens per
            // iteration; count committed tokens by cursor delta
            let before = self.active[i].pos;
            let outcome = if self.active[i].speculative() {
                self.active[i].try_speculate(&mut self.pool, self.cfg.skip)
            } else {
                self.active[i].try_step(&mut self.pool, self.cfg.skip)
            };
            match outcome {
                StepOutcome::NoPage => {
                    // first try the prefix cache: dropping cold cached
                    // prefixes reclaims pages no live session holds —
                    // strictly cheaper than preempting a session (the
                    // cache's work is already amortised, a victim's is
                    // re-decoded).  Retry the same session when any
                    // physical page came back; terminates because the
                    // cache only shrinks.
                    if let Some(cache) = &mut self.prefix {
                        if cache.reclaim(&mut self.pool, 1) > 0 {
                            continue;
                        }
                    }
                    if self.active.len() == 1 {
                        // unreachable given the submit() fit check, but
                        // fail loudly rather than spin
                        bail!(
                            "session {} stalled alone on an exhausted pool ({} pages)",
                            self.active[i].req.id,
                            self.pool.capacity()
                        );
                    }
                    // evict the session with the most *unique* pages —
                    // preempting it returns the most physical pages and
                    // discards the least shared (cheap-to-reattach)
                    // work; ties break toward the most recently
                    // admitted.  Index 0 is never a victim, so the
                    // oldest sequence always progresses and the
                    // scheduler loop terminates.
                    let victim = self.preemption_victim();
                    let s = self.active.remove(victim);
                    self.preemptions += 1;
                    // the victim's progress is discarded and re-decoded
                    // after readmission — uncount it so `tokens` stays
                    // "useful generated tokens", not work performed
                    self.decoded_tokens -= (s.pos - s.req.prompt_len) as u64;
                    self.waiting.push_front(s.preempt(&mut self.pool));
                    if victim > i {
                        // retry session i with the freed pages
                        continue;
                    }
                    // victim <= i: the active vec shifted left under the
                    // cursor; re-run the slot now holding the next
                    // unstepped session (victim == i retries next pass)
                }
                StepOutcome::Stepped => {
                    self.decoded_tokens += (self.active[i].pos - before) as u64;
                    i += 1;
                }
                StepOutcome::Finished => {
                    self.decoded_tokens += (self.active[i].pos - before) as u64;
                    let s = self.active.remove(i);
                    self.agg.merge(&s.stats);
                    s.stats.publish();
                    let resp = s.retire(&mut self.pool);
                    self.ttft.record_ms(resp.ttft_ms);
                    self.g_ttft.record_ms(resp.ttft_ms);
                    // one sample per inter-token gap, not the sequence
                    // mean: the ITL percentiles must see individual
                    // stalls (single-token sequences have no gaps)
                    for &gap in &resp.itl_gaps_ms {
                        self.itl.record_ms(gap);
                        self.g_itl.record_ms(gap);
                    }
                    self.g_peak.set_max(self.pool.stats.peak_in_use as u64);
                    self.finished.push(resp);
                    // don't advance: the next session shifted into slot i
                }
            }
        }
        Ok(true)
    }

    /// Drive the batcher until every submitted sequence has retired.
    pub fn run(&mut self) -> Result<BatcherReport> {
        while self.step()? {}
        Ok(self.report())
    }

    /// Completed sequences, in retirement order.
    pub fn take_finished(&mut self) -> Vec<DecodeResponse> {
        std::mem::take(&mut self.finished)
    }

    pub fn report(&self) -> BatcherReport {
        // K and V planes, f32 — what the pool's peak residency cost
        let page_bytes = 2 * self.cfg.page_size * self.cfg.d * std::mem::size_of::<f32>();
        BatcherReport {
            sequences: self.finished.len(),
            tokens: self.decoded_tokens,
            tokens_per_s: self.decoded_tokens as f64
                / self.started.elapsed().as_secs_f64().max(1e-9),
            pages_skip_fraction: self.agg.skip_fraction(),
            pages_total: self.agg.pages_total,
            preemptions: self.preemptions,
            evicted_pages: self.pool.stats.evictions,
            peak_pages: self.pool.stats.peak_in_use,
            resident_kv_bytes: self.pool.stats.peak_in_use * page_bytes,
            pages_per_token: if self.decoded_tokens == 0 {
                0.0
            } else {
                self.pool.stats.allocs as f64 / self.decoded_tokens as f64
            },
            drafted_tokens: self.agg.drafted,
            accepted_tokens: self.agg.accepted,
            spec_fallbacks: self.agg.fallback_steps,
            plans_built: self.agg.plans_built,
            ttft_p50_ms: self.ttft.quantile_ms(0.50),
            ttft_p99_ms: self.ttft.quantile_ms(0.99),
            itl_p50_ms: self.itl.quantile_ms(0.50),
            itl_p99_ms: self.itl.quantile_ms(0.99),
            prefill_rejects: self.prefill_rejects,
            prefix_hits: self.prefix_stats().hits,
            prefix_misses: self.prefix_stats().misses,
            prefix_shared_pages: self.prefix_stats().shared_pages,
            cow_copies: self.pool.stats.cow_copies,
            prefill_macs: self.agg.prefill_macs,
        }
    }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::{flash, AttnConfig};
    use crate::mask::{builders, BlockTable};
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }

    fn request(id: u64, heads: usize, n: usize, d: usize, prompt: usize, seed: u64) -> DecodeRequest {
        let mut rng = Rng::new(seed);
        let mask = match id % 3 {
            0 => builders::causal(n),
            1 => builders::sliding_window(n, (n / 4).max(1)),
            _ => builders::causal_document(n, &[n / 2, n - n / 2]),
        };
        DecodeRequest::new(
            id,
            heads,
            n,
            d,
            prompt,
            rand_vec(heads * n * d, &mut rng),
            rand_vec(heads * n * d, &mut rng),
            rand_vec(heads * n * d, &mut rng),
            mask,
        )
    }

    /// Full-sequence prefill oracle for the generated span of one head.
    fn oracle_rows(req: &DecodeRequest, h: usize) -> Vec<f32> {
        let (n, d) = (req.n, req.d);
        let cfg = AttnConfig::new(32.min(n), 32.min(n), d);
        let table = BlockTable::build(&req.mask, cfg.bc);
        let r = h * n * d..(h + 1) * n * d;
        let (out, _) = flash::flashmask_forward(
            &req.q[r.clone()],
            &req.k[r.clone()],
            &req.v[r],
            n,
            d,
            &req.mask,
            &table,
            cfg,
            true,
        );
        out.o[req.prompt_len * d..].to_vec()
    }

    fn assert_matches_oracle(req: &DecodeRequest, resp: &DecodeResponse) {
        let gen = req.gen_len() * req.d;
        assert_eq!(resp.o.len(), req.layout.q_heads * gen);
        for h in 0..req.layout.q_heads {
            let want = oracle_rows(req, h);
            let got = &resp.o[h * gen..(h + 1) * gen];
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "req {} head {h} elem {i}: {a} vs {b}",
                    req.id
                );
            }
        }
    }

    #[test]
    fn continuous_batching_mixed_lengths_match_oracle() {
        // three sequences of different n decode side by side — the
        // same-n restriction of the prefill scheduler does not apply
        let d = 8;
        let reqs: Vec<DecodeRequest> = [(0u64, 40usize, 8usize), (1, 64, 16), (2, 96, 0)]
            .iter()
            .map(|&(id, n, p)| request(id, 2, n, d, p, 100 + id))
            .collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 16,
            d,
            max_pages: 64,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, (40 - 8) + (64 - 16) + 96);
        // plan reuse: one decode plan per session, reused for every
        // token — the schedule is never rebuilt mid-session
        assert_eq!(report.plans_built, 3);
        assert!(report.tokens > report.plans_built);
        // latency histograms: every sequence contributes a TTFT sample,
        // multi-token sequences an ITL sample, and log2-bucket quantiles
        // are monotone in q
        assert!(report.ttft_p50_ms > 0.0);
        assert!(report.ttft_p99_ms >= report.ttft_p50_ms);
        assert!(report.itl_p99_ms >= report.itl_p50_ms);
        for resp in b.finished.iter() {
            assert!(resp.ttft_ms > 0.0 && resp.ttft_ms <= resp.queue_ms + resp.decode_ms + 1.0);
            assert!(resp.itl_ms >= 0.0);
        }
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        for (req, resp) in reqs.iter().zip(&done) {
            assert_eq!(req.id, resp.id);
            assert_matches_oracle(req, resp);
        }
    }

    #[test]
    fn preemption_under_page_pressure_still_correct() {
        // pool big enough for any one sequence but not all three at
        // once: the batcher must preempt (evict + retry) and still
        // produce oracle-exact outputs
        let d = 8;
        let reqs: Vec<DecodeRequest> =
            (0..3u64).map(|id| request(id, 1, 64, d, 0, 200 + id)).collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 10, // one sequence needs 8; three need 24
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert!(report.preemptions > 0, "pool pressure should have preempted");
        assert!(report.evicted_pages > 0);
        // preempted work is uncounted: tokens == useful generated tokens
        assert_eq!(report.tokens, 3 * 64);
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        for (req, resp) in reqs.iter().zip(&done) {
            assert_matches_oracle(req, resp);
        }
    }

    #[test]
    fn oversized_request_rejected_at_submit() {
        let d = 4;
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 2,
            max_active: 2,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        let r = request(0, 1, 64, d, 0, 1); // needs 8 pages
        assert!(b.submit(r).is_err());
    }

    #[test]
    fn wrong_head_dim_rejected() {
        let mut b = ContinuousBatcher::new(BatcherConfig { d: 16, ..Default::default() });
        assert!(b.submit(request(0, 1, 32, 8, 0, 1)).is_err());
    }

    #[test]
    fn max_active_bounds_concurrency() {
        let d = 4;
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 256,
            max_active: 2,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        for id in 0..5u64 {
            b.submit(request(id, 1, 24, d, 0, 300 + id)).unwrap();
        }
        b.step().unwrap();
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.waiting_len(), 3);
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 5);
    }

    #[test]
    fn speculative_batching_matches_oracle_and_accepts() {
        // oracle drafter at full acceptance: every sequence commits k
        // tokens per verify pass and the outputs still match prefill
        let d = 8;
        let reqs: Vec<DecodeRequest> = [(0u64, 40usize, 8usize), (1, 64, 16), (2, 96, 0)]
            .iter()
            .map(|&(id, n, p)| request(id, 2, n, d, p, 400 + id))
            .collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 16,
            d,
            max_pages: 64,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 2, seed: 9 },
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, (40 - 8) + (64 - 16) + 96);
        assert!(report.drafted_tokens > 0);
        assert!(report.accepted_tokens > 0);
        assert_eq!(report.spec_fallbacks, 0, "rate-1 oracle never falls back");
        // branch=2 drafts one junk sibling per pass: acceptance < 1 but
        // the whole truth chain is always committed
        assert!(report.accept_rate() > 0.5, "accept rate {}", report.accept_rate());
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        for (req, resp) in reqs.iter().zip(&done) {
            assert_matches_oracle(req, resp);
        }
    }

    #[test]
    fn speculative_partial_acceptance_still_exact() {
        // rejections interleave verify passes with sequential fallbacks;
        // outputs must stay oracle-exact and every token must commit
        let d = 8;
        let reqs: Vec<DecodeRequest> =
            (0..3u64).map(|id| request(id, 1, 48, d, 0, 500 + id)).collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 256,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Oracle { k: 4, accept_rate: 0.5, branch: 1, seed: 13 },
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, 3 * 48);
        assert!(report.drafted_tokens > report.accepted_tokens, "rate-0.5 must reject some");
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        for (req, resp) in reqs.iter().zip(&done) {
            assert_matches_oracle(req, resp);
        }
    }

    #[test]
    fn preemption_mid_speculation_leaks_no_pages() {
        // satellite: pool pressure fires while sessions are speculating;
        // drafted-but-uncommitted rows must never leak pages, outputs
        // must stay exact, and the pool must drain completely
        let d = 8;
        let reqs: Vec<DecodeRequest> =
            (0..3u64).map(|id| request(id, 1, 64, d, 0, 600 + id)).collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 10, // one sequence alone fits (8 pages), three don't
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 1, seed: 17 },
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert!(report.preemptions > 0, "pool pressure should have preempted");
        assert_eq!(report.tokens, 3 * 64);
        assert_eq!(b.pool().in_use(), 0, "speculation leaked pages");
        assert_eq!(b.pool().available(), 10);
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        for (req, resp) in reqs.iter().zip(&done) {
            assert_matches_oracle(req, resp);
        }
    }

    #[test]
    fn session_no_page_mid_speculation_leaves_state_untouched() {
        // direct session-level check: a draft that cannot fit allocates
        // nothing and changes nothing
        let d = 4;
        let req = request(0, 1, 32, d, 0, 700);
        let mut pool = PagePool::new(8, d, 2); // 16 tokens max
        let mut s = DecodeSession::new(req, 8);
        s.set_speculation(Box::new(spec::OracleProposer::new(1.0, 1, 3)), 4, false);
        assert!(s.prefill(&mut pool, None));
        // decode 14 tokens sequentially-ish via speculation until the
        // pool frontier: at pos 14 a 4-token draft needs a 3rd page
        while s.pos < 14 {
            assert_ne!(s.try_speculate(&mut pool, true), StepOutcome::NoPage);
        }
        let held = s.pages_held();
        let pos = s.pos;
        assert_eq!(s.try_speculate(&mut pool, true), StepOutcome::NoPage);
        assert_eq!(s.pages_held(), held, "NoPage draft must not allocate");
        assert_eq!(s.pos, pos);
        assert_eq!(pool.in_use(), 2);
    }

    #[test]
    fn submit_after_pool_exhaustion_completes() {
        // satellite: new work arrives while the pool is saturated; the
        // late request queues, is admitted once pages free up, finishes
        let d = 4;
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 8,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        for id in 0..2u64 {
            b.submit(request(id, 1, 32, d, 0, 800 + id)).unwrap();
        }
        // drive until the pool is fully committed to the first two
        // (position 28 of 32: 4 pages held each, none released yet)
        for _ in 0..28 {
            assert!(b.step().unwrap());
        }
        assert_eq!(b.pool().available(), 0);
        let late = request(2, 1, 32, d, 0, 802);
        b.submit(late.clone()).unwrap();
        // an oversized late submit still fails loudly, even mid-run
        assert!(b.submit(request(3, 1, 128, d, 0, 803)).is_err());
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 3);
        assert_eq!(report.tokens, 3 * 32);
        let mut done = b.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3);
        assert_matches_oracle(&late, &done[2]);
    }

    /// A GQA request and its MHA twin (same Q, KV heads replicated per
    /// query head): the two must decode to the same rows.
    fn gqa_pair(
        id: u64,
        layout: HeadLayout,
        n: usize,
        d: usize,
        prompt: usize,
        seed: u64,
    ) -> (DecodeRequest, DecodeRequest) {
        let mut rng = Rng::new(seed);
        let mask = match id % 3 {
            0 => builders::causal(n),
            1 => builders::sliding_window(n, (n / 4).max(1)),
            _ => builders::causal_document(n, &[n / 2, n - n / 2]),
        };
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let mut k_rep = Vec::with_capacity(layout.q_heads * n * d);
        let mut v_rep = Vec::with_capacity(layout.q_heads * n * d);
        for qh in 0..layout.q_heads {
            let kh = layout.kv_head_of(qh);
            k_rep.extend_from_slice(&k[kh * n * d..(kh + 1) * n * d]);
            v_rep.extend_from_slice(&v[kh * n * d..(kh + 1) * n * d]);
        }
        let gqa =
            DecodeRequest::with_layout(id, layout, n, d, prompt, q.clone(), k, v, mask.clone());
        let mha = DecodeRequest::new(id, layout.q_heads, n, d, prompt, q, k_rep, v_rep, mask);
        (gqa, mha)
    }

    fn run_one(req: DecodeRequest, max_pages: usize, spec: SpecPolicy) -> (BatcherReport, DecodeResponse) {
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d: req.d,
            max_pages,
            max_active: 4,
            skip: true,
            spec,
            prefix_cache: false,
        });
        b.submit(req).unwrap();
        let report = b.run().unwrap();
        (report, b.take_finished().pop().unwrap())
    }

    #[test]
    fn gqa_matches_replicated_mha_and_holds_group_fewer_pages() {
        // the tentpole's core claim: a grouped layout is semantically a
        // KV-replicated MHA run (bitwise here: identical float ops in
        // identical order) at 1/group the cache residency and 1/group
        // the page-classification work
        let (n, d) = (64, 8);
        for (id, layout) in
            [(0u64, HeadLayout::new(4, 2)), (1, HeadLayout::new(8, 2)), (2, HeadLayout::mqa(4))]
        {
            let g = layout.group();
            let (gqa, mha) = gqa_pair(id, layout, n, d, 8, 1000 + id);
            let (gqa_rep, gqa_resp) = run_one(gqa, 4096, SpecPolicy::Off);
            let (mha_rep, mha_resp) = run_one(mha, 4096, SpecPolicy::Off);
            assert_eq!(gqa_resp.o, mha_resp.o, "{layout}: outputs diverged from MHA twin");
            assert_eq!(gqa_resp.layout, layout);
            // residency: one page chain per KV head
            assert_eq!(mha_rep.peak_pages, g * gqa_rep.peak_pages, "{layout}");
            assert_eq!(mha_rep.resident_kv_bytes, g * gqa_rep.resident_kv_bytes, "{layout}");
            // classification: skip-stat denominators shrink by the group
            // factor, the skip *fraction* is unchanged
            assert_eq!(mha_rep.pages_total, g * gqa_rep.pages_total, "{layout}");
            assert!(
                (mha_rep.pages_skip_fraction - gqa_rep.pages_skip_fraction).abs() < 1e-12,
                "{layout}"
            );
        }
    }

    #[test]
    fn gqa_exact_under_preemption_and_speculative_rollback() {
        // pool pressure preempts mid-flight and speculation rolls the
        // shared KV chains back; outputs must still match the MHA twin
        let (n, d) = (48, 8);
        let layout = HeadLayout::new(4, 1);
        let (gqa, mha) = gqa_pair(1, layout, n, d, 0, 2000);
        let (mha_rep, mha_resp) = run_one(mha, 4096, SpecPolicy::Off);
        assert_eq!(mha_rep.preemptions, 0);
        // 6-page pool vs 6 pages needed per GQA sequence: admit two
        // clones so pressure forces preemption
        let spec = SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 2, seed: 31 };
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 8,
            max_active: 4,
            skip: true,
            spec,
            prefix_cache: false,
        });
        let mut clone = gqa.clone();
        clone.id = 2;
        b.submit(gqa).unwrap();
        b.submit(clone).unwrap();
        let report = b.run().unwrap();
        assert!(report.preemptions > 0, "pool pressure should have preempted");
        assert!(report.drafted_tokens > 0);
        assert_eq!(b.pool().in_use(), 0, "GQA chains leaked pages");
        for resp in b.take_finished() {
            assert_eq!(resp.o.len(), mha_resp.o.len());
            for (i, (a, b)) in resp.o.iter().zip(&mha_resp.o).enumerate() {
                assert!((a - b).abs() < 1e-4, "req {} elem {i}: {a} vs {b}", resp.id);
            }
        }
    }

    #[test]
    fn adaptive_budget_collapses_under_rejection() {
        // satellite: a session whose drafts always miss must converge
        // its budget to k=1 and stop paying for large verify passes
        let d = 4;
        let req = request(0, 1, 64, d, 0, 950);
        let mut pool = PagePool::new(8, d, 64);
        let mut s = DecodeSession::new(req, 8);
        s.set_speculation(Box::new(spec::OracleProposer::new(0.0, 1, 5)), 4, true);
        while !s.finished() {
            assert_ne!(s.try_speculate(&mut pool, true), StepOutcome::NoPage);
        }
        assert_eq!(s.spec_budget(), 1, "rate-0 drafts must collapse the budget");
        // a fixed k=4 policy would draft ~4 per generated token; the
        // collapsed budget pays only the opening passes plus rare probes
        assert!(s.stats.drafted < 64, "drafted {}", s.stats.drafted);
    }

    #[test]
    fn prop_pool_conservation_under_batcher_interleavings() {
        // satellite: allocs == frees + evictions + in_use after any
        // interleaving of admit / step / speculate / preempt / retire,
        // across mixed MHA/GQA/MQA layouts sharing one pool
        crate::util::prop::check(
            "pool-conservation-batcher",
            crate::util::prop::PropConfig { cases: 8, base_seed: 0xBA7C4 },
            |rng| {
                let d = 4;
                let page_size = 4;
                let max_pages = 12 + rng.range(0, 20) as usize;
                let spec = if rng.f64() < 0.5 {
                    SpecPolicy::Oracle {
                        k: 3,
                        accept_rate: 0.7,
                        branch: 2,
                        seed: rng.next_u64(),
                    }
                } else {
                    SpecPolicy::Off
                };
                let mut b = ContinuousBatcher::new(BatcherConfig {
                    page_size,
                    d,
                    max_pages,
                    max_active: 3,
                    skip: true,
                    spec,
                    prefix_cache: rng.f64() < 0.5,
                });
                let mut next_id = 0u64;
                let mut last: Option<DecodeRequest> = None;
                let mut submit_random = |b: &mut ContinuousBatcher, rng: &mut Rng| {
                    // a third of submissions replay the previous prompt
                    // verbatim under a fresh id: with the prefix cache
                    // on these hit, attach shared pages, and CoW on the
                    // first divergent append
                    if let Some(prev) = &last {
                        if rng.f64() < 0.35 {
                            let mut req = prev.clone();
                            req.id = next_id;
                            next_id += 1;
                            let _ = b.submit(req);
                            return;
                        }
                    }
                    let layout = *rng.choose(&[
                        HeadLayout::mha(2),
                        HeadLayout::new(4, 2),
                        HeadLayout::mqa(4),
                    ]);
                    let n = 8 + rng.range(0, 24) as usize;
                    let prompt = rng.range(0, (n / 2) as i64) as usize;
                    let mask = builders::causal(n);
                    let q = rand_vec(layout.q_heads * n * d, rng);
                    let k = rand_vec(layout.kv_heads * n * d, rng);
                    let v = rand_vec(layout.kv_heads * n * d, rng);
                    let req = DecodeRequest::with_layout(
                        next_id, layout, n, d, prompt, q, k, v, mask,
                    );
                    next_id += 1;
                    last = Some(req.clone());
                    // oversized requests are rejected at submit — also a
                    // legal interleaving, the pool must stay conserved
                    let _ = b.submit(req);
                };
                for _ in 0..3 {
                    submit_random(&mut b, rng);
                }
                let mut steps = 0;
                loop {
                    let more = b.step().map_err(|e| e.to_string())?;
                    if !b.pool().conserved() {
                        return Err("conservation broken mid-run".into());
                    }
                    if steps < 20 && rng.f64() < 0.3 {
                        submit_random(&mut b, rng);
                    }
                    steps += 1;
                    if !more && b.waiting_len() == 0 {
                        break;
                    }
                    if steps > 10_000 {
                        return Err("batcher failed to terminate".into());
                    }
                }
                // the prefix cache legitimately pins donated pages past
                // retirement; release it before asserting a full drain
                b.release_prefix_cache();
                if b.pool().in_use() != 0 {
                    return Err(format!("leaked {} pages", b.pool().in_use()));
                }
                if !b.pool().conserved() {
                    return Err("conservation broken after drain".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prefill_failure_between_fit_check_and_prefill_requeues_cleanly() {
        // release-profile-safe regression for the old
        // `debug_assert!(ok, "prefill failed after fit check")`: drain
        // the pool *between* the fit check and the prefill (a wave
        // admitter interleaving its own allocations does exactly this)
        // and assert the request is rolled back and re-queued with
        // nothing allocated.  In release builds the old code silently
        // pushed a pageless session into the active set; this test
        // asserts the handled path, so it holds under both profiles.
        let d = 4;
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 8,
            d,
            max_pages: 4,
            max_active: 2,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        b.submit(request(0, 1, 32, d, 16, 1234)).unwrap(); // prompt: 2 pages
        // the interleaved allocation: every page is taken by the time
        // admit_one runs, even though the fit check would have passed
        let stolen: Vec<_> = (0..4).map(|_| b.pool.try_alloc().unwrap()).collect();
        let cap = crate::telemetry::log::capture();
        let req = b.waiting.pop_front().unwrap();
        assert!(!b.admit_one(req), "prefill must fail on the drained pool");
        assert!(
            cap.take().iter().any(|r| r.level == crate::telemetry::log::Level::Warn
                && r.target == "decode"
                && r.msg.contains("re-queued")),
            "the rejected prefill must be logged"
        );
        drop(cap);
        assert_eq!(b.active_len(), 0, "no pageless session may enter the active set");
        assert_eq!(b.waiting_len(), 1, "the request must be re-queued");
        assert_eq!(b.waiting.front().unwrap().id, 0);
        assert_eq!(b.pool.in_use(), 4, "a failed prefill must not allocate");
        assert!(b.pool.conserved());
        // once the contention clears, the re-queued request completes
        for id in stolen {
            b.pool.free_page(id);
        }
        let report = b.run().unwrap();
        assert_eq!(report.sequences, 1);
        assert_eq!(report.tokens, 32 - 16);
        assert_eq!(report.prefill_rejects, 1);
        assert_eq!(b.pool().in_use(), 0);
    }

    #[test]
    fn prop_decoded_tokens_match_retired_generation_under_preemption() {
        // satellite audit of the preemption accounting
        // (`decoded_tokens -= pos - prompt_len`): across random
        // preempt/readmit interleavings — tight pools preempt
        // organically, mid-run submissions churn the victim order — the
        // counter must always equal
        //     Σ retired gen_len  +  Σ active (pos - prompt_len)
        // after every scheduler iteration, and exactly Σ retired
        // gen_len once drained.  A double-subtract on a session
        // preempted more than once would wrap the u64 or break the
        // equality; the invariant holding here is the audit's verdict
        // that subtracting the *cursor delta since the last admission*
        // is correct however many times a session is evicted.
        crate::util::prop::check(
            "decoded-tokens-preemption",
            crate::util::prop::PropConfig { cases: 8, base_seed: 0xDEC0D },
            |rng| {
                let d = 4;
                let mut b = ContinuousBatcher::new(BatcherConfig {
                    page_size: 4,
                    // one sequence needs <= 8 pages; several don't fit
                    max_pages: 8 + rng.range(0, 4) as usize,
                    d,
                    max_active: 4,
                    skip: true,
                    spec: SpecPolicy::Off,
                    prefix_cache: false,
                });
                let mut next_id = 0u64;
                let mut submit_random = |b: &mut ContinuousBatcher, rng: &mut Rng| {
                    let n = 16 + rng.range(0, 16) as usize;
                    let prompt = rng.range(0, (n / 2) as i64) as usize;
                    let req = request(next_id, 1, n, d, prompt, 3000 + next_id);
                    next_id += 1;
                    b.submit(req).unwrap();
                    (n - prompt) as u64
                };
                let mut expect_total = 0u64;
                for _ in 0..3 {
                    expect_total += submit_random(&mut b, rng);
                }
                let mut steps = 0;
                loop {
                    let more = b.step().map_err(|e| e.to_string())?;
                    // mid-run invariant: useful tokens == retired + live
                    let retired: u64 =
                        b.finished.iter().map(|r| (r.n - r.prompt_len) as u64).sum();
                    let live: u64 =
                        b.active.iter().map(|s| (s.pos - s.req.prompt_len) as u64).sum();
                    if b.decoded_tokens != retired + live {
                        return Err(format!(
                            "step {steps}: decoded_tokens {} != retired {retired} + live {live}",
                            b.decoded_tokens
                        ));
                    }
                    if steps < 10 && rng.f64() < 0.4 {
                        expect_total += submit_random(&mut b, rng);
                    }
                    steps += 1;
                    if !more && b.waiting_len() == 0 {
                        break;
                    }
                    if steps > 10_000 {
                        return Err("batcher failed to terminate".into());
                    }
                }
                let retired: u64 =
                    b.finished.iter().map(|r| (r.n - r.prompt_len) as u64).sum();
                if b.decoded_tokens != retired || retired != expect_total {
                    return Err(format!(
                        "drained: decoded_tokens {} retired {retired} submitted {expect_total}",
                        b.decoded_tokens
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn itl_gaps_are_per_token_and_consistent_with_mean() {
        // satellite: a retired response carries one gap per consecutive
        // generated-token pair (gen-1 of them), the mean field is the
        // mean of exactly those gaps, and the batcher's ITL histogram
        // holds per-token samples — its count is Σ (gen_i - 1), not the
        // number of sequences
        let d = 8;
        let reqs: Vec<DecodeRequest> = [(0u64, 40usize, 8usize), (1, 64, 16), (2, 96, 0)]
            .iter()
            .map(|&(id, n, p)| request(id, 2, n, d, p, 4000 + id))
            .collect();
        let mut b = ContinuousBatcher::new(BatcherConfig {
            page_size: 16,
            d,
            max_pages: 64,
            max_active: 4,
            skip: true,
            spec: SpecPolicy::Off,
            prefix_cache: false,
        });
        for r in &reqs {
            b.submit(r.clone()).unwrap();
        }
        b.run().unwrap();
        let expected_gaps: u64 = reqs.iter().map(|r| (r.gen_len() - 1) as u64).sum();
        assert_eq!(b.itl.count(), expected_gaps, "histogram must hold per-token gaps");
        for resp in b.take_finished() {
            let gen = resp.n - resp.prompt_len;
            assert_eq!(resp.itl_gaps_ms.len(), gen - 1);
            assert!(resp.itl_gaps_ms.iter().all(|&g| g >= 0.0));
            let mean = resp.itl_gaps_ms.iter().sum::<f64>() / (gen - 1) as f64;
            assert!((resp.itl_ms - mean).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_length_prompt_decodes_from_scratch() {
        // satellite: prompt_len == 0 — prefill loads nothing, the first
        // decoded row attends only to itself, speculation works from
        // position 0 (both with and without acceptance)
        let d = 8;
        for spec in [
            SpecPolicy::Off,
            SpecPolicy::Oracle { k: 4, accept_rate: 1.0, branch: 2, seed: 23 },
            SpecPolicy::Oracle { k: 4, accept_rate: 0.0, branch: 1, seed: 23 },
        ] {
            let req = request(0, 2, 40, d, 0, 900);
            let mut b = ContinuousBatcher::new(BatcherConfig {
                page_size: 8,
                d,
                max_pages: 64,
                max_active: 2,
                skip: true,
                spec,
                prefix_cache: false,
            });
            b.submit(req.clone()).unwrap();
            let report = b.run().unwrap();
            assert_eq!(report.sequences, 1, "{spec:?}");
            assert_eq!(report.tokens, 40);
            let done = b.take_finished();
            assert_matches_oracle(&req, &done[0]);
        }
    }

    #[test]
    fn batcher_prefix_sharing_bitwise_identical_and_fewer_pages() {
        // tentpole: three sessions with an identical page-aligned prompt
        // — sharing must cut peak residency and prefill MACs while
        // leaving every decoded output bitwise unchanged
        let d = 4;
        let base = request(0, 1, 48, d, 32, 7100); // prompt = 4 pages, aligned
        let run = |prefix_cache: bool| {
            let mut b = ContinuousBatcher::new(BatcherConfig {
                page_size: 8,
                d,
                max_pages: 64,
                max_active: 4,
                skip: true,
                spec: SpecPolicy::Off,
                prefix_cache,
            });
            for id in 0..3u64 {
                let mut r = base.clone();
                r.id = id;
                b.submit(r).unwrap();
            }
            let report = b.run().unwrap();
            let mut done = b.take_finished();
            done.sort_by_key(|r| r.id);
            b.release_prefix_cache();
            assert_eq!(b.pool().in_use(), 0, "pages leaked (sharing={prefix_cache})");
            assert!(b.pool().conserved());
            (report, done)
        };
        let (off, off_done) = run(false);
        let (on, on_done) = run(true);
        assert_eq!(off.preemptions, 0);
        assert_eq!(on.preemptions, 0);
        for (x, y) in off_done.iter().zip(&on_done) {
            assert_eq!(x.o, y.o, "sharing changed decoded outputs");
        }
        // first session misses and donates, the other two attach 4 pages
        assert_eq!(off.prefix_hits, 0);
        assert_eq!(on.prefix_misses, 1);
        assert_eq!(on.prefix_hits, 2);
        assert_eq!(on.prefix_shared_pages, 2 * 4);
        // prefill compute: only the donor materializes prompt rows
        assert_eq!(off.prefill_macs, 3 * 32 * d as u64);
        assert_eq!(on.prefill_macs, 32 * d as u64);
        assert!(
            on.peak_pages < off.peak_pages,
            "sharing must cut residency: {} vs {}",
            on.peak_pages,
            off.peak_pages
        );
        // page-aligned sharing never writes into a shared page (appends
        // after attach start at a fresh page), so CoW is a guarded
        // invariant here, exercised directly at the kvcache layer
        assert_eq!(on.cow_copies, 0);
    }

    #[test]
    fn prefix_hit_survives_donor_preemption() {
        // negative path (satellite): the donor session is preempted
        // immediately after a recipient attached its pages — refcounts
        // must keep the shared pages resident, the recipient must decode
        // bitwise-identically to a no-sharing run, and the donor's
        // re-admission must itself hit the cache
        let d = 4;
        let req_a = request(0, 1, 40, d, 36, 7200); // 4 full pages + 4 rows
        let mut req_b = req_a.clone();
        req_b.id = 1;
        let mut pool = PagePool::new(8, d, 64);
        let mut cache = PrefixCache::new();
        let mut a = DecodeSession::new(req_a, 8);
        assert!(a.prefill(&mut pool, Some(&mut cache)));
        assert_eq!(cache.stats.misses, 1);
        let mut b = DecodeSession::new(req_b.clone(), 8);
        assert!(b.prefill(&mut pool, Some(&mut cache)));
        assert_eq!(cache.stats.hits, 1, "identical prompt must hit");
        // donor preempted: its unique tail page frees, the 4 shared
        // pages stay resident under the recipient and the cache
        let before = pool.in_use();
        let requeued = a.preempt(&mut pool);
        assert!(pool.conserved());
        assert!(pool.in_use() < before, "donor's unique page must free");
        assert!(pool.in_use() >= 4, "shared pages must survive the donor");
        while !b.finished() {
            assert_ne!(b.try_step(&mut pool, true), StepOutcome::NoPage);
        }
        let resp_b = b.retire(&mut pool);
        // the donor comes back and now *hits* its own donated prefix
        let mut a2 = DecodeSession::new(requeued, 8);
        assert!(a2.prefill(&mut pool, Some(&mut cache)));
        assert_eq!(cache.stats.hits, 2);
        while !a2.finished() {
            assert_ne!(a2.try_step(&mut pool, true), StepOutcome::NoPage);
        }
        let resp_a = a2.retire(&mut pool);
        // no-sharing baseline: bitwise-identical outputs
        let mut solo = DecodeSession::new(req_b, 8);
        assert!(solo.prefill(&mut pool, None));
        while !solo.finished() {
            assert_ne!(solo.try_step(&mut pool, true), StepOutcome::NoPage);
        }
        let resp_solo = solo.retire(&mut pool);
        assert_eq!(resp_b.o, resp_solo.o, "recipient diverged from no-sharing run");
        assert_eq!(resp_a.o, resp_solo.o, "re-admitted donor diverged");
        cache.release_all(&mut pool);
        assert_eq!(pool.in_use(), 0);
        assert!(pool.conserved());
    }
}
