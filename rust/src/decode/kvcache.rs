//! Paged KV cache for autoregressive decode.
//!
//! K/V rows are stored in fixed-size pages (`page_size` tokens of head
//! dimension `d`) drawn from a global [`PagePool`].  A sequence owns a
//! [`PagedKv`] — an ordered list of page ids plus a token count — so
//! cache memory is allocated in page granules as the sequence grows and
//! returned to the pool when it retires (or is preempted, which the
//! pool accounts separately as an eviction).
//!
//! The page is also the *skip granule*: `mask::incremental` classifies
//! whole pages against the FlashMask column intervals, so the decode
//! step kernel never touches pages whose every column is masked for the
//! current row (sliding windows, packed documents, evicted KV entries).

/// Index into the pool's page storage.
pub type PageId = usize;

/// Pool bookkeeping (the numbers a serving dashboard graphs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolStats {
    /// Pages handed out over the pool's lifetime.
    pub allocs: u64,
    /// Pages returned by retiring sequences.
    pub frees: u64,
    /// Pages returned by preemption under memory pressure.
    pub evictions: u64,
    /// Allocation attempts that found the pool exhausted.
    pub alloc_failures: u64,
    /// High-water mark of pages simultaneously in use.
    pub peak_in_use: usize,
}

/// Global fixed-capacity page pool shared by every active sequence.
///
/// Storage is grown lazily up to `max_pages`; freed pages go on a free
/// list and are reused before new storage is touched.
pub struct PagePool {
    page_size: usize,
    d: usize,
    max_pages: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    free: Vec<PageId>,
    in_use: usize,
    pub stats: PoolStats,
}

impl PagePool {
    pub fn new(page_size: usize, d: usize, max_pages: usize) -> PagePool {
        assert!(page_size >= 1 && d >= 1 && max_pages >= 1);
        PagePool {
            page_size,
            d,
            max_pages,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            in_use: 0,
            stats: PoolStats::default(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn capacity(&self) -> usize {
        self.max_pages
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Pages that an `alloc` could still hand out right now.
    pub fn available(&self) -> usize {
        self.max_pages - self.in_use
    }

    /// Conservation invariant: every page ever handed out is either
    /// still in use or was returned through exactly one of free/evict.
    /// The leak detector for per-KV-head page chains — any admit /
    /// step / speculate / preempt / retire interleaving must preserve
    /// it (asserted by the property tests here and in
    /// [`super::session`]).
    pub fn conserved(&self) -> bool {
        self.stats.allocs == self.stats.frees + self.stats.evictions + self.in_use as u64
    }

    /// Hand out one page, or `None` when the pool is exhausted.
    pub fn try_alloc(&mut self) -> Option<PageId> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                if self.k.len() >= self.max_pages {
                    self.stats.alloc_failures += 1;
                    return None;
                }
                let elems = self.page_size * self.d;
                self.k.push(vec![0.0; elems]);
                self.v.push(vec![0.0; elems]);
                self.k.len() - 1
            }
        };
        self.in_use += 1;
        self.stats.allocs += 1;
        self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use);
        Some(id)
    }

    /// Return a page from a retiring sequence.
    pub fn free_page(&mut self, id: PageId) {
        self.release(id);
        self.stats.frees += 1;
    }

    /// Return a page taken back by preemption (eviction accounting).
    pub fn evict_page(&mut self, id: PageId) {
        self.release(id);
        self.stats.evictions += 1;
    }

    fn release(&mut self, id: PageId) {
        debug_assert!(id < self.k.len(), "foreign page id");
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
        self.in_use -= 1;
    }

    pub fn page_k(&self, id: PageId) -> &[f32] {
        &self.k[id]
    }

    pub fn page_v(&self, id: PageId) -> &[f32] {
        &self.v[id]
    }

    fn write_row(&mut self, id: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let off = slot * self.d;
        self.k[id][off..off + self.d].copy_from_slice(k_row);
        self.v[id][off..off + self.d].copy_from_slice(v_row);
    }
}

/// One sequence's (single-head) cache: ordered pages plus token count.
#[derive(Clone, Debug, Default)]
pub struct PagedKv {
    page_ids: Vec<PageId>,
    len: usize,
}

impl PagedKv {
    pub fn new() -> PagedKv {
        PagedKv::default()
    }

    /// Cached tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_pages(&self) -> usize {
        self.page_ids.len()
    }

    pub fn page_id(&self, p: usize) -> PageId {
        self.page_ids[p]
    }

    /// Valid tokens in page `p` (the tail page may be partially filled).
    pub fn page_cols(&self, p: usize, page_size: usize) -> usize {
        debug_assert!(p < self.page_ids.len());
        (self.len - p * page_size).min(page_size)
    }

    /// Append one K/V row; returns `false` (appending nothing) when a
    /// fresh page was needed and the pool is exhausted.
    #[must_use]
    pub fn append(&mut self, pool: &mut PagePool, k_row: &[f32], v_row: &[f32]) -> bool {
        let ps = pool.page_size();
        let slot = self.len % ps;
        if slot == 0 {
            match pool.try_alloc() {
                Some(id) => self.page_ids.push(id),
                None => return false,
            }
        }
        let id = *self.page_ids.last().unwrap();
        pool.write_row(id, slot, k_row, v_row);
        self.len += 1;
        true
    }

    /// Drop rows past `new_len`, returning now-empty tail pages to the
    /// pool — the speculative-decode rollback: drafted-but-rejected
    /// rows vanish and their pages are immediately reusable.  Stale
    /// data left in the kept tail page is unreachable (`page_cols`
    /// bounds every read by `len`) and is overwritten by the next
    /// `append`.
    pub fn truncate(&mut self, pool: &mut PagePool, new_len: usize) {
        assert!(new_len <= self.len, "truncate {new_len} > len {}", self.len);
        let keep = new_len.div_ceil(pool.page_size());
        for id in self.page_ids.drain(keep..) {
            pool.free_page(id);
        }
        self.len = new_len;
    }

    /// Return every page to the pool; `evict` selects the accounting
    /// bucket (preemption vs. normal retirement).
    pub fn release(&mut self, pool: &mut PagePool, evict: bool) {
        for id in self.page_ids.drain(..) {
            if evict {
                pool.evict_page(id);
            } else {
                pool.free_page(id);
            }
        }
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32, d: usize) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn append_and_lookup_roundtrip() {
        let d = 4;
        let mut pool = PagePool::new(3, d, 8);
        let mut kv = PagedKv::new();
        for t in 0..7 {
            assert!(kv.append(&mut pool, &row(t as f32, d), &row(-(t as f32), d)));
        }
        assert_eq!(kv.len(), 7);
        assert_eq!(kv.n_pages(), 3); // ceil(7/3)
        assert_eq!(kv.page_cols(0, 3), 3);
        assert_eq!(kv.page_cols(2, 3), 1); // tail page
        for t in 0..7 {
            let (p, slot) = (t / 3, t % 3);
            let k = pool.page_k(kv.page_id(p));
            let v = pool.page_v(kv.page_id(p));
            assert_eq!(k[slot * d], t as f32);
            assert_eq!(v[slot * d], -(t as f32));
        }
    }

    #[test]
    fn pool_exhaustion_fails_cleanly() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut kv = PagedKv::new();
        for t in 0..4 {
            assert!(kv.append(&mut pool, &row(t as f32, 2), &row(0.0, 2)));
        }
        // pool full: the 5th token needs a 3rd page
        assert!(!kv.append(&mut pool, &row(9.0, 2), &row(9.0, 2)));
        assert_eq!(kv.len(), 4, "failed append must not grow the cache");
        assert_eq!(pool.stats.alloc_failures, 1);
        assert_eq!(pool.available(), 0);
    }

    #[test]
    fn release_recycles_pages() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut a = PagedKv::new();
        for _ in 0..4 {
            assert!(a.append(&mut pool, &row(1.0, 2), &row(1.0, 2)));
        }
        a.release(&mut pool, false);
        assert_eq!(pool.available(), 2);
        assert_eq!(pool.stats.frees, 2);
        // freed pages are reusable by another sequence
        let mut b = PagedKv::new();
        for _ in 0..4 {
            assert!(b.append(&mut pool, &row(2.0, 2), &row(2.0, 2)));
        }
        assert_eq!(b.n_pages(), 2);
        assert_eq!(pool.stats.allocs, 4);
    }

    #[test]
    fn truncate_frees_tail_pages_and_allows_reappend() {
        let d = 2;
        let mut pool = PagePool::new(3, d, 4);
        let mut kv = PagedKv::new();
        for t in 0..8 {
            assert!(kv.append(&mut pool, &row(t as f32, d), &row(t as f32, d)));
        }
        assert_eq!(kv.n_pages(), 3); // ceil(8/3)
        // mid-page truncate: page holding row 4 stays, tail pages freed
        kv.truncate(&mut pool, 5);
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.n_pages(), 2);
        assert_eq!(pool.in_use(), 2);
        // surviving rows intact
        for t in 0..5 {
            let (p, slot) = (t / 3, t % 3);
            assert_eq!(pool.page_k(kv.page_id(p))[slot * d], t as f32);
        }
        // re-append overwrites the stale slot and can regrow pages
        assert!(kv.append(&mut pool, &row(50.0, d), &row(50.0, d)));
        assert_eq!(pool.page_k(kv.page_id(1))[2 * d], 50.0);
        // boundary truncate: exactly page-aligned length keeps the page
        kv.truncate(&mut pool, 3);
        assert_eq!(kv.n_pages(), 1);
        // truncate to zero returns everything
        kv.truncate(&mut pool, 0);
        assert!(kv.is_empty());
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn prop_pool_conservation_random_chain_interleavings() {
        // satellite: allocs == frees + evictions + in_use after any
        // interleaving of append / truncate / release(free) /
        // release(evict) across multiple chains sharing one pool —
        // the chain-level half of the leak detector (the batcher-level
        // admit/step/speculate/preempt/retire half lives in session.rs)
        crate::util::prop::check(
            "pool-conservation-chains",
            crate::util::prop::PropConfig { cases: 24, base_seed: 0xC0DE },
            |rng| {
                let d = 2;
                let ps = 1 + rng.range(1, 4) as usize;
                let max_pages = 4 + rng.range(0, 12) as usize;
                let mut pool = PagePool::new(ps, d, max_pages);
                let mut chains: Vec<PagedKv> = (0..4).map(|_| PagedKv::new()).collect();
                for _ in 0..200 {
                    let c = rng.range(0, chains.len() as i64) as usize;
                    match rng.range(0, 4) {
                        0 | 1 => {
                            // append (may fail on exhaustion — that must
                            // not break conservation either)
                            let _ = chains[c].append(&mut pool, &[1.0; 2], &[2.0; 2]);
                        }
                        2 => {
                            let new_len =
                                rng.range(0, chains[c].len() as i64 + 1) as usize;
                            chains[c].truncate(&mut pool, new_len);
                        }
                        _ => {
                            let evict = rng.f64() < 0.5;
                            chains[c].release(&mut pool, evict);
                        }
                    }
                    if !pool.conserved() {
                        return Err(format!(
                            "conservation broken: allocs {} != frees {} + evictions {} + in_use {}",
                            pool.stats.allocs,
                            pool.stats.frees,
                            pool.stats.evictions,
                            pool.in_use()
                        ));
                    }
                    let held: usize = chains.iter().map(|ch| ch.n_pages()).sum();
                    if held != pool.in_use() {
                        return Err(format!("held {held} != pool in_use {}", pool.in_use()));
                    }
                }
                for ch in &mut chains {
                    ch.release(&mut pool, false);
                }
                if pool.in_use() != 0 || !pool.conserved() {
                    return Err("final drain leaked pages".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_beyond_len_panics() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut kv = PagedKv::new();
        assert!(kv.append(&mut pool, &row(0.0, 2), &row(0.0, 2)));
        kv.truncate(&mut pool, 2);
    }

    #[test]
    fn eviction_accounting_separate_from_frees() {
        let mut pool = PagePool::new(2, 2, 4);
        let mut kv = PagedKv::new();
        for _ in 0..4 {
            assert!(kv.append(&mut pool, &row(0.0, 2), &row(0.0, 2)));
        }
        kv.release(&mut pool, true);
        assert_eq!(pool.stats.evictions, 2);
        assert_eq!(pool.stats.frees, 0);
        assert_eq!(pool.stats.peak_in_use, 2);
        assert_eq!(pool.in_use(), 0);
    }
}
