//! Paged KV cache for autoregressive decode.
//!
//! K/V rows are stored in fixed-size pages (`page_size` tokens of head
//! dimension `d`) drawn from a global [`PagePool`].  A sequence owns a
//! [`PagedKv`] — an ordered list of page ids plus a token count — so
//! cache memory is allocated in page granules as the sequence grows and
//! returned to the pool when it retires (or is preempted, which the
//! pool accounts separately as an eviction).
//!
//! The page is also the *skip granule*: `mask::incremental` classifies
//! whole pages against the FlashMask column intervals, so the decode
//! step kernel never touches pages whose every column is masked for the
//! current row (sliding windows, packed documents, evicted KV entries).
//!
//! # Page sharing
//!
//! Every page carries a refcount.  A page with one reference behaves
//! exactly as before; [`PagePool::retain`] lets a second reader attach
//! the same physical page (content-addressed prompt-prefix reuse via
//! [`PrefixCache`]), and `free_page`/`evict_page` only return the page
//! to the free list when the *last* reference drops — a shared page is
//! unevictable until its last reader retires.  Writes go through
//! copy-on-write: appending into a partially-filled shared page first
//! clones it into a private page ([`PoolStats::cow_copies`]), so no
//! reader ever observes another sequence's rows.  `truncate` never
//! clones — it only drops references — because stale rows in a kept
//! shared page are unreachable (`page_cols` bounds every read by `len`)
//! and the next `append` into it triggers the clone.

use std::collections::HashMap;

/// Index into the pool's page storage.
pub type PageId = usize;

/// Pool bookkeeping (the numbers a serving dashboard graphs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolStats {
    /// Pages handed out over the pool's lifetime.
    pub allocs: u64,
    /// Pages returned by retiring sequences.
    pub frees: u64,
    /// Pages returned by preemption under memory pressure.
    pub evictions: u64,
    /// Allocation attempts that found the pool exhausted.
    pub alloc_failures: u64,
    /// High-water mark of pages simultaneously in use.
    pub peak_in_use: usize,
    /// Extra references handed out on already-resident pages.
    pub retains: u64,
    /// Releases that dropped a reference on a still-shared page
    /// (no physical page returned to the free list).
    pub shared_releases: u64,
    /// Shared pages cloned into private copies before a write.
    pub cow_copies: u64,
}

/// Global fixed-capacity page pool shared by every active sequence.
///
/// Storage is grown lazily up to `max_pages`; freed pages go on a free
/// list and are reused before new storage is touched.  `in_use` /
/// `available` count *physical* pages — a page shared by five readers
/// occupies one slot — which is what admission fit checks care about.
pub struct PagePool {
    page_size: usize,
    d: usize,
    max_pages: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    refs: Vec<u32>,
    free: Vec<PageId>,
    in_use: usize,
    pub stats: PoolStats,
}

impl PagePool {
    pub fn new(page_size: usize, d: usize, max_pages: usize) -> PagePool {
        assert!(page_size >= 1 && d >= 1 && max_pages >= 1);
        PagePool {
            page_size,
            d,
            max_pages,
            k: Vec::new(),
            v: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            in_use: 0,
            stats: PoolStats::default(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn capacity(&self) -> usize {
        self.max_pages
    }

    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Pages that an `alloc` could still hand out right now.
    pub fn available(&self) -> usize {
        self.max_pages - self.in_use
    }

    /// Current reference count of a page (0 = on the free list).
    pub fn ref_count(&self, id: PageId) -> u32 {
        self.refs[id]
    }

    /// Live references across all resident pages (each shared reader
    /// counts once).
    pub fn live_refs(&self) -> u64 {
        self.refs.iter().map(|&r| r as u64).sum()
    }

    /// Conservation invariants, physical and logical.
    ///
    /// Physical: every page ever handed out is either still resident or
    /// was returned through exactly one of free/evict.  Logical: every
    /// reference ever created (`allocs` + `retains`) was either dropped
    /// (`frees` + `evictions` + `shared_releases`) or is still live.
    /// The leak detector for per-KV-head page chains — any admit /
    /// step / speculate / share / CoW / preempt / retire interleaving
    /// must preserve both (asserted by the property tests here and in
    /// [`super::session`]).
    pub fn conserved(&self) -> bool {
        let physical =
            self.stats.allocs == self.stats.frees + self.stats.evictions + self.in_use as u64;
        let dropped = self.stats.frees + self.stats.evictions + self.stats.shared_releases;
        let logical = self.stats.allocs + self.stats.retains == dropped + self.live_refs();
        physical && logical
    }

    /// Hand out one page (refcount 1), or `None` when the pool is
    /// exhausted.
    pub fn try_alloc(&mut self) -> Option<PageId> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                if self.k.len() >= self.max_pages {
                    self.stats.alloc_failures += 1;
                    return None;
                }
                let elems = self.page_size * self.d;
                self.k.push(vec![0.0; elems]);
                self.v.push(vec![0.0; elems]);
                self.refs.push(0);
                self.k.len() - 1
            }
        };
        debug_assert_eq!(self.refs[id], 0, "page on free list with live refs");
        self.refs[id] = 1;
        self.in_use += 1;
        self.stats.allocs += 1;
        self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use);
        Some(id)
    }

    /// Add a reference to an already-resident page (prefix sharing).
    pub fn retain(&mut self, id: PageId) {
        debug_assert!(id < self.refs.len(), "foreign page id");
        debug_assert!(self.refs[id] > 0, "retain of a free page {id}");
        self.refs[id] += 1;
        self.stats.retains += 1;
    }

    /// Drop a reference from a retiring sequence; the page returns to
    /// the free list only when this was the last reference.
    pub fn free_page(&mut self, id: PageId) {
        if self.drop_ref(id) {
            self.stats.frees += 1;
        }
    }

    /// Drop a reference taken back by preemption (eviction accounting).
    /// A still-shared page is *not* evicted — only the reference goes.
    pub fn evict_page(&mut self, id: PageId) {
        if self.drop_ref(id) {
            self.stats.evictions += 1;
        }
    }

    /// Returns true when the page physically left the pool.
    fn drop_ref(&mut self, id: PageId) -> bool {
        debug_assert!(id < self.refs.len(), "foreign page id");
        debug_assert!(self.refs[id] > 0, "double free of page {id}");
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.free.push(id);
            self.in_use -= 1;
            true
        } else {
            self.stats.shared_releases += 1;
            false
        }
    }

    /// Clone a shared page into a fresh private one (copy-on-write),
    /// dropping the caller's reference on the original.  `None` when
    /// the pool is exhausted, in which case the original keeps its
    /// reference and nothing changed.
    fn cow_clone(&mut self, id: PageId) -> Option<PageId> {
        debug_assert!(self.refs[id] > 1, "CoW of an unshared page");
        let new_id = self.try_alloc()?;
        let (src, dst) = if id < new_id {
            let (a, b) = self.k.split_at_mut(new_id);
            b[0].copy_from_slice(&a[id]);
            let (a, b) = self.v.split_at_mut(new_id);
            b[0].copy_from_slice(&a[id]);
            (id, new_id)
        } else {
            let (a, b) = self.k.split_at_mut(id);
            a[new_id].copy_from_slice(&b[0]);
            let (a, b) = self.v.split_at_mut(id);
            a[new_id].copy_from_slice(&b[0]);
            (id, new_id)
        };
        debug_assert_ne!(src, dst);
        self.refs[id] -= 1;
        self.stats.shared_releases += 1;
        self.stats.cow_copies += 1;
        crate::telemetry::metrics::global().add(crate::telemetry::names::PREFIX_COW_COPIES, 1);
        Some(new_id)
    }

    pub fn page_k(&self, id: PageId) -> &[f32] {
        &self.k[id]
    }

    pub fn page_v(&self, id: PageId) -> &[f32] {
        &self.v[id]
    }

    fn write_row(&mut self, id: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        debug_assert_eq!(self.refs[id], 1, "write into a shared page (missed CoW)");
        let off = slot * self.d;
        self.k[id][off..off + self.d].copy_from_slice(k_row);
        self.v[id][off..off + self.d].copy_from_slice(v_row);
    }
}

/// One sequence's (single-head) cache: ordered pages plus token count.
#[derive(Clone, Debug, Default)]
pub struct PagedKv {
    page_ids: Vec<PageId>,
    len: usize,
}

impl PagedKv {
    pub fn new() -> PagedKv {
        PagedKv::default()
    }

    /// Cached tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_pages(&self) -> usize {
        self.page_ids.len()
    }

    pub fn page_id(&self, p: usize) -> PageId {
        self.page_ids[p]
    }

    /// Valid tokens in page `p` (the tail page may be partially filled).
    pub fn page_cols(&self, p: usize, page_size: usize) -> usize {
        debug_assert!(p < self.page_ids.len());
        (self.len - p * page_size).min(page_size)
    }

    /// Attach already-resident *full* pages as a shared prefix, adding
    /// a reference to each.  Only legal at a page boundary (a shared
    /// page is full by construction — sharing below page granularity
    /// does not exist), so in practice on an empty chain at prefill.
    pub fn attach_shared(&mut self, pool: &mut PagePool, ids: &[PageId]) {
        assert_eq!(
            self.len % pool.page_size(),
            0,
            "shared prefix attach must be page-aligned"
        );
        for &id in ids {
            pool.retain(id);
            self.page_ids.push(id);
        }
        self.len += ids.len() * pool.page_size();
    }

    /// Append one K/V row; returns `false` (appending nothing) when a
    /// fresh page was needed (or a shared tail page had to be cloned)
    /// and the pool is exhausted.
    #[must_use]
    pub fn append(&mut self, pool: &mut PagePool, k_row: &[f32], v_row: &[f32]) -> bool {
        let ps = pool.page_size();
        let slot = self.len % ps;
        if slot == 0 {
            match pool.try_alloc() {
                Some(id) => self.page_ids.push(id),
                None => return false,
            }
        } else {
            // copy-on-write: never scribble on a page another reader
            // (or the prefix cache) still holds
            let id = *self.page_ids.last().unwrap();
            if pool.ref_count(id) > 1 {
                match pool.cow_clone(id) {
                    Some(new_id) => *self.page_ids.last_mut().unwrap() = new_id,
                    None => return false,
                }
            }
        }
        let id = *self.page_ids.last().unwrap();
        pool.write_row(id, slot, k_row, v_row);
        self.len += 1;
        true
    }

    /// Drop rows past `new_len`, releasing now-empty tail pages to the
    /// pool — the speculative-decode rollback: drafted-but-rejected
    /// rows vanish and their pages are immediately reusable.  Stale
    /// data left in the kept tail page is unreachable (`page_cols`
    /// bounds every read by `len`) and is overwritten by the next
    /// `append` — which clones first if the page is shared, so
    /// truncating into a shared prefix page never needs a copy here.
    pub fn truncate(&mut self, pool: &mut PagePool, new_len: usize) {
        assert!(new_len <= self.len, "truncate {new_len} > len {}", self.len);
        let keep = new_len.div_ceil(pool.page_size());
        for id in self.page_ids.drain(keep..) {
            pool.free_page(id);
        }
        self.len = new_len;
    }

    /// Drop every reference; `evict` selects the accounting bucket
    /// (preemption vs. normal retirement).  Pages still shared with
    /// other readers stay resident.
    pub fn release(&mut self, pool: &mut PagePool, evict: bool) {
        for id in self.page_ids.drain(..) {
            if evict {
                pool.evict_page(id);
            } else {
                pool.free_page(id);
            }
        }
        self.len = 0;
    }

    /// Pages only this chain references (physically freed if the chain
    /// were released right now) — the preemption cost signal: a victim
    /// with many unique pages gives memory back, one whose pages are
    /// all shared frees nothing.
    pub fn unique_pages(&self, pool: &PagePool) -> usize {
        self.page_ids.iter().filter(|&&id| pool.ref_count(id) == 1).count()
    }
}

// ---------------------------------------------------------------------------
// Content-addressed prefix index
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a_u64(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// Cumulative FNV-1a hash chain over page-aligned prompt-prefix K/V
/// content, head-major across all KV-head chains (the same
/// content-key idiom as `attention::api::PlanCache`, hashing f32 bit
/// patterns so the key is exact, not tolerance-based).
///
/// `k`/`v` are head-major `[kv_heads][n][d]`; entry `p` of the result
/// keys the first `p + 1` full pages of the prompt (tokens
/// `0..(p+1)*page_size`).  The partial tail page of an unaligned
/// prompt is never keyed — sharing below page granularity does not
/// exist.  Callers feed these hashes to [`PrefixCache`]; tests forge
/// them to exercise the equality double-check.
pub fn prefix_hash_chain(
    k: &[f32],
    v: &[f32],
    kv_heads: usize,
    n: usize,
    d: usize,
    prompt_len: usize,
    page_size: usize,
) -> Vec<u64> {
    let full_pages = prompt_len / page_size;
    let mut hashes = Vec::with_capacity(full_pages);
    let mut h = fnv1a_u64(FNV_OFFSET, kv_heads as u64);
    h = fnv1a_u64(h, d as u64);
    h = fnv1a_u64(h, page_size as u64);
    for p in 0..full_pages {
        for kh in 0..kv_heads {
            let base = kh * n * d + p * page_size * d;
            for &x in &k[base..base + page_size * d] {
                h = fnv1a_u64(h, x.to_bits() as u64);
            }
            for &x in &v[base..base + page_size * d] {
                h = fnv1a_u64(h, x.to_bits() as u64);
            }
        }
        hashes.push(h);
    }
    hashes
}

/// Counters the prefix index keeps (mirrored into `BatcherReport` /
/// `RouterReport` and the `prefix.*` global metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrefixStats {
    /// Lookups that attached at least one shared page.
    pub hits: u64,
    /// Lookups that found no reusable prefix.
    pub misses: u64,
    /// Prefix lengths registered (one per new cumulative entry).
    pub insertions: u64,
    /// Hash matches rejected by the content equality double-check.
    pub collisions: u64,
    /// Total pages attached as shared prefixes (sum over hits).
    pub shared_pages: u64,
    /// Physical pages freed by LRU reclaim under pool pressure.
    pub reclaimed_pages: u64,
}

struct PrefixEntry {
    kv_heads: usize,
    /// Full pages per KV-head chain covered by this entry.
    n_pages: usize,
    /// `[kv_heads][n_pages]` page ids, each holding one cache reference.
    pages: Vec<Vec<PageId>>,
    last_use: u64,
}

/// Content-addressed index of page-aligned prompt prefixes.
///
/// Keyed by [`prefix_hash_chain`] values; every hash hit is
/// double-checked against the pool's actual page bytes before any
/// sharing happens (as in `PlanCache`), so a hash collision degrades to
/// a miss, never to wrong KV content.  The cache itself is a reader:
/// registering a prefix retains its pages, so donor retirement cannot
/// free them out from under later sessions.  Under pool pressure
/// [`PrefixCache::reclaim`] drops least-recently-used entries — cached
/// pages are reclaimed before any live session is preempted.
#[derive(Default)]
pub struct PrefixCache {
    entries: HashMap<u64, PrefixEntry>,
    seq: u64,
    pub stats: PrefixStats,
}

impl PrefixCache {
    pub fn new() -> PrefixCache {
        PrefixCache::default()
    }

    /// Cached prefix entries (cumulative lengths count separately).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// References this cache holds (pages counted once per entry using
    /// them).
    pub fn held_refs(&self) -> usize {
        self.entries.values().map(|e| e.kv_heads * e.n_pages).sum()
    }

    fn touch(&mut self, key: u64) {
        self.seq += 1;
        let seq = self.seq;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = seq;
        }
    }

    /// Bitwise compare an entry's cached pages against a candidate
    /// prompt's head-major K/V rows — the collision guard.
    fn content_matches(
        entry: &PrefixEntry,
        pool: &PagePool,
        k: &[f32],
        v: &[f32],
        n: usize,
    ) -> bool {
        let (ps, d) = (pool.page_size(), pool.d());
        for kh in 0..entry.kv_heads {
            for p in 0..entry.n_pages {
                let base = kh * n * d + p * ps * d;
                let want_k = &k[base..base + ps * d];
                let want_v = &v[base..base + ps * d];
                let id = entry.pages[kh][p];
                let bits_eq = |a: &[f32], b: &[f32]| {
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                };
                if !bits_eq(pool.page_k(id), want_k) || !bits_eq(pool.page_v(id), want_v) {
                    return false;
                }
            }
        }
        true
    }

    /// Longest cached page-aligned prefix of the candidate prompt,
    /// without mutating anything — the admission fit checks' view.
    /// Returns the number of full pages *per KV-head chain* that a
    /// prefill lookup would attach (0 = no reuse).
    pub fn peek(
        &self,
        pool: &PagePool,
        kv_heads: usize,
        hashes: &[u64],
        k: &[f32],
        v: &[f32],
        n: usize,
    ) -> usize {
        for p in (0..hashes.len()).rev() {
            if let Some(e) = self.entries.get(&hashes[p]) {
                if e.kv_heads == kv_heads
                    && e.n_pages == p + 1
                    && Self::content_matches(e, pool, k, v, n)
                {
                    return p + 1;
                }
            }
        }
        0
    }

    /// Longest cached page-aligned prefix match for a candidate prompt:
    /// walks the hash chain from longest to shortest, double-checking
    /// every hash hit against page content.  On a hit returns the
    /// `[kv_heads][pages]` page ids (NOT yet retained — the caller
    /// attaches them per chain via [`PagedKv::attach_shared`]) and the
    /// token count they cover.
    pub fn lookup(
        &mut self,
        pool: &PagePool,
        kv_heads: usize,
        hashes: &[u64],
        k: &[f32],
        v: &[f32],
        n: usize,
    ) -> Option<(Vec<Vec<PageId>>, usize)> {
        for p in (0..hashes.len()).rev() {
            let key = hashes[p];
            let Some(e) = self.entries.get(&key) else { continue };
            if e.kv_heads != kv_heads || e.n_pages != p + 1 {
                continue;
            }
            if !Self::content_matches(e, pool, k, v, n) {
                self.stats.collisions += 1;
                crate::telemetry::metrics::global().add(crate::telemetry::names::PREFIX_COLLISIONS, 1);
                continue;
            }
            let pages = e.pages.clone();
            let tokens = (p + 1) * pool.page_size();
            self.touch(key);
            self.stats.hits += 1;
            self.stats.shared_pages += (kv_heads * (p + 1)) as u64;
            let reg = crate::telemetry::metrics::global();
            reg.add(crate::telemetry::names::PREFIX_HITS, 1);
            reg.add(crate::telemetry::names::PREFIX_SHARED_PAGES, (kv_heads * (p + 1)) as u64);
            return Some((pages, tokens));
        }
        self.stats.misses += 1;
        crate::telemetry::metrics::global().add(crate::telemetry::names::PREFIX_MISSES, 1);
        None
    }

    /// Register a donor's page-aligned prompt prefix: one cumulative
    /// entry per full page count, each retaining the pages it covers so
    /// they outlive the donor.  Existing entries (same content) are
    /// left alone; a hash collision with different content keeps the
    /// incumbent and counts a collision.
    pub fn register(
        &mut self,
        pool: &mut PagePool,
        hashes: &[u64],
        chains: &[PagedKv],
    ) {
        let kv_heads = chains.len();
        for (p, &key) in hashes.iter().enumerate() {
            debug_assert!(chains.iter().all(|c| c.n_pages() > p));
            if let Some(e) = self.entries.get(&key) {
                if e.kv_heads != kv_heads || e.n_pages != p + 1 {
                    self.stats.collisions += 1;
                }
                // same content already cached (the common case: a
                // second session re-registering the shared prefix it
                // just attached) — or a collision; either way keep the
                // incumbent
                continue;
            }
            let mut pages = Vec::with_capacity(kv_heads);
            for c in chains {
                let ids: Vec<PageId> = (0..=p).map(|i| c.page_id(i)).collect();
                for &id in &ids {
                    pool.retain(id);
                }
                pages.push(ids);
            }
            self.seq += 1;
            self.entries.insert(
                key,
                PrefixEntry { kv_heads, n_pages: p + 1, pages, last_use: self.seq },
            );
            self.stats.insertions += 1;
        }
    }

    /// Drop least-recently-used entries until at least `want` physical
    /// pages came back to the free list (or the cache is empty).
    /// Returns the pages actually freed — fewer than the refs dropped
    /// when live sessions still share them, zero when every cached page
    /// has another reader.
    pub fn reclaim(&mut self, pool: &mut PagePool, want: usize) -> usize {
        let before = pool.in_use();
        while before - pool.in_use() < want && !self.entries.is_empty() {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&k, _)| k)
                .expect("non-empty");
            let e = self.entries.remove(&lru).expect("present");
            for chain in &e.pages {
                for &id in chain {
                    pool.evict_page(id);
                }
            }
        }
        let freed = before - pool.in_use();
        self.stats.reclaimed_pages += freed as u64;
        freed
    }

    /// Drop every entry, releasing all cache-held references (normal
    /// free accounting).  Used when a batcher drains or sharing is
    /// switched off; live sessions keep shared pages alive.
    pub fn release_all(&mut self, pool: &mut PagePool) {
        for (_, e) in self.entries.drain() {
            for chain in &e.pages {
                for &id in chain {
                    pool.free_page(id);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32, d: usize) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn append_and_lookup_roundtrip() {
        let d = 4;
        let mut pool = PagePool::new(3, d, 8);
        let mut kv = PagedKv::new();
        for t in 0..7 {
            assert!(kv.append(&mut pool, &row(t as f32, d), &row(-(t as f32), d)));
        }
        assert_eq!(kv.len(), 7);
        assert_eq!(kv.n_pages(), 3); // ceil(7/3)
        assert_eq!(kv.page_cols(0, 3), 3);
        assert_eq!(kv.page_cols(2, 3), 1); // tail page
        for t in 0..7 {
            let (p, slot) = (t / 3, t % 3);
            let k = pool.page_k(kv.page_id(p));
            let v = pool.page_v(kv.page_id(p));
            assert_eq!(k[slot * d], t as f32);
            assert_eq!(v[slot * d], -(t as f32));
        }
    }

    #[test]
    fn pool_exhaustion_fails_cleanly() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut kv = PagedKv::new();
        for t in 0..4 {
            assert!(kv.append(&mut pool, &row(t as f32, 2), &row(0.0, 2)));
        }
        // pool full: the 5th token needs a 3rd page
        assert!(!kv.append(&mut pool, &row(9.0, 2), &row(9.0, 2)));
        assert_eq!(kv.len(), 4, "failed append must not grow the cache");
        assert_eq!(pool.stats.alloc_failures, 1);
        assert_eq!(pool.available(), 0);
    }

    #[test]
    fn release_recycles_pages() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut a = PagedKv::new();
        for _ in 0..4 {
            assert!(a.append(&mut pool, &row(1.0, 2), &row(1.0, 2)));
        }
        a.release(&mut pool, false);
        assert_eq!(pool.available(), 2);
        assert_eq!(pool.stats.frees, 2);
        // freed pages are reusable by another sequence
        let mut b = PagedKv::new();
        for _ in 0..4 {
            assert!(b.append(&mut pool, &row(2.0, 2), &row(2.0, 2)));
        }
        assert_eq!(b.n_pages(), 2);
        assert_eq!(pool.stats.allocs, 4);
    }

    #[test]
    fn truncate_frees_tail_pages_and_allows_reappend() {
        let d = 2;
        let mut pool = PagePool::new(3, d, 4);
        let mut kv = PagedKv::new();
        for t in 0..8 {
            assert!(kv.append(&mut pool, &row(t as f32, d), &row(t as f32, d)));
        }
        assert_eq!(kv.n_pages(), 3); // ceil(8/3)
        // mid-page truncate: page holding row 4 stays, tail pages freed
        kv.truncate(&mut pool, 5);
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.n_pages(), 2);
        assert_eq!(pool.in_use(), 2);
        // surviving rows intact
        for t in 0..5 {
            let (p, slot) = (t / 3, t % 3);
            assert_eq!(pool.page_k(kv.page_id(p))[slot * d], t as f32);
        }
        // re-append overwrites the stale slot and can regrow pages
        assert!(kv.append(&mut pool, &row(50.0, d), &row(50.0, d)));
        assert_eq!(pool.page_k(kv.page_id(1))[2 * d], 50.0);
        // boundary truncate: exactly page-aligned length keeps the page
        kv.truncate(&mut pool, 3);
        assert_eq!(kv.n_pages(), 1);
        // truncate to zero returns everything
        kv.truncate(&mut pool, 0);
        assert!(kv.is_empty());
        assert_eq!(pool.in_use(), 0);
    }

    #[test]
    fn shared_page_refcount_lifecycle() {
        let d = 2;
        let mut pool = PagePool::new(2, d, 4);
        let mut a = PagedKv::new();
        for t in 0..4 {
            assert!(a.append(&mut pool, &row(t as f32, d), &row(t as f32, d)));
        }
        // b shares a's two full pages
        let ids: Vec<PageId> = (0..a.n_pages()).map(|p| a.page_id(p)).collect();
        let mut b = PagedKv::new();
        b.attach_shared(&mut pool, &ids);
        assert_eq!(b.len(), 4);
        assert_eq!(pool.in_use(), 2, "sharing costs no physical pages");
        assert_eq!(pool.ref_count(ids[0]), 2);
        assert_eq!(pool.stats.retains, 2);
        // donor retires: pages stay resident for b
        a.release(&mut pool, false);
        assert_eq!(pool.in_use(), 2, "shared pages survive the donor");
        assert_eq!(pool.stats.shared_releases, 2);
        assert_eq!(pool.stats.frees, 0);
        assert_eq!(pool.page_k(b.page_id(1))[0], 2.0, "content intact");
        // last reader retires: pages physically free
        b.release(&mut pool, false);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(pool.stats.frees, 2);
        assert!(pool.conserved());
    }

    #[test]
    fn shared_page_eviction_only_drops_reference() {
        let d = 2;
        let mut pool = PagePool::new(2, d, 4);
        let mut a = PagedKv::new();
        for _ in 0..2 {
            assert!(a.append(&mut pool, &row(1.0, d), &row(1.0, d)));
        }
        let mut b = PagedKv::new();
        b.attach_shared(&mut pool, &[a.page_id(0)]);
        // preempting b must not evict the shared page out from under a
        b.release(&mut pool, true);
        assert_eq!(pool.stats.evictions, 0, "shared page is unevictable");
        assert_eq!(pool.stats.shared_releases, 1);
        assert_eq!(pool.in_use(), 1);
        a.release(&mut pool, true);
        assert_eq!(pool.stats.evictions, 1, "last reader's eviction counts");
        assert!(pool.conserved());
    }

    #[test]
    fn cow_on_append_into_shared_tail_page() {
        let d = 2;
        let mut pool = PagePool::new(4, d, 4);
        let mut a = PagedKv::new();
        for t in 0..4 {
            assert!(a.append(&mut pool, &row(t as f32, d), &row(t as f32, d)));
        }
        let mut b = PagedKv::new();
        b.attach_shared(&mut pool, &[a.page_id(0)]);
        // b rolls back into the shared page, then appends a diverging
        // row: the write must clone, not scribble on a's page
        b.truncate(&mut pool, 2);
        assert_eq!(pool.stats.cow_copies, 0, "truncate alone never clones");
        assert!(b.append(&mut pool, &row(99.0, d), &row(99.0, d)));
        assert_eq!(pool.stats.cow_copies, 1);
        assert_ne!(b.page_id(0), a.page_id(0), "b moved to a private copy");
        assert_eq!(pool.ref_count(a.page_id(0)), 1, "a's ref survives the CoW");
        // a sees its original rows, b sees shared prefix + divergence
        assert_eq!(pool.page_k(a.page_id(0))[2 * d], 2.0);
        assert_eq!(pool.page_k(b.page_id(0))[0], 0.0, "cloned prefix kept");
        assert_eq!(pool.page_k(b.page_id(0))[2 * d], 99.0);
        assert!(pool.conserved());
        a.release(&mut pool, false);
        b.release(&mut pool, false);
        assert_eq!(pool.in_use(), 0);
        assert!(pool.conserved());
    }

    #[test]
    fn cow_exhaustion_leaves_share_intact() {
        let d = 2;
        let mut pool = PagePool::new(2, d, 1);
        let mut a = PagedKv::new();
        for _ in 0..2 {
            assert!(a.append(&mut pool, &row(1.0, d), &row(1.0, d)));
        }
        let mut b = PagedKv::new();
        b.attach_shared(&mut pool, &[a.page_id(0)]);
        b.truncate(&mut pool, 1);
        // pool has no free page for the clone: append must fail cleanly
        assert!(!b.append(&mut pool, &row(9.0, d), &row(9.0, d)));
        assert_eq!(b.len(), 1);
        assert_eq!(pool.ref_count(a.page_id(0)), 2, "reference unchanged");
        assert_eq!(pool.page_k(a.page_id(0))[d], 1.0, "no partial write");
        assert!(pool.conserved());
        a.release(&mut pool, false);
        b.release(&mut pool, false);
        assert!(pool.conserved());
    }

    fn fill_chains(
        pool: &mut PagePool,
        kv_heads: usize,
        k: &[f32],
        v: &[f32],
        n: usize,
        len: usize,
    ) -> Vec<PagedKv> {
        let d = pool.d();
        let mut chains: Vec<PagedKv> = (0..kv_heads).map(|_| PagedKv::new()).collect();
        for (kh, c) in chains.iter_mut().enumerate() {
            for t in 0..len {
                let off = kh * n * d + t * d;
                assert!(c.append(pool, &k[off..off + d], &v[off..off + d]));
            }
        }
        chains
    }

    fn prompt(seed: u64, kv_heads: usize, n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let k: Vec<f32> = (0..kv_heads * n * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..kv_heads * n * d).map(|_| rng.normal_f32()).collect();
        (k, v)
    }

    #[test]
    fn prefix_cache_longest_match_and_double_check() {
        let (kv_heads, n, d, ps) = (2, 12, 3, 4);
        let mut pool = PagePool::new(ps, d, 32);
        let mut cache = PrefixCache::new();
        let (k, v) = prompt(7, kv_heads, n, d);
        let hashes = prefix_hash_chain(&k, &v, kv_heads, n, d, 12, ps);
        assert_eq!(hashes.len(), 3);
        let chains = fill_chains(&mut pool, kv_heads, &k, &v, n, 12);
        cache.register(&mut pool, &hashes, &chains);
        assert_eq!(cache.stats.insertions, 3);
        // a prompt sharing only the first 2 pages matches at length 2
        let (mut k2, v2) = (k.clone(), v.clone());
        for kh in 0..kv_heads {
            k2[kh * n * d + 2 * ps * d] += 1.0; // diverge at token 8
        }
        let h2 = prefix_hash_chain(&k2, &v2, kv_heads, n, d, 12, ps);
        assert_eq!(h2[..2], hashes[..2]);
        assert_ne!(h2[2], hashes[2]);
        let (pages, tokens) = cache.lookup(&pool, kv_heads, &h2, &k2, &v2, n).expect("hit");
        assert_eq!(tokens, 8);
        assert_eq!(pages.len(), kv_heads);
        assert_eq!(pages[0], vec![chains[0].page_id(0), chains[0].page_id(1)]);
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.shared_pages, 4);
        // an unrelated prompt misses
        let (k3, v3) = prompt(8, kv_heads, n, d);
        let h3 = prefix_hash_chain(&k3, &v3, kv_heads, n, d, 12, ps);
        assert!(cache.lookup(&pool, kv_heads, &h3, &k3, &v3, n).is_none());
        assert_eq!(cache.stats.misses, 1);
        // kv-head layout mismatch can never share pages
        assert_eq!(cache.peek(&pool, 1, &hashes, &k, &v, n), 0);
        cache.release_all(&mut pool);
        for mut c in chains {
            c.release(&mut pool, false);
        }
        assert_eq!(pool.in_use(), 0);
        assert!(pool.conserved());
    }

    #[test]
    fn prefix_cache_forced_hash_collision_rejected() {
        // negative path: forge the hash chain so two different contents
        // collide — the equality double-check must reject the hit
        let (kv_heads, n, d, ps) = (1, 4, 2, 4);
        let mut pool = PagePool::new(ps, d, 8);
        let mut cache = PrefixCache::new();
        let (ka, va) = prompt(1, kv_heads, n, d);
        let chains = fill_chains(&mut pool, kv_heads, &ka, &va, n, 4);
        let forged = vec![0xDEAD_BEEFu64];
        cache.register(&mut pool, &forged, &chains);
        let (kb, vb) = prompt(2, kv_heads, n, d);
        assert!(
            cache.lookup(&pool, kv_heads, &forged, &kb, &vb, n).is_none(),
            "colliding hash with different content must miss"
        );
        assert_eq!(cache.stats.collisions, 1);
        assert_eq!(cache.peek(&pool, kv_heads, &forged, &kb, &vb, n), 0);
        // the true content still hits
        assert!(cache.lookup(&pool, kv_heads, &forged, &ka, &va, n).is_some());
        cache.release_all(&mut pool);
        for mut c in chains {
            c.release(&mut pool, false);
        }
        assert!(pool.conserved());
    }

    #[test]
    fn prefix_cache_no_sharing_below_page_granularity() {
        // negative path: prompts identical for 1.5 pages share exactly
        // one page — the partial second page is never shared
        let (kv_heads, n, d, ps) = (1, 8, 2, 4);
        let mut pool = PagePool::new(ps, d, 16);
        let mut cache = PrefixCache::new();
        let (k, v) = prompt(3, kv_heads, n, d);
        let chains = fill_chains(&mut pool, kv_heads, &k, &v, n, 8);
        let hashes = prefix_hash_chain(&k, &v, kv_heads, n, d, 8, ps);
        cache.register(&mut pool, &hashes, &chains);
        // identical through token 5 (mid page 1), diverging at token 6
        let (mut k2, v2) = (k.clone(), v.clone());
        k2[6 * d] += 1.0;
        // an unaligned prompt of 6 tokens keys only its single full page
        let h2 = prefix_hash_chain(&k2, &v2, kv_heads, n, d, 6, ps);
        assert_eq!(h2.len(), 1, "partial pages are never keyed");
        let (pages, tokens) = cache.lookup(&pool, kv_heads, &h2, &k2, &v2, n).expect("hit");
        assert_eq!(tokens, ps, "sharing stops at the page boundary");
        assert_eq!(pages[0].len(), 1);
        cache.release_all(&mut pool);
        for mut c in chains {
            c.release(&mut pool, false);
        }
        assert!(pool.conserved());
    }

    #[test]
    fn prefix_cache_reclaim_frees_lru_under_pressure() {
        let (kv_heads, n, d, ps) = (1, 8, 2, 16);
        let mut pool = PagePool::new(ps, d, 16);
        let mut cache = PrefixCache::new();
        // two donors, the second used more recently
        let (ka, va) = prompt(10, kv_heads, n, d);
        let (kb, vb) = prompt(11, kv_heads, n, d);
        let mut ca = fill_chains(&mut pool, kv_heads, &ka, &va, n, 8);
        let mut cb = fill_chains(&mut pool, kv_heads, &kb, &vb, n, 8);
        let ha = prefix_hash_chain(&ka, &va, kv_heads, n, d, 8, ps);
        let hb = prefix_hash_chain(&kb, &vb, kv_heads, n, d, 8, ps);
        cache.register(&mut pool, &ha, &ca);
        cache.register(&mut pool, &hb, &cb);
        // donors retire; only the cache keeps the pages alive
        ca[0].release(&mut pool, false);
        cb[0].release(&mut pool, false);
        assert_eq!(pool.in_use(), 2);
        let _ = cache.lookup(&pool, kv_heads, &hb, &kb, &vb, n); // touch b
        let freed = cache.reclaim(&mut pool, 1);
        assert_eq!(freed, 1);
        assert_eq!(cache.len(), 1, "only the LRU entry (a) was dropped");
        assert!(cache.lookup(&pool, kv_heads, &hb, &kb, &vb, n).is_some());
        assert!(cache.lookup(&pool, kv_heads, &ha, &ka, &va, n).is_none());
        // reclaiming more than exists drains the cache and reports less
        let freed = cache.reclaim(&mut pool, 100);
        assert_eq!(freed, 1);
        assert!(cache.is_empty());
        assert_eq!(pool.in_use(), 0);
        assert!(pool.conserved());
    }

    #[test]
    fn prop_pool_conservation_random_chain_interleavings() {
        // satellite: both conservation equalities hold after any
        // interleaving of append / truncate / share-attach / CoW /
        // release(free) / release(evict) across multiple chains sharing
        // one pool — the chain-level half of the leak detector (the
        // batcher-level admit/step/speculate/preempt/retire half lives
        // in session.rs).  Shared pages make `held pages == in_use`
        // a *distinct*-page identity, and per-page refcounts must equal
        // the number of chains referencing the page.
        crate::util::prop::check(
            "pool-conservation-chains",
            crate::util::prop::PropConfig { cases: 24, base_seed: 0xC0DE },
            |rng| {
                let d = 2;
                let ps = 1 + rng.range(1, 4) as usize;
                let max_pages = 4 + rng.range(0, 12) as usize;
                let mut pool = PagePool::new(ps, d, max_pages);
                let mut chains: Vec<PagedKv> = (0..4).map(|_| PagedKv::new()).collect();
                for _ in 0..300 {
                    let c = rng.range(0, chains.len() as i64) as usize;
                    match rng.range(0, 6) {
                        0 | 1 => {
                            // append (may fail on exhaustion, and may
                            // CoW a shared tail page — neither may
                            // break conservation)
                            let _ = chains[c].append(&mut pool, &[1.0; 2], &[2.0; 2]);
                        }
                        2 => {
                            let new_len =
                                rng.range(0, chains[c].len() as i64 + 1) as usize;
                            chains[c].truncate(&mut pool, new_len);
                        }
                        3 => {
                            // share: attach a random aligned prefix of
                            // a donor chain onto an empty chain
                            let donor = rng.range(0, chains.len() as i64) as usize;
                            let full = chains[donor].len() / ps;
                            if donor != c && chains[c].is_empty() && full > 0 {
                                let take = 1 + rng.range(0, full as i64) as usize;
                                let ids: Vec<PageId> =
                                    (0..take).map(|p| chains[donor].page_id(p)).collect();
                                chains[c].attach_shared(&mut pool, &ids);
                            }
                        }
                        _ => {
                            let evict = rng.f64() < 0.5;
                            chains[c].release(&mut pool, evict);
                        }
                    }
                    if !pool.conserved() {
                        return Err(format!(
                            "conservation broken: allocs {} retains {} vs frees {} evictions {} shared_releases {} in_use {} live_refs {}",
                            pool.stats.allocs,
                            pool.stats.retains,
                            pool.stats.frees,
                            pool.stats.evictions,
                            pool.stats.shared_releases,
                            pool.in_use(),
                            pool.live_refs(),
                        ));
                    }
                    let mut ref_counts: HashMap<PageId, u32> = HashMap::new();
                    for ch in &chains {
                        for p in 0..ch.n_pages() {
                            *ref_counts.entry(ch.page_id(p)).or_insert(0) += 1;
                        }
                    }
                    if ref_counts.len() != pool.in_use() {
                        return Err(format!(
                            "distinct held {} != pool in_use {}",
                            ref_counts.len(),
                            pool.in_use()
                        ));
                    }
                    for (&id, &want) in &ref_counts {
                        if pool.ref_count(id) != want {
                            return Err(format!(
                                "page {id}: refcount {} != holders {want}",
                                pool.ref_count(id)
                            ));
                        }
                    }
                }
                for ch in &mut chains {
                    ch.release(&mut pool, false);
                }
                if pool.in_use() != 0 || pool.live_refs() != 0 || !pool.conserved() {
                    return Err("final drain leaked pages or references".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_beyond_len_panics() {
        let mut pool = PagePool::new(2, 2, 2);
        let mut kv = PagedKv::new();
        assert!(kv.append(&mut pool, &row(0.0, 2), &row(0.0, 2)));
        kv.truncate(&mut pool, 2);
    }

    #[test]
    fn eviction_accounting_separate_from_frees() {
        let mut pool = PagePool::new(2, 2, 4);
        let mut kv = PagedKv::new();
        for _ in 0..4 {
            assert!(kv.append(&mut pool, &row(0.0, 2), &row(0.0, 2)));
        }
        kv.release(&mut pool, true);
        assert_eq!(pool.stats.evictions, 2);
        assert_eq!(pool.stats.frees, 0);
        assert_eq!(pool.stats.peak_in_use, 2);
        assert_eq!(pool.in_use(), 0);
    }
}
