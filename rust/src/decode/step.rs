//! Single-row flash-decode kernel.
//!
//! Computes attention for one new query row against a paged KV cache
//! with the same online-softmax recurrence as `attention::flash`
//! (Alg. 1 lines 25–26), but tiled by cache page instead of by Bc
//! key block.  Pages the [`IncrementalMaskView`] classifies as fully
//! masked are skipped before their K/V memory is touched — the decode
//! analogue of the prefill kernel's Eq. 4 tile skip, so KV-cache reads
//! (the decode bottleneck) scale with *visible* context, not total
//! context.
//!
//! Exactness mirrors §4.4: skipped pages contribute only `exp(-inf)=0`
//! terms, so `skip=true` and `skip=false` are bitwise-identical
//! (asserted in the tests below).

// lint: allow-file(hot-path-panic:index) — page-local indices are
// bounded by the pool's page geometry (`ps`, `d` fixed at pool build)
// and `t`-derived page counts; the skip/no-skip bitwise-equality tests
// cover every indexing path against the dense reference.

use super::kvcache::{PagePool, PagedKv};
use crate::attention::gemm;
use crate::mask::{BlockClass, FlashMask, IncrementalMaskView};

const NEG_INF: f32 = f32::NEG_INFINITY;

/// Work counters for the decode path (the per-page census the bench
/// and serving reports aggregate).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DecodeStats {
    /// Kernel rows evaluated.  Sequential decode: one per
    /// `(sequence, head, token)`.  Speculative decode: every drafted
    /// row of every verify pass counts (rejected rows and fallback
    /// steps included), so this measures work performed, not tokens
    /// committed — compare `accepted` for useful speculative output.
    pub steps: u64,
    /// Cache pages considered across all steps.
    pub pages_total: u64,
    /// Pages skipped without touching their K/V memory.
    pub pages_skipped: u64,
    /// Pages computed with the element-wise interval mask applied.
    pub pages_partial: u64,
    /// Pages computed mask-free.
    pub pages_unmasked: u64,
    /// Multiply-accumulate count (2 per MAC = FLOPs).
    pub macs: u64,
    /// Element-wise mask evaluations on partial pages.
    pub mask_evals: u64,
    /// Speculative verify passes executed (one per draft tree).
    pub spec_passes: u64,
    /// Draft tokens proposed and run through a verify pass.
    pub drafted: u64,
    /// Draft tokens accepted and committed to the cache.
    pub accepted: u64,
    /// Verify passes that accepted nothing and fell back to one
    /// sequential decode step.
    pub fallback_steps: u64,
    /// Decode plans (incremental mask views + page schedules) built —
    /// one per session construction.  Compared against `steps` this
    /// proves a session builds its plan once and reuses it for every
    /// decoded token (the bench's plan-reuse column).
    pub plans_built: u64,
    /// Multiply-accumulates worth of K/V rows materialized at prefill
    /// (`d` per row actually written into the cache).  Rows attached
    /// from the prefix cache cost nothing here — the shared-prefix
    /// bench asserts this drops by the sharing factor.
    pub prefill_macs: u64,
}

impl DecodeStats {
    /// Element-wise sum.  Every field is an additive counter (no
    /// maxima, no ratios), so `merge` is commutative and associative:
    /// per-head, per-session and per-batch aggregates can be folded in
    /// any order and agree — asserted in the tests below.
    pub fn merge(&mut self, other: &DecodeStats) {
        self.steps += other.steps;
        self.pages_total += other.pages_total;
        self.pages_skipped += other.pages_skipped;
        self.pages_partial += other.pages_partial;
        self.pages_unmasked += other.pages_unmasked;
        self.macs += other.macs;
        self.mask_evals += other.mask_evals;
        self.spec_passes += other.spec_passes;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.fallback_steps += other.fallback_steps;
        self.plans_built += other.plans_built;
        self.prefill_macs += other.prefill_macs;
    }

    /// Fraction of cache pages skipped; 0 when no pages were visited
    /// (empty run, or a merge of empty stats), never NaN.
    pub fn skip_fraction(&self) -> f64 {
        if self.pages_total == 0 {
            0.0
        } else {
            self.pages_skipped as f64 / self.pages_total as f64
        }
    }

    /// Fraction of drafted tokens accepted; 0 when nothing was drafted
    /// (sequential decode), never NaN.
    pub fn accept_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Accumulate these counters into the global telemetry registry
    /// under the `decode.*` names (DESIGN.md §Telemetry).  Called once
    /// per retired sequence by the continuous batcher — never from the
    /// per-token hot loop.
    pub fn publish(&self) {
        use crate::telemetry::names as tn;
        let r = crate::telemetry::metrics::global();
        r.add(tn::DECODE_STEPS, self.steps);
        r.add(tn::DECODE_PAGES_TOTAL, self.pages_total);
        r.add(tn::DECODE_PAGES_SKIPPED, self.pages_skipped);
        r.add(tn::DECODE_PAGES_PARTIAL, self.pages_partial);
        r.add(tn::DECODE_PAGES_UNMASKED, self.pages_unmasked);
        r.add(tn::DECODE_MACS, self.macs);
        r.add(tn::DECODE_MASK_EVALS, self.mask_evals);
        r.add(tn::DECODE_SPEC_PASSES, self.spec_passes);
        r.add(tn::DECODE_DRAFTED, self.drafted);
        r.add(tn::DECODE_ACCEPTED, self.accepted);
        r.add(tn::DECODE_FALLBACK_STEPS, self.fallback_steps);
        r.add(tn::DECODE_PLANS_BUILT, self.plans_built);
        r.add(tn::DECODE_PREFILL_MACS, self.prefill_macs);
    }
}

/// Attention for decode row `t` (already appended: `cache.len() == t+1`)
/// over one head's paged cache.  Returns the `[d]` output row.
///
/// Single-query-head convenience over [`decode_step_group`] — the MHA
/// case, where every query head owns its KV head.
///
/// Deprecated shim over `attention::api` (see
/// [`api::Backend::decode_step`](crate::attention::api::Backend::decode_step)).
#[deprecated(
    note = "use attention::api — CpuBackend::decode_step with a DecodeStep argument pack (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn decode_step(
    q_row: &[f32],
    cache: &PagedKv,
    pool: &PagePool,
    mask: &FlashMask,
    view: &IncrementalMaskView,
    t: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    step_shim(q_row, 1, cache, pool, mask, view, t, scale, skip, stats, scratch)
}

/// Attention for decode row `t` for a whole query *group* sharing one
/// KV head's paged cache (GQA).  `q_rows` is `[group, d]`; returns the
/// `[group, d]` output rows in query-head order.
///
/// The Eq. 4 page classification and the per-column interval test run
/// **once per page** and are reused by every query row in the group —
/// the skip decision is a property of the KV columns alone (§4.1), so
/// `pages_total` / `pages_skipped` / `mask_evals` count KV-head work:
/// at group size `g` the classification cost and the skip-stat
/// denominators drop by `g` while per-query-row MACs are unchanged.
/// Each loaded K/V row also serves all `g` dot products, so cache
/// memory traffic (the decode bottleneck) drops by `g` too.
///
/// `scratch` is a caller-owned buffer holding the score rows and the
/// per-row softmax state (grown to `group * (page_size + 2)` on first
/// use) so the per-token hot loop performs no allocation beyond the
/// returned rows.
///
/// `skip=false` is the dense-cache baseline: every page is visited and
/// element-masked, the behaviour of a decoder that keeps no mask
/// structure — the comparison `bench_decode` measures.
///
/// Deprecated shim over `attention::api` (see
/// [`api::Backend::decode_step`](crate::attention::api::Backend::decode_step)).
#[deprecated(
    note = "use attention::api — CpuBackend::decode_step with a DecodeStep argument pack (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn decode_step_group(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    mask: &FlashMask,
    view: &IncrementalMaskView,
    t: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    step_shim(q_rows, group, cache, pool, mask, view, t, scale, skip, stats, scratch)
}

/// Shared body of the two deprecated step entry points.
#[allow(clippy::too_many_arguments)]
fn step_shim(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    mask: &FlashMask,
    view: &IncrementalMaskView,
    t: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    use crate::attention::api::{Backend, CpuBackend, DecodeStep};
    CpuBackend
        .decode_step(
            DecodeStep { q_rows, group, cache, pool, mask, view, t, scale, skip },
            stats,
            scratch,
        )
        // lint: allow(hot-path-panic:expect) — deprecated shim: the backend revalidates the pack; the api path returns the typed error instead
        .expect("decode_step: CPU backend rejected a validated step")
}

/// The decode-step kernel body (see [`decode_step_group`] for the
/// contract) — called through [`crate::attention::api::CpuBackend`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_step_group_impl(
    q_rows: &[f32],
    group: usize,
    cache: &PagedKv,
    pool: &PagePool,
    mask: &FlashMask,
    view: &IncrementalMaskView,
    t: usize,
    scale: f32,
    skip: bool,
    stats: &mut DecodeStats,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    let _sp = crate::telemetry::trace::span(crate::telemetry::names::DECODE_STEP);
    let d = pool.d();
    let ps = pool.page_size();
    debug_assert!(group >= 1);
    debug_assert_eq!(q_rows.len(), group * d);
    debug_assert_eq!(view.page_size(), ps);
    debug_assert_eq!(cache.len(), t + 1, "append the row's K/V before stepping");

    let mut o = vec![0f32; group * d];
    // scratch carries the score rows plus the per-row softmax state, so
    // the only per-token allocation is the returned rows
    if scratch.len() < group * (ps + 2) {
        scratch.resize(group * (ps + 2), 0.0);
    }
    let (s, run) = scratch.split_at_mut(group * ps);
    let (m_run, l_run) = run.split_at_mut(group);
    m_run[..group].fill(NEG_INF);
    l_run[..group].fill(0.0);

    // interval-driven page schedule: pages outside [p_lo, p_hi) are
    // fully masked (the range scan classified them), so the hot loop
    // never visits them; their census is charged in bulk.  Counting
    // happens once per KV head, not per query head, exactly as before.
    let np = cache.n_pages();
    let (p_lo, p_hi) = if skip { view.visit_range(mask, t, np) } else { (0, np) };
    stats.pages_total += np as u64;
    stats.pages_skipped += (p_lo + (np - p_hi)) as u64;

    for p in p_lo..p_hi {
        let class = if skip {
            view.classify_page(mask, t, p)
        } else {
            BlockClass::PartiallyMasked
        };
        if class == BlockClass::FullyMasked {
            stats.pages_skipped += 1; // interior hole (non-contiguous mask)
            continue;
        }
        let cols = cache.page_cols(p, ps);
        let col0 = p * ps;
        let kp = pool.page_k(cache.page_id(p));

        // s_g = q_g · K_pᵀ * scale, column-outer so each loaded K row
        // serves the whole query group, lane-parallel along d
        for c in 0..cols {
            let krow = &kp[c * d..(c + 1) * d];
            for g in 0..group {
                let q_row = &q_rows[g * d..(g + 1) * d];
                s[g * ps + c] = gemm::dot(q_row, krow) * scale;
            }
        }
        stats.macs += (group * cols * d) as u64;

        if class == BlockClass::PartiallyMasked {
            // one interval test per column, applied to every group row
            for c in 0..cols {
                if !view.visible(mask, t, col0 + c) {
                    for g in 0..group {
                        s[g * ps + c] = NEG_INF;
                    }
                }
            }
            stats.mask_evals += cols as u64;
            stats.pages_partial += 1;
        } else {
            stats.pages_unmasked += 1;
        }

        // online softmax update (Alg. 1 lines 25-26 with Br = 1),
        // independently per query row
        let vp = pool.page_v(cache.page_id(p));
        for g in 0..group {
            let sg = &s[g * ps..g * ps + cols];
            let mut page_max = NEG_INF;
            for &sv in sg {
                page_max = page_max.max(sv);
            }
            let m_new = m_run[g].max(page_max);
            let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
            let a = if m_run[g].is_finite() { (m_run[g] - m_safe).exp() } else { 0.0 };
            let o_row = &mut o[g * d..(g + 1) * d];
            for ov in o_row.iter_mut() {
                *ov *= a;
            }
            let mut page_sum = 0f32;
            for (c, &sv) in sg.iter().enumerate() {
                let pexp = (sv - m_safe).exp(); // exp(-inf) == 0 for masked
                page_sum += pexp;
                for dd in 0..d {
                    o_row[dd] += pexp * vp[c * d + dd];
                }
            }
            l_run[g] = a * l_run[g] + page_sum;
            m_run[g] = m_new;
        }
        stats.macs += (group * cols * d) as u64;
    }

    stats.steps += group as u64; // kernel rows evaluated
    for g in 0..group {
        if l_run[g] > 0.0 {
            let inv = 1.0 / l_run[g];
            for ov in o[g * d..(g + 1) * d].iter_mut() {
                *ov *= inv;
            }
        } // fully-masked row: output stays 0, like the prefill kernel
    }
    o
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::{flash, AttnConfig};
    use crate::mask::{builders, BlockTable};
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }

    /// Decode every row of a sequence through the paged cache and
    /// return the full [n, d] output.
    fn decode_all(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        mask: &crate::mask::FlashMask,
        ps: usize,
        skip: bool,
        stats: &mut DecodeStats,
    ) -> Vec<f32> {
        let mut pool = PagePool::new(ps, d, n.div_ceil(ps) + 1);
        let mut cache = PagedKv::new();
        let view = IncrementalMaskView::new(mask, ps);
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = Vec::with_capacity(n * d);
        let mut scratch = Vec::new();
        for t in 0..n {
            assert!(cache.append(&mut pool, &k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]));
            let o = decode_step(
                &q[t * d..(t + 1) * d],
                &cache,
                &pool,
                mask,
                &view,
                t,
                scale,
                skip,
                stats,
                &mut scratch,
            );
            out.extend(o);
        }
        out
    }

    #[test]
    fn decode_matches_prefill_acceptance_masks() {
        // the ISSUE's correctness oracle: decode-step outputs must match
        // full-sequence prefill row-for-row (max abs diff < 1e-4) for
        // causal, sliding-window, causal-document and random-eviction
        let (n, d, ps) = (96, 8, 16);
        let mut rng = Rng::new(11);
        let (q, k, v) = (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng));
        let masks = [
            ("causal", builders::causal(n)),
            ("sliding_window", builders::sliding_window(n, 12)),
            ("causal_document", builders::causal_document(n, &[40, 31, 25])),
            ("random_eviction", builders::random_eviction(n, &mut rng)),
            ("qk_sparse", builders::qk_sparse(n, (30, 38), &[5, 50])),
        ];
        for (name, mask) in &masks {
            let cfg = AttnConfig::new(32, 32, d);
            let table = BlockTable::build(mask, cfg.bc);
            let (want, _) = flash::flashmask_forward(&q, &k, &v, n, d, mask, &table, cfg, true);
            let mut stats = DecodeStats::default();
            let got = decode_all(&q, &k, &v, n, d, mask, ps, true, &mut stats);
            for i in 0..n * d {
                assert!(
                    (got[i] - want.o[i]).abs() < 1e-4,
                    "{name} row {} dim {}: {} vs {}",
                    i / d,
                    i % d,
                    got[i],
                    want.o[i]
                );
            }
            assert_eq!(stats.steps, n as u64, "{name}");
        }
    }

    #[test]
    fn skip_is_bitwise_noop_on_decode_path() {
        let (n, d, ps) = (64, 8, 8);
        let mut rng = Rng::new(12);
        let (q, k, v) = (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng));
        for mask in [
            builders::sliding_window(n, 8),
            builders::causal_document(n, &[20, 24, 20]),
            builders::random_eviction(n, &mut rng),
        ] {
            let mut s_skip = DecodeStats::default();
            let mut s_dense = DecodeStats::default();
            let a = decode_all(&q, &k, &v, n, d, &mask, ps, true, &mut s_skip);
            let b = decode_all(&q, &k, &v, n, d, &mask, ps, false, &mut s_dense);
            assert_eq!(a, b, "skip changed decode outputs");
            assert!(s_skip.pages_skipped > 0, "nothing skipped");
            assert_eq!(s_dense.pages_skipped, 0);
            assert!(s_skip.macs < s_dense.macs, "skip did not reduce work");
            // bulk range accounting must preserve the census semantics:
            // both modes consider every cached page of every step
            assert_eq!(s_skip.pages_total, s_dense.pages_total);
            assert_eq!(
                s_skip.pages_skipped + s_skip.pages_partial + s_skip.pages_unmasked,
                s_skip.pages_total
            );
        }
    }

    #[test]
    fn fully_masked_row_outputs_zero() {
        // qk_sparse drops query rows entirely: decode must produce the
        // same all-zero rows the prefill kernel produces
        let (n, d, ps) = (32, 4, 8);
        let mut rng = Rng::new(13);
        let (q, k, v) = (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng));
        let mask = builders::qk_sparse(n, (10, 14), &[]);
        let mut stats = DecodeStats::default();
        let out = decode_all(&q, &k, &v, n, d, &mask, ps, true, &mut stats);
        for t in 10..14 {
            assert!(out[t * d..(t + 1) * d].iter().all(|&x| x == 0.0), "row {t} not zero");
        }
        assert!(out[9 * d..10 * d].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn grouped_step_matches_per_row_bitwise() {
        // a query group over one shared KV cache must equal `group`
        // separate single-row steps bitwise, while the page census is
        // charged once (per KV head) instead of once per query row
        let (n, d, ps, group) = (48, 4, 8, 3);
        let mut rng = Rng::new(15);
        let q = rand_vec(group * n * d, &mut rng); // [group, n, d]
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let mask = builders::sliding_window(n, 10);
        let view = IncrementalMaskView::new(&mask, ps);
        let mut pool = PagePool::new(ps, d, n.div_ceil(ps) + 1);
        let mut cache = PagedKv::new();
        let scale = 1.0 / (d as f32).sqrt();
        let mut g_stats = DecodeStats::default();
        let mut r_stats = DecodeStats::default();
        let mut scratch = Vec::new();
        for t in 0..n {
            assert!(cache.append(&mut pool, &k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]));
            let mut q_rows = Vec::with_capacity(group * d);
            for g in 0..group {
                let base = g * n * d + t * d;
                q_rows.extend_from_slice(&q[base..base + d]);
            }
            let got = decode_step_group(
                &q_rows, group, &cache, &pool, &mask, &view, t, scale, true, &mut g_stats,
                &mut scratch,
            );
            for g in 0..group {
                let want = decode_step(
                    &q_rows[g * d..(g + 1) * d],
                    &cache,
                    &pool,
                    &mask,
                    &view,
                    t,
                    scale,
                    true,
                    &mut r_stats,
                    &mut scratch,
                );
                assert_eq!(&got[g * d..(g + 1) * d], &want[..], "t={t} g={g}");
            }
        }
        // per-KV-head accounting: the group visits each page once where
        // the per-row loop visits it `group` times; compute is unchanged
        assert_eq!(g_stats.pages_total * group as u64, r_stats.pages_total);
        assert_eq!(g_stats.pages_skipped * group as u64, r_stats.pages_skipped);
        assert_eq!(g_stats.mask_evals * group as u64, r_stats.mask_evals);
        assert_eq!(g_stats.macs, r_stats.macs);
        assert_eq!(g_stats.steps, r_stats.steps); // rows evaluated
        assert!(g_stats.pages_skipped > 0, "window mask should skip pages");
    }

    #[test]
    fn stats_skip_fraction_guards_empty_runs() {
        // no pages visited: 0.0, not NaN — and merging empties keeps it
        let mut s = DecodeStats::default();
        assert_eq!(s.skip_fraction(), 0.0);
        assert_eq!(s.accept_rate(), 0.0);
        s.merge(&DecodeStats::default());
        assert_eq!(s.skip_fraction(), 0.0);
        assert!(!s.skip_fraction().is_nan());
        // and a real census still divides correctly
        s.pages_total = 4;
        s.pages_skipped = 1;
        assert_eq!(s.skip_fraction(), 0.25);
    }

    fn arbitrary_stats(seed: u64) -> DecodeStats {
        let mut rng = Rng::new(seed);
        let mut r = || rng.range(0, 1000) as u64;
        DecodeStats {
            steps: r(),
            pages_total: r(),
            pages_skipped: r(),
            pages_partial: r(),
            pages_unmasked: r(),
            macs: r(),
            mask_evals: r(),
            spec_passes: r(),
            drafted: r(),
            accepted: r(),
            fallback_steps: r(),
            plans_built: r(),
            prefill_macs: r(),
        }
    }

    fn merged(parts: &[&DecodeStats]) -> DecodeStats {
        let mut out = DecodeStats::default();
        for p in parts {
            out.merge(p);
        }
        out
    }

    #[test]
    fn stats_merge_commutative_associative() {
        let (a, b, c) = (arbitrary_stats(1), arbitrary_stats(2), arbitrary_stats(3));
        // commutative
        assert_eq!(merged(&[&a, &b]), merged(&[&b, &a]));
        // associative: (a+b)+c == a+(b+c)
        let ab_c = merged(&[&merged(&[&a, &b]), &c]);
        let a_bc = merged(&[&a, &merged(&[&b, &c])]);
        assert_eq!(ab_c, a_bc);
        // identity
        assert_eq!(merged(&[&a, &DecodeStats::default()]), a);
    }

    #[test]
    fn sliding_window_page_skips_grow_with_context() {
        // the decode win: skipped pages per step grows as the window
        // slides past old pages
        let (n, d, ps) = (128, 4, 16);
        let mut rng = Rng::new(14);
        let (q, k, v) = (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng));
        let mask = builders::sliding_window(n, 16);
        let mut stats = DecodeStats::default();
        decode_all(&q, &k, &v, n, d, &mask, ps, true, &mut stats);
        // per step at most 2 pages are ever live (window 16, page 16)
        let visited = stats.pages_total - stats.pages_skipped;
        assert!(visited <= 2 * n as u64, "visited {visited}");
        assert!(stats.skip_fraction() > 0.5, "skip fraction {}", stats.skip_fraction());
    }
}
