//! Autoregressive decode subsystem: paged KV cache + incremental
//! FlashMask + continuous batching.
//!
//! The serving substrate in [`crate::server`] is prefill-only: every
//! request carries full Q/K/V and batches must share `(heads, n, d)`.
//! Real LLM serving is dominated by token-by-token *decode* against a
//! KV cache.  This module provides that path, built on the same paper
//! machinery as prefill:
//!
//! * [`kvcache`] — fixed-size KV pages per sequence drawn from a global
//!   [`PagePool`] with eviction accounting (the vLLM PagedAttention
//!   layout, sized to the mask skip granule).
//! * [`step`] — the single-row flash-decode kernel: online softmax over
//!   cache pages, skipping pages the Eq. 4 classifier
//!   ([`crate::mask::IncrementalMaskView`]) proves fully masked for the
//!   current row — sliding-window, document and eviction masks never
//!   touch dead pages.
//! * [`session`] — [`DecodeSession`] (one sequence's caches + cursor)
//!   and [`ContinuousBatcher`]: admit waiting sequences, step all
//!   active ones each iteration, retire finished ones; sequences of
//!   different lengths decode side by side, with preemption (page
//!   eviction + requeue) under pool pressure.
//! * [`spec`] — speculative decoding: a [`DraftProposer`] proposes a
//!   token tree, [`spec::verify_rows`] scores every drafted row in one
//!   pass over the cache pages under a
//!   [`crate::mask::builders::tree_mask`], and the session commits the
//!   longest greedily-accepted root path, rolling the cache back past
//!   the rejected remainder.  Greedy speculative decode is
//!   token-identical to sequential decode.
//!
//! Correctness oracle: decode-step outputs equal the full-sequence
//! `attention::flash` prefill on the same mask, row for row (the
//! decode analogue of the paper's §4.4 exactness claim); sequential
//! decode, speculative decode and prefill are pinned to each other in
//! `tests/decode_oracle.rs`.

//!
//! Head layouts: every piece above is layout-aware (GQA/MQA).  A
//! [`DecodeRequest`] carries a [`HeadLayout`]; the session holds one
//! page chain per *KV* head (cache residency scales with `kv_heads`,
//! not `q_heads`), and the step/verify kernels score a KV head's whole
//! query group in one pass, classifying pages once per KV head
//! (DESIGN.md §Head layouts).

pub mod kvcache;
pub mod session;
pub mod spec;
pub mod step;

pub use crate::attention::HeadLayout;
pub use kvcache::{
    prefix_hash_chain, PageId, PagePool, PagedKv, PoolStats, PrefixCache, PrefixStats,
};
pub use session::{
    BatcherConfig, BatcherReport, ContinuousBatcher, DecodeRequest, DecodeResponse,
    DecodeSession, StepOutcome,
};
#[allow(deprecated)] // verify_rows{,_group} re-exported as migration shims
pub use spec::{
    greedy_accept_path, token_rows, verify_rows, verify_rows_group, DraftKind, DraftProposer,
    DraftTree, OracleProposer, SelfDraftProposer, SpecBudget, SpecPolicy,
};
#[allow(deprecated)] // decode_step{,_group} re-exported as migration shims
pub use step::{decode_step, decode_step_group, DecodeStats};
