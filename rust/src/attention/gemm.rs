//! Small dense matmul microkernels for the blocked attention engine.
//!
//! Row-major f32.  These are the hot inner loops of the simulator; they
//! use 8-lane dot reductions and 2-row-unrolled axpy so LLVM vectorizes
//! (see EXPERIMENTS.md §Perf for the measured iteration history).

const LANES: usize = 8;

/// 8-lane dot product: independent partial sums let LLVM vectorize the
/// reduction (plain `s += a*b` is a serial dependency chain).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / LANES;
    let mut acc = [0f32; LANES];
    for c in 0..chunks {
        let ac = &a[c * LANES..(c + 1) * LANES];
        let bc = &b[c * LANES..(c + 1) * LANES];
        for l in 0..LANES {
            acc[l] += ac[l] * bc[l];
        }
    }
    let mut s = acc.iter().sum::<f32>();
    for kk in chunks * LANES..a.len() {
        s += a[kk] * b[kk];
    }
    s
}

/// `out[m,n] += a[m,k] @ b[n,k]^T` — the S = Q K^T shape.
pub fn matmul_nt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            oi[j] += dot(ai, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `out[m,n] += a[m,k] @ b[k,n]` — the O = P V shape.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        // 2-row unrolled axpy: halves the number of passes over `oi`
        let mut kk = 0;
        while kk + 2 <= k {
            let (a0, a1) = (ai[kk], ai[kk + 1]);
            if a0 == 0.0 && a1 == 0.0 {
                kk += 2; // masked probabilities are exactly zero
                continue;
            }
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            for j in 0..n {
                oi[j] += a0 * b0[j] + a1 * b1[j];
            }
            kk += 2;
        }
        if kk < k {
            let av = ai[kk];
            if av != 0.0 {
                let bk = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    oi[j] += av * bk[j];
                }
            }
        }
    }
}

/// `out[k,n] += a[m,k]^T @ b[m,n]` — the dV = P^T dO / dK = dS^T Q shape.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    // process two source rows per pass so each out-row sees fused updates
    let mut i = 0;
    while i < m {
        let pair = (i + 1 < m) as usize;
        let a0 = &a[i * k..(i + 1) * k];
        let b0 = &b[i * n..(i + 1) * n];
        let (a1, b1) = if pair == 1 {
            (&a[(i + 1) * k..(i + 2) * k], &b[(i + 1) * n..(i + 2) * n])
        } else {
            (a0, b0)
        };
        for kk in 0..k {
            let (x0, x1) = (a0[kk], if pair == 1 { a1[kk] } else { 0.0 });
            if x0 == 0.0 && x1 == 0.0 {
                continue; // masked probabilities are exactly zero
            }
            let ok = &mut out[kk * n..(kk + 1) * n];
            for j in 0..n {
                ok[j] += x0 * b0[j] + x1 * b1[j];
            }
        }
        i += 1 + pair;
    }
}

/// Scale rows of `x[m,n]` by `alpha[m]` in place.
pub fn scale_rows(x: &mut [f32], alpha: &[f32], m: usize, n: usize) {
    for i in 0..m {
        let a = alpha[i];
        if a == 1.0 {
            continue;
        }
        for v in &mut x[i * n..(i + 1) * n] {
            *v *= a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    out[i * n + j] += a[i * k + kk] * b[j * k + kk];
                }
            }
        }
        out
    }

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn nt_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 5, 7), (8, 16, 8), (1, 1, 1), (5, 4, 6)] {
            let a = rand(m * k, &mut rng);
            let b = rand(n * k, &mut rng);
            let mut out = vec![0.0; m * n];
            matmul_nt_acc(&a, &b, m, k, n, &mut out);
            let want = naive_nt(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn nn_matches_naive() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (4, 6, 5);
        let a = rand(m * k, &mut rng);
        let b = rand(k * n, &mut rng);
        let mut out = vec![0.0; m * n];
        matmul_nn_acc(&a, &b, m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tn_matches_naive() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (6, 4, 5);
        let a = rand(m * k, &mut rng);
        let b = rand(m * n, &mut rng);
        let mut out = vec![0.0; k * n];
        matmul_tn_acc(&a, &b, m, k, n, &mut out);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + kk] * b[i * n + j]).sum();
                assert!((out[kk * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut out = vec![10.0];
        matmul_nt_acc(&a, &b, 1, 2, 1, &mut out);
        assert_eq!(out[0], 10.0 + 11.0);
    }

    #[test]
    fn scale_rows_works() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        scale_rows(&mut x, &[2.0, 0.5], 2, 2);
        assert_eq!(x, vec![2.0, 4.0, 1.5, 2.0]);
    }
}
