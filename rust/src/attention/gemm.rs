//! Dense matmul microkernels for the blocked attention engine.
//!
//! Row-major f32, three tiers (EXPERIMENTS.md §Perf tracks the measured
//! iteration history):
//!
//! 1. **Register-blocked packed kernel** ([`matmul_nt_packed`]) — the
//!    S = Q K^T hot path.  Operands are re-laid out into zero-padded
//!    [`PackedBlock`]s (depth rounded up to the 8-lane width), so the
//!    4×2 register tile streams full SIMD chunks with no remainder
//!    loop, keeps eight independent 8-lane accumulators live (enough
//!    ILP to hide FMA latency), and amortizes every K-row load over
//!    four query rows.  The softmax `scale` is fused into the final
//!    accumulator reduction, removing the separate scaling pass over
//!    the score tile.  [`PackedKt`] packs a whole K head once per
//!    column block; the pack cost is then reused across **every row
//!    block and every query head of a GQA group** (the data-layout
//!    analogue of the classify-once reuse).  The backward pass rides
//!    the same tile through [`matmul_nt_packed_acc`] and the
//!    transposed-operand TN/NN wrappers ([`matmul_tn_packed_acc`],
//!    [`matmul_nn_packed_acc`]): packing an operand with
//!    [`PackedBlock::pack_transposed`] swaps its reduction axis, so
//!    dP = dO·Vᵀ, dV += Pᵀ·dO, dQ += dS·K and dK += dSᵀ·Q are all the
//!    one NT register kernel under different layouts.
//! 2. **Lane-blocked loose kernels** ([`matmul_nt_acc`],
//!    [`matmul_nn_acc`], [`matmul_tn_acc`]) — unpacked fallbacks used
//!    by the baseline engines (and kept as the backward bench's
//!    pre-rebuild reference).  [`dot`] keeps 8
//!    independent partial sums and folds the `len % 8` tail into the
//!    lane accumulators, so shapes like d = 80 stay on the parallel
//!    accumulation path instead of degrading to a serial chain.
//! 3. **Softmax row helpers** ([`row_max`], [`exp_sub_sum`]) — the
//!    online-softmax inner pass as two lane-parallel sweeps instead of
//!    the scalar per-element loop.

// lint: allow-file(hot-path-panic:index) — every index in this file is
// bounded by the pack layout: rows live at `i*kp..(i+1)*kp` with
// `i < rows` and `kp` a multiple of LANES, the register tile loops stop
// at `i + MR <= m` / `j + NR <= n`, and `out.len() == m*n` is checked by
// debug_assert at each entry.  Switching the inner loops to `get` costs
// the bounds-check-elision this microkernel exists for.

pub(crate) const LANES: usize = 8;
/// Register-tile rows (query rows per microkernel invocation).
pub const MR: usize = 4;
/// Register-tile columns (key rows per microkernel invocation).
pub const NR: usize = 2;

/// Fused multiply-add when the target actually has an FMA unit;
/// plain mul+add otherwise (`f32::mul_add` without hardware FMA lowers
/// to a libm call, which would be far slower than the unfused form).
#[inline(always)]
fn fmadd(a: f32, b: f32, acc: f32) -> f32 {
    #[cfg(target_feature = "fma")]
    {
        a.mul_add(b, acc)
    }
    #[cfg(not(target_feature = "fma"))]
    {
        acc + a * b
    }
}

/// The `c`-th full 8-lane chunk of `row` as a fixed-size array ref.
/// The pack/chunk arithmetic guarantees `(c + 1) * LANES <= row.len()`
/// at every call site; if a future caller ever violates that, the
/// kernel degrades to an all-zero chunk (finite, visibly wrong output
/// caught by the oracle suites) instead of aborting a live serve batch.
#[inline(always)]
fn lane_chunk(row: &[f32], c: usize) -> &[f32; LANES] {
    static ZERO_CHUNK: [f32; LANES] = [0.0; LANES];
    debug_assert!((c + 1) * LANES <= row.len());
    row.get(c * LANES..(c + 1) * LANES)
        .and_then(|s| s.try_into().ok())
        .unwrap_or(&ZERO_CHUNK)
}

/// 8-lane dot product: independent partial sums let LLVM vectorize the
/// reduction (plain `s += a*b` is a serial dependency chain).  The
/// remainder elements are folded into distinct lane accumulators —
/// a `len % 8` tail (d = 80, 100, …) costs one extra partial chunk,
/// not a serial scalar loop.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / LANES;
    let mut acc = [0f32; LANES];
    for c in 0..chunks {
        let ac = lane_chunk(a, c);
        let bc = lane_chunk(b, c);
        for l in 0..LANES {
            acc[l] = fmadd(ac[l], bc[l], acc[l]);
        }
    }
    // tail: fold into the lane accumulators (tail length < LANES, so
    // each tail element lands in its own independent lane)
    for (l, kk) in (chunks * LANES..a.len()).enumerate() {
        acc[l] = fmadd(a[kk], b[kk], acc[l]);
    }
    acc.iter().sum()
}

/// A row-panel with the depth axis zero-padded to a multiple of
/// [`LANES`]: row `i` lives at `data[i*kp .. (i+1)*kp]` with
/// `data[i*kp + k ..]` zeroed.  Padding makes every microkernel chunk a
/// full SIMD width — the zero lanes contribute exact zeros to the
/// accumulators, so no remainder loop ever runs.
#[derive(Clone, Debug, Default)]
pub struct PackedBlock {
    rows: usize,
    k: usize,
    kp: usize,
    data: Vec<f32>,
}

impl PackedBlock {
    pub fn new() -> PackedBlock {
        PackedBlock::default()
    }

    /// (Re)fill from a row-major `[rows, k]` slice, reusing the buffer.
    pub fn pack(&mut self, src: &[f32], rows: usize, k: usize) {
        debug_assert_eq!(src.len(), rows * k);
        let kp = k.div_ceil(LANES) * LANES;
        self.rows = rows;
        self.k = k;
        self.kp = kp;
        self.data.resize(rows * kp, 0.0);
        for i in 0..rows {
            self.data[i * kp..i * kp + k].copy_from_slice(&src[i * k..(i + 1) * k]);
            self.data[i * kp + k..(i + 1) * kp].fill(0.0);
        }
    }

    /// (Re)fill with the **transpose** of a row-major `[rows, cols]`
    /// slice: the packed panel holds `cols` rows of depth `rows` (padded
    /// to the lane width).  This is how the backward pass turns every
    /// TN/NN GEMM into the one NT register tile: packing an operand
    /// transposed swaps which axis is the reduction axis, so
    /// dV += Pᵀ·dO, dK += dSᵀ·Q and dQ += dS·K all become `A Bᵀ` over
    /// suitably transposed panels (see [`matmul_tn_packed_acc`] /
    /// [`matmul_nn_packed_acc`]).
    pub fn pack_transposed(&mut self, src: &[f32], rows: usize, cols: usize) {
        debug_assert_eq!(src.len(), rows * cols);
        let kp = rows.div_ceil(LANES) * LANES;
        self.rows = cols;
        self.k = rows;
        self.kp = kp;
        self.data.resize(cols * kp, 0.0);
        for j in 0..cols {
            let row = &mut self.data[j * kp..(j + 1) * kp];
            for (i, slot) in row[..rows].iter_mut().enumerate() {
                *slot = src[i * cols + j];
            }
            row[rows..].fill(0.0);
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical (unpadded) depth.
    pub fn depth(&self) -> usize {
        self.k
    }

    /// Padded row `i` (`kp` elements, tail zeroed).
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.kp..(i + 1) * self.kp]
    }
}

/// K for one head, packed per `bc`-wide column block.  Built **once per
/// KV head** and reused by every row block of every query head in the
/// head's group — the packing cost is amortized by `tr × group`.
#[derive(Clone, Debug)]
pub struct PackedKt {
    bc: usize,
    blocks: Vec<PackedBlock>,
}

impl PackedKt {
    /// An unpacked placeholder (no blocks); fill it with
    /// [`repack`](Self::repack) before use.  Lets plan/cache layers own
    /// reusable packing buffers without packing dummy data.
    pub fn empty(bc: usize) -> PackedKt {
        PackedKt { bc, blocks: Vec::new() }
    }

    /// Pack row-major `k[n, d]` into `⌈n/bc⌉` padded column blocks.
    pub fn pack(k: &[f32], n: usize, d: usize, bc: usize) -> PackedKt {
        let mut kt = PackedKt::empty(bc);
        kt.repack(k, n, d);
        kt
    }

    /// (Re)fill from row-major `k[n, d]`, reusing the block buffers —
    /// repeated packs of same-shape data perform no allocation, which
    /// is what lets an `ExecutionPlan` amortize packing storage across
    /// calls.
    pub fn repack(&mut self, k: &[f32], n: usize, d: usize) {
        debug_assert_eq!(k.len(), n * d);
        let bc = self.bc;
        let nb = n.div_ceil(bc);
        self.blocks.resize_with(nb, PackedBlock::new);
        for (bj, b) in self.blocks.iter_mut().enumerate() {
            let col0 = bj * bc;
            let cols = bc.min(n - col0);
            b.pack(&k[col0 * d..(col0 + cols) * d], cols, d);
        }
    }

    pub fn bc(&self) -> usize {
        self.bc
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The packed key block for column block `bj`.
    pub fn block(&self, bj: usize) -> &PackedBlock {
        &self.blocks[bj]
    }
}

/// Lane dot over two padded rows (no tail by construction).
#[inline]
fn dot_padded(a: &[f32], b: &[f32], chunks: usize) -> f32 {
    let mut acc = [0f32; LANES];
    for c in 0..chunks {
        let av = lane_chunk(a, c);
        let bv = lane_chunk(b, c);
        for l in 0..LANES {
            acc[l] = fmadd(av[l], bv[l], acc[l]);
        }
    }
    acc.iter().sum()
}

/// `out[m, n] = scale * (A B^T)` over packed operands — the fused
/// S = (Q K^T)·scale shape.  4×2 register tiling: four A rows × two B
/// rows share eight independent 8-lane accumulators, so each loaded A
/// chunk is reused twice and each B chunk four times, and the FMA
/// chains stay deep enough to saturate the ports.  Writes (does not
/// accumulate): the score tile needs no pre-zeroing pass.
pub fn matmul_nt_packed(a: &PackedBlock, b: &PackedBlock, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(a.kp, b.kp, "packed operands must share the padded depth");
    let (m, n) = (a.rows, b.rows);
    debug_assert_eq!(out.len(), m * n);
    let chunks = a.kp / LANES;
    let mut i = 0;
    while i + MR <= m {
        let ar = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        let mut j = 0;
        while j + NR <= n {
            let br = [b.row(j), b.row(j + 1)];
            let mut acc = [[0f32; LANES]; MR * NR];
            for c in 0..chunks {
                for (r, arow) in ar.iter().enumerate() {
                    let av = lane_chunk(arow, c);
                    for (s, brow) in br.iter().enumerate() {
                        let bv = lane_chunk(brow, c);
                        let lane = &mut acc[r * NR + s];
                        for l in 0..LANES {
                            lane[l] = fmadd(av[l], bv[l], lane[l]);
                        }
                    }
                }
            }
            for r in 0..MR {
                for s in 0..NR {
                    out[(i + r) * n + j + s] = scale * acc[r * NR + s].iter().sum::<f32>();
                }
            }
            j += NR;
        }
        while j < n {
            let brow = b.row(j);
            for (r, arow) in ar.iter().enumerate() {
                out[(i + r) * n + j] = scale * dot_padded(arow, brow, chunks);
            }
            j += 1;
        }
        i += MR;
    }
    while i < m {
        let arow = a.row(i);
        for j in 0..n {
            out[i * n + j] = scale * dot_padded(arow, b.row(j), chunks);
        }
        i += 1;
    }
}

/// `out[m, n] += scale * (A B^T)` over packed operands — the
/// accumulating twin of [`matmul_nt_packed`], for the backward shapes
/// that add into running gradient buffers instead of overwriting a
/// score tile.  Identical 4×2 register tiling and edge paths; only the
/// final store accumulates.
pub fn matmul_nt_packed_acc(a: &PackedBlock, b: &PackedBlock, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(a.kp, b.kp, "packed operands must share the padded depth");
    let (m, n) = (a.rows, b.rows);
    debug_assert_eq!(out.len(), m * n);
    let chunks = a.kp / LANES;
    let mut i = 0;
    while i + MR <= m {
        let ar = [a.row(i), a.row(i + 1), a.row(i + 2), a.row(i + 3)];
        let mut j = 0;
        while j + NR <= n {
            let br = [b.row(j), b.row(j + 1)];
            let mut acc = [[0f32; LANES]; MR * NR];
            for c in 0..chunks {
                for (r, arow) in ar.iter().enumerate() {
                    let av = lane_chunk(arow, c);
                    for (s, brow) in br.iter().enumerate() {
                        let bv = lane_chunk(brow, c);
                        let lane = &mut acc[r * NR + s];
                        for l in 0..LANES {
                            lane[l] = fmadd(av[l], bv[l], lane[l]);
                        }
                    }
                }
            }
            for r in 0..MR {
                for s in 0..NR {
                    out[(i + r) * n + j + s] += scale * acc[r * NR + s].iter().sum::<f32>();
                }
            }
            j += NR;
        }
        while j < n {
            let brow = b.row(j);
            for (r, arow) in ar.iter().enumerate() {
                out[(i + r) * n + j] += scale * dot_padded(arow, brow, chunks);
            }
            j += 1;
        }
        i += MR;
    }
    while i < m {
        let arow = a.row(i);
        for j in 0..n {
            out[i * n + j] += scale * dot_padded(arow, b.row(j), chunks);
        }
        i += 1;
    }
}

/// Packed TN microkernel: `out[k, n] += scale * (A^T B)` where
/// `at = pack_transposed(A[m, k])` and `bt = pack_transposed(B[m, n])`.
/// Transposing both operands turns the shared `m` axis into the packed
/// reduction depth, so the dV += Pᵀ·dO and dK += dSᵀ·Q shapes ride the
/// same 4×2 `fmadd` tile as the forward S = Q·Kᵀ kernel — there is one
/// register kernel in this engine, and operand *layout* selects the
/// GEMM flavor.
#[inline]
pub fn matmul_tn_packed_acc(at: &PackedBlock, bt: &PackedBlock, scale: f32, out: &mut [f32]) {
    matmul_nt_packed_acc(at, bt, scale, out);
}

/// Packed NN microkernel: `out[m, n] += scale * (A B)` where
/// `a = pack(A[m, k])` and `bt = pack_transposed(B[k, n])`.  Only the
/// right operand is transposed-packed (a `PackedVt`-style layout), which
/// is exactly the dQ += dS·K shape — dS packs naturally along its key
/// axis and Kᵀ is packed once per column block and reused by every row
/// block of every query head in the group.
#[inline]
pub fn matmul_nn_packed_acc(a: &PackedBlock, bt: &PackedBlock, scale: f32, out: &mut [f32]) {
    matmul_nt_packed_acc(a, bt, scale, out);
}

/// Max over a score row — lane-parallel (exact: max is order-free).
#[inline]
pub fn row_max(s: &[f32]) -> f32 {
    let chunks = s.len() / LANES;
    let mut acc = [f32::NEG_INFINITY; LANES];
    for c in 0..chunks {
        let sv = lane_chunk(s, c);
        for l in 0..LANES {
            acc[l] = acc[l].max(sv[l]);
        }
    }
    for (l, kk) in (chunks * LANES..s.len()).enumerate() {
        acc[l] = acc[l].max(s[kk]);
    }
    acc.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
}

/// In place `s[i] = exp(s[i] - m)`, returning the row sum — the online
/// softmax exp/accumulate pass with independent partial sums
/// (`exp(-inf) == 0` keeps masked elements exact).
#[inline]
pub fn exp_sub_sum(s: &mut [f32], m: f32) -> f32 {
    const P: usize = 4;
    let mut acc = [0f32; P];
    let chunks = s.len() / P;
    for c in 0..chunks {
        let sv = &mut s[c * P..(c + 1) * P];
        for l in 0..P {
            let p = (sv[l] - m).exp();
            sv[l] = p;
            acc[l] += p;
        }
    }
    for (l, kk) in (chunks * P..s.len()).enumerate() {
        let p = (s[kk] - m).exp();
        s[kk] = p;
        acc[l] += p;
    }
    acc.iter().sum()
}

/// `out[m,n] += a[m,k] @ b[n,k]^T` — the S = Q K^T shape (loose-layout
/// fallback; the forward hot path uses [`matmul_nt_packed`]).
pub fn matmul_nt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            oi[j] += dot(ai, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `out[m,n] += a[m,k] @ b[k,n]` — the O = P V shape.
pub fn matmul_nn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        // 2-row unrolled axpy: halves the number of passes over `oi`
        let mut kk = 0;
        while kk + 2 <= k {
            let (a0, a1) = (ai[kk], ai[kk + 1]);
            if a0 == 0.0 && a1 == 0.0 {
                kk += 2; // masked probabilities are exactly zero
                continue;
            }
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            for j in 0..n {
                oi[j] += a0 * b0[j] + a1 * b1[j];
            }
            kk += 2;
        }
        if kk < k {
            let av = ai[kk];
            if av != 0.0 {
                let bk = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    oi[j] += av * bk[j];
                }
            }
        }
    }
}

/// `out[k,n] += a[m,k]^T @ b[m,n]` — the dV = P^T dO / dK = dS^T Q shape.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    // process two source rows per pass so each out-row sees fused updates
    let mut i = 0;
    while i < m {
        let pair = (i + 1 < m) as usize;
        let a0 = &a[i * k..(i + 1) * k];
        let b0 = &b[i * n..(i + 1) * n];
        let (a1, b1) = if pair == 1 {
            (&a[(i + 1) * k..(i + 2) * k], &b[(i + 1) * n..(i + 2) * n])
        } else {
            (a0, b0)
        };
        for kk in 0..k {
            let (x0, x1) = (a0[kk], if pair == 1 { a1[kk] } else { 0.0 });
            if x0 == 0.0 && x1 == 0.0 {
                continue; // masked probabilities are exactly zero
            }
            let ok = &mut out[kk * n..(kk + 1) * n];
            for j in 0..n {
                ok[j] += x0 * b0[j] + x1 * b1[j];
            }
        }
        i += 1 + pair;
    }
}

/// Scale rows of `x[m,n]` by `alpha[m]` in place.
pub fn scale_rows(x: &mut [f32], alpha: &[f32], m: usize, n: usize) {
    for i in 0..m {
        let a = alpha[i];
        if a == 1.0 {
            continue;
        }
        for v in &mut x[i * n..(i + 1) * n] {
            *v *= a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    out[i * n + j] += a[i * k + kk] * b[j * k + kk];
                }
            }
        }
        out
    }

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn nt_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 5, 7), (8, 16, 8), (1, 1, 1), (5, 4, 6)] {
            let a = rand(m * k, &mut rng);
            let b = rand(n * k, &mut rng);
            let mut out = vec![0.0; m * n];
            matmul_nt_acc(&a, &b, m, k, n, &mut out);
            let want = naive_nt(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn nt_tail_shapes_match_naive() {
        // satellite: d % 8 != 0 shapes (the lane-folded tail) must stay
        // on the fast path *and* stay correct — d = 80 is the ISSUE's
        // canonical odd head dim
        let mut rng = Rng::new(7);
        for k in [1usize, 3, 5, 7, 9, 15, 17, 80, 100] {
            let (m, n) = (3, 4);
            let a = rand(m * k, &mut rng);
            let b = rand(n * k, &mut rng);
            let mut out = vec![0.0; m * n];
            matmul_nt_acc(&a, &b, m, k, n, &mut out);
            let want = naive_nt(&a, &b, m, k, n);
            for (x, y) in out.iter().zip(&want) {
                assert!((x - y).abs() < 2e-4, "k={k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_kernel_matches_naive_awkward_shapes() {
        // satellite: every m,k,n in {1,3,5,7,80,100} — non-multiples of
        // the 4×2 register tile and of the 8-lane width, so all edge
        // paths (odd rows, odd columns, padded depth) are exercised
        let dims = [1usize, 3, 5, 7, 80, 100];
        let mut rng = Rng::new(2);
        for &m in &dims {
            for &k in &dims {
                for &n in &dims {
                    let a = rand(m * k, &mut rng);
                    let b = rand(n * k, &mut rng);
                    let mut pa = PackedBlock::new();
                    pa.pack(&a, m, k);
                    let mut pb = PackedBlock::new();
                    pb.pack(&b, n, k);
                    let mut out = vec![0.0; m * n];
                    matmul_nt_packed(&pa, &pb, 1.0, &mut out);
                    let want = naive_nt(&a, &b, m, k, n);
                    for (i, (x, y)) in out.iter().zip(&want).enumerate() {
                        assert!(
                            (x - y).abs() < 2e-4,
                            "m={m} k={k} n={n} out[{i}]: {x} vs {y}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_kernel_fuses_scale() {
        let (m, k, n) = (6, 19, 5);
        let mut rng = Rng::new(3);
        let a = rand(m * k, &mut rng);
        let b = rand(n * k, &mut rng);
        let mut pa = PackedBlock::new();
        pa.pack(&a, m, k);
        let mut pb = PackedBlock::new();
        pb.pack(&b, n, k);
        let mut out = vec![0.0; m * n];
        matmul_nt_packed(&pa, &pb, 0.125, &mut out);
        let want = naive_nt(&a, &b, m, k, n);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - 0.125 * y).abs() < 1e-4, "{x} vs {}", 0.125 * y);
        }
    }

    #[test]
    fn packed_block_reuse_and_padding() {
        // repacking a larger then smaller panel must not leak stale data
        let mut p = PackedBlock::new();
        p.pack(&[1.0; 24], 2, 12); // kp = 16
        p.pack(&[2.0; 6], 2, 3); // kp = 8, reused buffer
        assert_eq!(p.rows(), 2);
        assert_eq!(p.depth(), 3);
        for i in 0..2 {
            let r = p.row(i);
            assert_eq!(&r[..3], &[2.0, 2.0, 2.0]);
            assert!(r[3..].iter().all(|&x| x == 0.0), "padding must be zero");
        }
    }

    #[test]
    fn packed_kt_blocks_cover_the_head() {
        let (n, d, bc) = (100, 5, 32);
        let mut rng = Rng::new(4);
        let k = rand(n * d, &mut rng);
        let kt = PackedKt::pack(&k, n, d, bc);
        assert_eq!(kt.n_blocks(), 4);
        assert_eq!(kt.bc(), bc);
        assert_eq!(kt.block(0).rows(), 32);
        assert_eq!(kt.block(3).rows(), 4); // ragged tail block
        // block 3 row 0 is K row 96
        let mut pq = PackedBlock::new();
        pq.pack(&k[96 * d..97 * d], 1, d);
        let mut s = vec![0.0; 4];
        matmul_nt_packed(&pq, kt.block(3), 1.0, &mut s);
        let want: f32 = k[96 * d..97 * d].iter().map(|x| x * x).sum();
        assert!((s[0] - want).abs() < 1e-4);
    }

    #[test]
    fn row_max_and_exp_sub_sum() {
        let mut s = vec![0.5f32, -1.0, 3.0, f32::NEG_INFINITY, 2.0, 0.0, -2.5, 1.5, 0.25, -0.75];
        assert_eq!(row_max(&s), 3.0);
        assert_eq!(row_max(&[f32::NEG_INFINITY; 3]), f32::NEG_INFINITY);
        let want_sum: f32 = s.iter().map(|&x| (x - 3.0f32).exp()).sum();
        let got_sum = exp_sub_sum(&mut s, 3.0);
        assert!((got_sum - want_sum).abs() < 1e-5);
        assert_eq!(s[3], 0.0, "masked element must become exactly zero");
        assert!((s[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nn_matches_naive() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (4, 6, 5);
        let a = rand(m * k, &mut rng);
        let b = rand(k * n, &mut rng);
        let mut out = vec![0.0; m * n];
        matmul_nn_acc(&a, &b, m, k, n, &mut out);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn tn_matches_naive() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (6, 4, 5);
        let a = rand(m * k, &mut rng);
        let b = rand(m * n, &mut rng);
        let mut out = vec![0.0; k * n];
        matmul_tn_acc(&a, &b, m, k, n, &mut out);
        for kk in 0..k {
            for j in 0..n {
                let want: f32 = (0..m).map(|i| a[i * k + kk] * b[i * n + j]).sum();
                assert!((out[kk * n + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut out = vec![10.0];
        matmul_nt_acc(&a, &b, 1, 2, 1, &mut out);
        assert_eq!(out[0], 10.0 + 11.0);
    }

    #[test]
    fn scale_rows_works() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        scale_rows(&mut x, &[2.0, 0.5], 2, 2);
        assert_eq!(x, vec![2.0, 4.0, 1.5, 2.0]);
    }

    #[test]
    fn pack_transposed_is_the_transpose() {
        // pack_transposed([rows, cols]) must equal pack of the explicit
        // transpose, bit for bit (padding included) — the backward
        // kernels rely on the two layouts being interchangeable
        let dims = [1usize, 3, 5, 7, 80, 100];
        let mut rng = Rng::new(11);
        for &rows in &dims {
            for &cols in &dims {
                let src = rand(rows * cols, &mut rng);
                let mut t = vec![0.0; cols * rows];
                for i in 0..rows {
                    for j in 0..cols {
                        t[j * rows + i] = src[i * cols + j];
                    }
                }
                let mut pt = PackedBlock::new();
                pt.pack_transposed(&src, rows, cols);
                let mut pe = PackedBlock::new();
                pe.pack(&t, cols, rows);
                assert_eq!(pt.rows(), cols);
                assert_eq!(pt.depth(), rows);
                for j in 0..cols {
                    assert_eq!(pt.row(j), pe.row(j), "rows={rows} cols={cols} panel row {j}");
                }
            }
        }
    }

    #[test]
    fn packed_acc_matches_naive_awkward_shapes_and_accumulates() {
        // the accumulating twin must agree with naive A·Bᵀ *added onto*
        // a non-zero running buffer across the same edge-path grid as
        // the write kernel
        let dims = [1usize, 3, 5, 7, 80, 100];
        let mut rng = Rng::new(12);
        for &m in &dims {
            for &k in &dims {
                for &n in &dims {
                    let a = rand(m * k, &mut rng);
                    let b = rand(n * k, &mut rng);
                    let base = rand(m * n, &mut rng);
                    let mut pa = PackedBlock::new();
                    pa.pack(&a, m, k);
                    let mut pb = PackedBlock::new();
                    pb.pack(&b, n, k);
                    let mut out = base.clone();
                    matmul_nt_packed_acc(&pa, &pb, 0.5, &mut out);
                    let want = naive_nt(&a, &b, m, k, n);
                    for i in 0..m * n {
                        let expect = base[i] + 0.5 * want[i];
                        assert!(
                            (out[i] - expect).abs() < 2e-4,
                            "m={m} k={k} n={n} out[{i}]: {} vs {expect}",
                            out[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_tn_matches_loose_tn() {
        // out[k,n] += Aᵀ·B via transposed panels vs the loose kernel
        let mut rng = Rng::new(13);
        for (m, k, n) in [(6, 4, 5), (64, 64, 80), (7, 3, 100), (1, 1, 1)] {
            let a = rand(m * k, &mut rng);
            let b = rand(m * n, &mut rng);
            let mut want = rand(k * n, &mut rng);
            let mut got = want.clone();
            matmul_tn_acc(&a, &b, m, k, n, &mut want);
            let mut at = PackedBlock::new();
            at.pack_transposed(&a, m, k);
            let mut bt = PackedBlock::new();
            bt.pack_transposed(&b, m, n);
            matmul_tn_packed_acc(&at, &bt, 1.0, &mut got);
            for i in 0..k * n {
                assert!(
                    (got[i] - want[i]).abs() < 2e-4,
                    "m={m} k={k} n={n} out[{i}]: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn packed_nn_matches_loose_nn() {
        // out[m,n] += A·B with only the right operand transposed-packed
        let mut rng = Rng::new(14);
        for (m, k, n) in [(4, 6, 5), (64, 64, 128), (5, 100, 7), (1, 1, 1)] {
            let a = rand(m * k, &mut rng);
            let b = rand(k * n, &mut rng);
            let mut want = rand(m * n, &mut rng);
            let mut got = want.clone();
            matmul_nn_acc(&a, &b, m, k, n, &mut want);
            let mut pa = PackedBlock::new();
            pa.pack(&a, m, k);
            let mut bt = PackedBlock::new();
            bt.pack_transposed(&b, k, n);
            matmul_nn_packed_acc(&pa, &bt, 1.0, &mut got);
            for i in 0..m * n {
                assert!(
                    (got[i] - want[i]).abs() < 2e-4,
                    "m={m} k={k} n={n} out[{i}]: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}
