//! FlexAttention-like baseline (He et al., 2024).
//!
//! Execution model mirrors the published design: a *BlockMask* of
//! per-tile classes is precomputed by evaluating a `mask_mod(i, j)`
//! predicate over the whole score matrix (`O(N²/BrBc)` memory, O(N²)
//! setup work), then the kernel skips fully-masked tiles and re-invokes
//! the predicate *per element* on partial tiles.  The per-element
//! dynamic call is the structural reason FlexAttention trails FLASHMASK
//! on partial-tile-heavy masks (paper §5.4) — here it shows up as a
//! `dyn Fn` indirection instead of compiled-graph overhead.

use super::gemm;
use super::{AttnConfig, AttnGrads, AttnOutput, TileStats};
use crate::mask::BlockClass;

/// The FlexAttention mask predicate: `true` = position visible.
pub type MaskMod<'a> = dyn Fn(usize, usize) -> bool + Sync + 'a;

/// Precomputed block mask (FlexAttention's `BlockMask`).
pub struct BlockMask {
    pub br: usize,
    pub bc: usize,
    pub tr: usize,
    pub tc: usize,
    pub classes: Vec<BlockClass>,
}

impl BlockMask {
    /// Build by scanning the predicate — O(N²) evaluations, like
    /// `create_block_mask` in FlexAttention.  Counted as setup, not
    /// kernel time (the paper's kernel benches exclude it too).
    pub fn build(mask_mod: &MaskMod, n: usize, br: usize, bc: usize) -> BlockMask {
        let tr = n.div_ceil(br);
        let tc = n.div_ceil(bc);
        let mut classes = Vec::with_capacity(tr * tc);
        for bi in 0..tr {
            for bj in 0..tc {
                let mut any_vis = false;
                let mut any_masked = false;
                'scan: for i in bi * br..((bi + 1) * br).min(n) {
                    for j in bj * bc..((bj + 1) * bc).min(n) {
                        if mask_mod(i, j) {
                            any_vis = true;
                        } else {
                            any_masked = true;
                        }
                        if any_vis && any_masked {
                            break 'scan;
                        }
                    }
                }
                classes.push(match (any_vis, any_masked) {
                    (false, _) => BlockClass::FullyMasked,
                    (true, true) => BlockClass::PartiallyMasked,
                    (true, false) => BlockClass::Unmasked,
                });
            }
        }
        BlockMask { br, bc, tr, tc, classes }
    }

    #[inline]
    pub fn class(&self, bi: usize, bj: usize) -> BlockClass {
        self.classes[bi * self.tc + bj]
    }

    /// BlockMask storage in bytes — the paper's O(N²/BrBc) memory term.
    pub fn bytes(&self) -> usize {
        self.classes.len()
    }

    pub fn sparsity(&self) -> f64 {
        let f = self.classes.iter().filter(|c| **c == BlockClass::FullyMasked).count();
        f as f64 / self.classes.len() as f64
    }
}

/// FlexAttention-like forward: block-mask skip + per-element predicate
/// on partial tiles.
pub fn flex_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    mask_mod: &MaskMod,
    bm: &BlockMask,
    cfg: AttnConfig,
) -> (AttnOutput, TileStats) {
    let (br, bc) = (cfg.br, cfg.bc);
    assert_eq!((bm.br, bm.bc), (br, bc), "BlockMask tile mismatch");
    let (tr, tc) = (bm.tr, bm.tc);
    let mut out = vec![0f32; n * d];
    let mut lse = vec![f32::NEG_INFINITY; n];
    let mut stats = TileStats { tiles_total: tr * tc, ..Default::default() };

    let mut s = vec![0f32; br * bc];
    let mut o_acc = vec![0f32; br * d];
    let mut m_run = vec![f32::NEG_INFINITY; br];
    let mut l_run = vec![0f32; br];
    let mut alpha = vec![0f32; br];

    for bi in 0..tr {
        let row0 = bi * br;
        let rows = br.min(n - row0);
        o_acc[..rows * d].fill(0.0);
        m_run[..rows].fill(f32::NEG_INFINITY);
        l_run[..rows].fill(0.0);

        for bj in 0..tc {
            let class = bm.class(bi, bj);
            if class == BlockClass::FullyMasked {
                stats.tiles_skipped += 1;
                continue;
            }
            let col0 = bj * bc;
            let cols = bc.min(n - col0);
            let s_tile = &mut s[..rows * cols];
            s_tile.fill(0.0);
            gemm::matmul_nt_acc(
                &q[row0 * d..(row0 + rows) * d],
                &k[col0 * d..(col0 + cols) * d],
                rows,
                d,
                cols,
                s_tile,
            );
            stats.macs += (rows * cols * d) as u64;
            for sv in s_tile.iter_mut() {
                *sv *= cfg.scale;
            }
            if class == BlockClass::PartiallyMasked {
                // per-element mask_mod — Flex's expression-based masking
                for x in 0..rows {
                    for y in 0..cols {
                        if !mask_mod(row0 + x, col0 + y) {
                            s_tile[x * cols + y] = f32::NEG_INFINITY;
                        }
                    }
                }
                stats.mask_evals += (rows * cols) as u64;
                stats.tiles_partial += 1;
            } else {
                stats.tiles_unmasked += 1;
            }

            for x in 0..rows {
                let srow = &mut s_tile[x * cols..(x + 1) * cols];
                let mut row_max = f32::NEG_INFINITY;
                for &sv in srow.iter() {
                    row_max = row_max.max(sv);
                }
                let m_new = m_run[x].max(row_max);
                let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
                let a = if m_run[x].is_finite() { (m_run[x] - m_safe).exp() } else { 0.0 };
                let mut row_sum = 0f32;
                for sv in srow.iter_mut() {
                    let p = (*sv - m_safe).exp();
                    *sv = p;
                    row_sum += p;
                }
                l_run[x] = a * l_run[x] + row_sum;
                m_run[x] = m_new;
                alpha[x] = a;
            }
            gemm::scale_rows(&mut o_acc[..rows * d], &alpha[..rows], rows, d);
            gemm::matmul_nn_acc(
                s_tile,
                &v[col0 * d..(col0 + cols) * d],
                rows,
                cols,
                d,
                &mut o_acc[..rows * d],
            );
            stats.macs += (rows * cols * d) as u64;
        }
        for x in 0..rows {
            let i = row0 + x;
            if l_run[x] > 0.0 {
                let inv = 1.0 / l_run[x];
                for dd in 0..d {
                    out[i * d + dd] = o_acc[x * d + dd] * inv;
                }
                let m_safe = if m_run[x].is_finite() { m_run[x] } else { 0.0 };
                lse[i] = m_safe + l_run[x].ln();
            }
        }
    }
    (AttnOutput { o: out, lse }, stats)
}

/// FlexAttention-like backward (same block-mask skip + per-element
/// predicate structure as the forward).
#[allow(clippy::too_many_arguments)]
pub fn flex_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    mask_mod: &MaskMod,
    bm: &BlockMask,
    cfg: AttnConfig,
) -> (AttnGrads, TileStats) {
    let (br, bc) = (cfg.br, cfg.bc);
    let (tr, tc) = (bm.tr, bm.tc);
    let mut dq = vec![0f32; n * d];
    let mut dk = vec![0f32; n * d];
    let mut dv = vec![0f32; n * d];
    let mut stats = TileStats { tiles_total: tr * tc, ..Default::default() };

    let mut dvec = vec![0f32; n];
    for i in 0..n {
        let mut acc = 0f32;
        for dd in 0..d {
            acc += do_[i * d + dd] * o[i * d + dd];
        }
        dvec[i] = acc;
    }

    let mut s = vec![0f32; br * bc];
    let mut dp = vec![0f32; br * bc];
    for bj in 0..tc {
        let col0 = bj * bc;
        let cols = bc.min(n - col0);
        let kj = &k[col0 * d..(col0 + cols) * d];
        let vj = &v[col0 * d..(col0 + cols) * d];
        for bi in 0..tr {
            let class = bm.class(bi, bj);
            if class == BlockClass::FullyMasked {
                stats.tiles_skipped += 1;
                continue;
            }
            let row0 = bi * br;
            let rows = br.min(n - row0);
            let qi = &q[row0 * d..(row0 + rows) * d];
            let doi = &do_[row0 * d..(row0 + rows) * d];
            let s_tile = &mut s[..rows * cols];
            s_tile.fill(0.0);
            gemm::matmul_nt_acc(qi, kj, rows, d, cols, s_tile);
            stats.macs += (rows * cols * d) as u64;
            for sv in s_tile.iter_mut() {
                *sv *= cfg.scale;
            }
            if class == BlockClass::PartiallyMasked {
                for x in 0..rows {
                    for y in 0..cols {
                        if !mask_mod(row0 + x, col0 + y) {
                            s_tile[x * cols + y] = f32::NEG_INFINITY;
                        }
                    }
                }
                stats.mask_evals += (rows * cols) as u64;
                stats.tiles_partial += 1;
            } else {
                stats.tiles_unmasked += 1;
            }
            for x in 0..rows {
                let l = lse[row0 + x];
                let srow = &mut s_tile[x * cols..(x + 1) * cols];
                if l.is_finite() {
                    for sv in srow.iter_mut() {
                        *sv = (*sv - l).exp();
                    }
                } else {
                    srow.fill(0.0);
                }
            }
            gemm::matmul_tn_acc(s_tile, doi, rows, cols, d, &mut dv[col0 * d..(col0 + cols) * d]);
            let dp_tile = &mut dp[..rows * cols];
            dp_tile.fill(0.0);
            gemm::matmul_nt_acc(doi, vj, rows, d, cols, dp_tile);
            for x in 0..rows {
                let dv_i = dvec[row0 + x];
                for y in 0..cols {
                    let idx = x * cols + y;
                    dp_tile[idx] = s_tile[idx] * (dp_tile[idx] - dv_i) * cfg.scale;
                }
            }
            gemm::matmul_nn_acc(dp_tile, kj, rows, cols, d, &mut dq[row0 * d..(row0 + rows) * d]);
            gemm::matmul_tn_acc(dp_tile, qi, rows, cols, d, &mut dk[col0 * d..(col0 + cols) * d]);
            stats.macs += 4 * (rows * cols * d) as u64;
        }
    }
    (AttnGrads { dq, dk, dv }, stats)
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::testutil::rand_vec;
    use crate::attention::{dense, flash};
    use crate::mask::{builders, BlockTable};
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_and_flashmask() {
        let (n, d) = (128, 16);
        let mut rng = Rng::new(1);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 4) {
            let pred = |i: usize, j: usize| mask.allowed(i, j);
            let bm = BlockMask::build(&pred, n, cfg.br, cfg.bc);
            let (got, _) = flex_forward(&q, &k, &v, n, d, &pred, &bm, cfg);
            let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
            for (a, b) in got.o.iter().zip(&want.o) {
                assert!((a - b).abs() < 2e-5, "{kind}");
            }
            // and bitwise vs flashmask when block classes agree
            let table = BlockTable::build(&mask, cfg.bc);
            let (fm, _) = flash::flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            for (a, b) in got.o.iter().zip(&fm.o) {
                assert!((a - b).abs() < 2e-5, "{kind} flex vs flashmask");
            }
        }
    }

    #[test]
    fn block_mask_sparsity_matches_flashmask_table() {
        let n = 256;
        let mask = builders::causal(n);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let bm = BlockMask::build(&pred, n, 32, 32);
        assert!((bm.sparsity() - mask.block_sparsity(32, 32)).abs() < 1e-12);
        assert_eq!(bm.bytes(), 64);
    }

    #[test]
    fn backward_matches_flashmask_backward() {
        let (n, d) = (64, 8);
        let mut rng = Rng::new(2);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let do_ = rand_vec(n * d, &mut rng);
        let mask = builders::share_question(
            n,
            &[builders::SharedQuestionDoc { question_len: 40, answer_lens: vec![12, 12] }],
        );
        let cfg = AttnConfig::new(16, 16, d);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let bm = BlockMask::build(&pred, n, cfg.br, cfg.bc);
        let table = BlockTable::build(&mask, cfg.bc);
        let (fwd, _) = flash::flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        let (g1, _) = flex_backward(&q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &pred, &bm, cfg);
        let (g2, _) = flash::flashmask_backward(
            &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, true,
        );
        for (a, b) in g1.dq.iter().zip(&g2.dq) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in g1.dk.iter().zip(&g2.dk) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
