//! CPU blocked-attention engine — the "GPU simulator" substrate.
//!
//! Executes the paper's Algorithm 1/2 tile-for-tile in f32 on the CPU,
//! with per-tile skip decisions driven by the same [`BlockTable`]
//! classification the Pallas kernel uses.  Because block skipping is an
//! algorithmic property (not a hardware one), measured CPU wall-clock
//! scales with executed tiles exactly as GPU time scales with executed
//! tiles, so speedup *shapes* transfer (DESIGN.md §Substitutions).
//!
//! Engines:
//! * [`dense`] — vanilla O(N²) attention + dense additive mask
//!   (the paper's "vanilla attention" baseline).
//! * [`flash`] — FA2 tiling + online softmax; `skip=false` is the
//!   "FlashAttention dense mask" baseline, `skip=true` is FLASHMASK.
//! * [`flex`] — FlexAttention-like baseline: precomputed
//!   O(N²/BrBc) block mask + per-element `mask_mod` closure on
//!   partial tiles.
//! * [`bsr`] — FlashInfer-like block-sparse-row baseline with mask
//!   block size R/C (Tables 10–14).

pub mod bsr;
pub mod dense;
pub mod flash;
pub mod flex;
pub mod gemm;

use crate::mask::FlashMask;

/// Tile sizes + softmax scale for blocked engines.
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    pub br: usize,
    pub bc: usize,
    pub scale: f32,
}

impl AttnConfig {
    pub fn new(br: usize, bc: usize, d: usize) -> AttnConfig {
        AttnConfig { br, bc, scale: 1.0 / (d as f32).sqrt() }
    }
}

/// Forward output: attention result + per-row logsumexp (consumed by the
/// backward pass, exactly like the kernel's residuals).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
}

/// Work counters, used by the perf model and the benches to report the
/// paper's tile-census-based FLOPs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TileStats {
    pub tiles_total: usize,
    pub tiles_skipped: usize,
    pub tiles_partial: usize,
    pub tiles_unmasked: usize,
    /// Multiply-accumulate count of executed matmuls (2 per MAC = FLOPs).
    pub macs: u64,
    /// Element-wise mask evaluations (the Flex `mask_mod` cost proxy).
    pub mask_evals: u64,
}

impl TileStats {
    pub fn flops(&self) -> u64 {
        2 * self.macs
    }

    pub fn merge(&mut self, other: &TileStats) {
        self.tiles_total += other.tiles_total;
        self.tiles_skipped += other.tiles_skipped;
        self.tiles_partial += other.tiles_partial;
        self.tiles_unmasked += other.tiles_unmasked;
        self.macs += other.macs;
        self.mask_evals += other.mask_evals;
    }
}

/// Gradients from a backward pass.
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

/// Run `heads` independent single-head problems across OS threads
/// (the coordinator's head-parallel hot path).
pub fn parallel_heads<F, R>(heads: usize, max_threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
    R: Send,
{
    assert!(max_threads >= 1);
    let mut results: Vec<Option<R>> = (0..heads).map(|_| None).collect();
    std::thread::scope(|scope| {
        let chunks: Vec<&mut [Option<R>]> = {
            let per = heads.div_ceil(max_threads.min(heads).max(1));
            results.chunks_mut(per).collect()
        };
        for (ci, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            let per = heads.div_ceil(max_threads.min(heads).max(1));
            scope.spawn(move || {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(ci * per + off));
                }
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Reference finite-difference gradient check helper (tests only).
#[cfg(test)]
pub(crate) fn finite_diff_loss<F: Fn(&[f32]) -> f32>(
    f: F,
    x: &[f32],
    eps: f32,
) -> Vec<f32> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let orig = xp[i];
        xp[i] = orig + eps;
        let fp = f(&xp);
        xp[i] = orig - eps;
        let fm = f(&xp);
        xp[i] = orig;
        g[i] = (fp - fm) / (2.0 * eps);
    }
    g
}

/// Shared test fixtures.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::util::rng::Rng;

    pub fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }
}

pub use flash::{flashmask_backward, flashmask_forward};

/// Convenience: FLASHMASK forward for one head with stats.
pub fn forward_single_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnOutput, TileStats) {
    let table = crate::mask::BlockTable::build(mask, cfg.bc);
    flash::flashmask_forward(q, k, v, n, d, mask, &table, cfg, skip)
}
