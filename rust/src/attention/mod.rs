//! CPU blocked-attention engine — the "GPU simulator" substrate.
//!
//! Executes the paper's Algorithm 1/2 tile-for-tile in f32 on the CPU,
//! with per-tile skip decisions driven by the same [`BlockTable`]
//! classification the Pallas kernel uses.  Because block skipping is an
//! algorithmic property (not a hardware one), measured CPU wall-clock
//! scales with executed tiles exactly as GPU time scales with executed
//! tiles, so speedup *shapes* transfer (DESIGN.md §Substitutions).
//!
//! The public surface is [`api`]: an `AttnProblem` builder compiled to
//! a cached `ExecutionPlan` and executed on a pluggable `Backend`
//! (`CpuBackend` / `DenseRefBackend` / `PjrtBackend`).  The engine
//! free functions below are deprecated shims over it.
//!
//! Engines:
//! * [`dense`] — vanilla O(N²) attention + dense additive mask
//!   (the paper's "vanilla attention" baseline).
//! * [`flash`] — FA2 tiling + online softmax; `skip=false` is the
//!   "FlashAttention dense mask" baseline, `skip=true` is FLASHMASK.
//! * [`flex`] — FlexAttention-like baseline: precomputed
//!   O(N²/BrBc) block mask + per-element `mask_mod` closure on
//!   partial tiles.
//! * [`bsr`] — FlashInfer-like block-sparse-row baseline with mask
//!   block size R/C (Tables 10–14).

pub mod api;
pub mod bsr;
pub mod dense;
pub mod flash;
pub mod flex;
pub mod gemm;

use crate::mask::FlashMask;
// trait in scope for the deprecated `forward_single_head` shim below
use api::Backend as _;

/// Query/KV head counts of an attention layout.
///
/// Grouped-query attention (GQA) shares each KV head across a *group*
/// of `q_heads / kv_heads` query heads; multi-head attention (MHA,
/// `q_heads == kv_heads`) and multi-query attention (MQA,
/// `kv_heads == 1`) are the two ends of the spectrum.  The layout is
/// the unit every layer batches and accounts on: kernels classify
/// tiles/pages once per KV head, the paged KV cache holds one page
/// chain per KV head, and the serving scheduler groups requests by
/// `(layout, n, d)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct HeadLayout {
    pub q_heads: usize,
    pub kv_heads: usize,
}

impl HeadLayout {
    pub fn new(q_heads: usize, kv_heads: usize) -> HeadLayout {
        assert!(q_heads >= 1 && kv_heads >= 1, "layout needs at least one head of each kind");
        assert!(
            q_heads % kv_heads == 0,
            "q_heads {q_heads} must be a multiple of kv_heads {kv_heads}"
        );
        HeadLayout { q_heads, kv_heads }
    }

    /// Multi-head attention: every query head owns its KV head.
    pub fn mha(heads: usize) -> HeadLayout {
        HeadLayout::new(heads, heads)
    }

    /// Multi-query attention: one KV head shared by every query head.
    pub fn mqa(q_heads: usize) -> HeadLayout {
        HeadLayout::new(q_heads, 1)
    }

    /// Grouped-query attention — alias of [`HeadLayout::new`] matching
    /// the builder-API spelling `AttnProblem::new(n, d).layout(HeadLayout::gqa(32, 8))`.
    pub fn gqa(q_heads: usize, kv_heads: usize) -> HeadLayout {
        HeadLayout::new(q_heads, kv_heads)
    }

    /// Query heads per KV head.
    pub fn group(&self) -> usize {
        self.q_heads / self.kv_heads
    }

    /// The KV head query head `q_head` reads from.
    pub fn kv_head_of(&self, q_head: usize) -> usize {
        debug_assert!(q_head < self.q_heads);
        q_head / self.group()
    }

    pub fn is_mha(&self) -> bool {
        self.q_heads == self.kv_heads
    }
}

impl std::fmt::Display for HeadLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}q/{}kv", self.q_heads, self.kv_heads)
    }
}

/// Tile sizes + softmax scale for blocked engines.
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    pub br: usize,
    pub bc: usize,
    pub scale: f32,
}

impl AttnConfig {
    pub fn new(br: usize, bc: usize, d: usize) -> AttnConfig {
        AttnConfig { br, bc, scale: 1.0 / (d as f32).sqrt() }
    }
}

/// Forward output: attention result + per-row logsumexp (consumed by the
/// backward pass, exactly like the kernel's residuals).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
}

/// Work counters, used by the perf model and the benches to report the
/// paper's tile-census-based FLOPs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TileStats {
    pub tiles_total: usize,
    pub tiles_skipped: usize,
    pub tiles_partial: usize,
    pub tiles_unmasked: usize,
    /// Inner-loop trips actually performed by the interval-driven tile
    /// schedule (counted per compute pass, i.e. per query head).  Lies
    /// between the executed-tile count (`tiles_partial +
    /// tiles_unmasked` per pass) and the dense trip count the old
    /// `for bj in 0..tc` scan paid (`tiles_total` per pass): tiles
    /// outside a row block's `[bj_lo, bj_hi)` visit range are never
    /// visited at all, tiles inside it that Eq. 4 masks still cost one
    /// (branch-only) trip.
    pub tiles_visited: usize,
    /// Multiply-accumulate count of executed matmuls (2 per MAC = FLOPs).
    pub macs: u64,
    /// Element-wise mask evaluations (the Flex `mask_mod` cost proxy).
    /// With the per-tile mask cache these are performed once per
    /// [`api::ExecutionPlan`] build and charged once per KV head, not
    /// once per query head per call — at group size `g` the counter
    /// shrinks by `g` versus the pre-cache kernels.
    pub mask_evals: u64,
    /// Partial-tile mask applications served from the plan's
    /// precomputed per-tile mask cache (one per partial tile per
    /// row-block pass) instead of re-running the element-wise interval
    /// tests — the work the cache shares across the query group and
    /// across repeated calls.
    pub mask_cache_hits: u64,
}

impl TileStats {
    pub fn flops(&self) -> u64 {
        2 * self.macs
    }

    pub fn merge(&mut self, other: &TileStats) {
        self.tiles_total += other.tiles_total;
        self.tiles_skipped += other.tiles_skipped;
        self.tiles_partial += other.tiles_partial;
        self.tiles_unmasked += other.tiles_unmasked;
        self.tiles_visited += other.tiles_visited;
        self.macs += other.macs;
        self.mask_evals += other.mask_evals;
        self.mask_cache_hits += other.mask_cache_hits;
    }

    /// Accumulate this census into the global telemetry registry under
    /// the `tile.*` names (DESIGN.md §Telemetry).  Called once per
    /// prefill execution, not per tile, so the cost is a handful of
    /// counter adds.
    pub fn publish(&self) {
        use crate::telemetry::names as tn;
        let r = crate::telemetry::metrics::global();
        r.add(tn::TILE_TOTAL, self.tiles_total as u64);
        r.add(tn::TILE_SKIPPED, self.tiles_skipped as u64);
        r.add(tn::TILE_PARTIAL, self.tiles_partial as u64);
        r.add(tn::TILE_UNMASKED, self.tiles_unmasked as u64);
        r.add(tn::TILE_VISITED, self.tiles_visited as u64);
        r.add(tn::TILE_MACS, self.macs);
        r.add(tn::TILE_MASK_EVALS, self.mask_evals);
        r.add(tn::TILE_MASK_CACHE_HITS, self.mask_cache_hits);
    }
}

/// Gradients from a backward pass.
#[derive(Clone, Debug)]
pub struct AttnGrads {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

/// Gradients from a grouped-layout backward pass: one dQ per query
/// head, and one dK/dV per **KV head** — the query group's key/value
/// gradients are accumulated across the group (in ascending query-head
/// order), mirroring how the shared K/V received contributions from
/// every group member in the forward pass.
#[derive(Clone, Debug)]
pub struct GroupedGrads {
    /// Per query head, `[n * d]` each.
    pub dq: Vec<Vec<f32>>,
    /// Per KV head, `[n * d]` each (summed over the query group).
    pub dk: Vec<Vec<f32>>,
    /// Per KV head, `[n * d]` each (summed over the query group).
    pub dv: Vec<Vec<f32>>,
}

/// Cost-weighted work partitioning over a `(heads × blocks)` grid — the
/// generalization of head-only parallelism to the sequence axis
/// (FlashAttention-2's work-partitioning observation on this engine).
///
/// Work item `(h, b)` costs `weight[b]` (the caller passes the
/// visited-tile count per row block, so a causal workload's heavy last
/// rows don't tail-stall one thread while the early-row threads idle).
/// Items are cut into at most `max_threads` *contiguous* chunks of
/// approximately equal total weight — contiguity keeps each thread on
/// one head's memory for as long as possible and makes the result
/// order (head-major, block-minor) deterministic.
///
/// A single long sequence (`heads == 1`, many row blocks) now spreads
/// across every core; head-only parallelism gave it exactly one.
pub fn parallel_2d<F, R>(
    heads: usize,
    blocks: usize,
    weight: &[u64],
    max_threads: usize,
    f: F,
) -> Vec<R>
where
    F: Fn(usize, usize) -> R + Sync,
    R: Send,
{
    assert!(max_threads >= 1);
    assert_eq!(weight.len(), blocks, "one weight per block");
    let items = heads * blocks;
    if items == 0 {
        return Vec::new();
    }
    let bounds = chunk_bounds(weight, heads, max_threads.min(items));
    let mut results: Vec<Option<R>> = (0..items).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut rest: &mut [Option<R>] = &mut results;
        let mut start = 0;
        for &end in &bounds {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(end - start);
            rest = tail;
            let f = &f;
            scope.spawn(move || {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    let it = start + off;
                    *slot = Some(f(it / blocks, it % blocks));
                }
            });
            start = end;
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Contiguous chunk ends (ascending, last == `heads * weight.len()`)
/// cutting the item grid into at most `threads` pieces of ~equal total
/// weight: chunk `c` closes once the running weight reaches its
/// proportional share `(c+1)/threads` of the total.  Item `it` weighs
/// `weight[it % blocks].max(1)` (the `max(1)` floor keeps zero-cost
/// blocks from starving a chunk) — indexed modularly, so no per-item
/// weight vector is materialized.  Every chunk is non-empty.
fn chunk_bounds(weight: &[u64], heads: usize, threads: usize) -> Vec<usize> {
    let blocks = weight.len();
    let items = heads * blocks;
    debug_assert!(threads >= 1 && threads <= items);
    let w = |it: usize| weight[it % blocks].max(1);
    let total: u64 = (0..items).map(w).sum();
    let mut bounds: Vec<usize> = Vec::with_capacity(threads);
    let mut acc = 0u64;
    for it in 0..items {
        acc += w(it);
        if bounds.len() + 1 < threads
            && acc * threads as u64 >= total * (bounds.len() as u64 + 1)
        {
            bounds.push(it + 1);
        }
    }
    if bounds.last() != Some(&items) {
        bounds.push(items);
    }
    bounds
}

/// Run `heads` independent single-head problems across OS threads —
/// [`parallel_2d`] degenerated to a single uniform-weight block per
/// head (the pre-row-block-partitioning behaviour, kept for callers
/// whose work really is one item per head).
pub fn parallel_heads<F, R>(heads: usize, max_threads: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
    R: Send,
{
    parallel_2d(heads, 1, &[1], max_threads, |h, _| f(h))
}

/// Reference finite-difference gradient check helper (tests only).
#[cfg(test)]
pub(crate) fn finite_diff_loss<F: Fn(&[f32]) -> f32>(
    f: F,
    x: &[f32],
    eps: f32,
) -> Vec<f32> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let orig = xp[i];
        xp[i] = orig + eps;
        let fp = f(&xp);
        xp[i] = orig - eps;
        let fm = f(&xp);
        xp[i] = orig;
        g[i] = (fp - fm) / (2.0 * eps);
    }
    g
}

/// Shared test fixtures.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::util::rng::Rng;

    pub fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }
}

#[allow(deprecated)]
pub use flash::{
    flashmask_backward, flashmask_forward, flashmask_forward_grouped,
    flashmask_forward_grouped_parallel,
};

/// Convenience: FLASHMASK forward for one head with stats.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).mask(&mask).tile(br, bc) + CpuBackend::prefill (DESIGN.md §Public API)"
)]
pub fn forward_single_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnOutput, TileStats) {
    let problem = api::AttnProblem::new(n, d)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip);
    let plan = problem.plan().expect("forward_single_head: invalid problem");
    let out = api::CpuBackend
        .prefill(
            &plan,
            api::QViews::new(q, 1, n, d).expect("forward_single_head: q shape"),
            api::KvViews::new(k, v, 1, n, d).expect("forward_single_head: k/v shape"),
        )
        .expect("forward_single_head: CPU prefill");
    let mut outs = out.outs;
    (outs.remove(0), out.stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_layout_groups_and_mapping() {
        let gqa = HeadLayout::new(8, 2);
        assert_eq!(gqa.group(), 4);
        assert_eq!(gqa.kv_head_of(0), 0);
        assert_eq!(gqa.kv_head_of(3), 0);
        assert_eq!(gqa.kv_head_of(4), 1);
        assert_eq!(gqa.kv_head_of(7), 1);
        assert!(!gqa.is_mha());
        assert!(HeadLayout::mha(4).is_mha());
        assert_eq!(HeadLayout::mha(4).group(), 1);
        assert_eq!(HeadLayout::mqa(6).kv_heads, 1);
        assert_eq!(HeadLayout::mqa(6).group(), 6);
        assert_eq!(format!("{}", gqa), "8q/2kv");
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn head_layout_rejects_indivisible() {
        HeadLayout::new(6, 4);
    }

    #[test]
    fn parallel_heads_more_threads_than_heads() {
        // satellite: max_threads > heads must not spawn empty chunks or
        // scramble the head -> result mapping
        for (heads, threads) in [(1usize, 4usize), (3, 8), (5, 16), (4, 4)] {
            let got = parallel_heads(heads, threads, |h| h * 10);
            let want: Vec<usize> = (0..heads).map(|h| h * 10).collect();
            assert_eq!(got, want, "heads={heads} threads={threads}");
        }
    }

    #[test]
    fn parallel_heads_zero_heads_is_empty() {
        let got: Vec<usize> = parallel_heads(0, 4, |h| h);
        assert!(got.is_empty());
    }

    #[test]
    fn parallel_2d_preserves_item_order() {
        // result order must be head-major, block-minor regardless of
        // thread count or weight skew
        let weights: Vec<u64> = vec![1, 100, 3, 7];
        for threads in [1usize, 2, 3, 8, 64] {
            let got = parallel_2d(3, 4, &weights, threads, |h, b| (h, b));
            let want: Vec<(usize, usize)> =
                (0..3).flat_map(|h| (0..4).map(move |b| (h, b))).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn parallel_2d_zero_weights_and_degenerate_grids() {
        // all-zero weights (fully masked row blocks) must not divide by
        // zero or starve items
        let got = parallel_2d(2, 3, &[0, 0, 0], 4, |h, b| h * 10 + b);
        assert_eq!(got, vec![0, 1, 2, 10, 11, 12]);
        // single item, many threads
        let got = parallel_2d(1, 1, &[5], 16, |h, b| (h, b));
        assert_eq!(got, vec![(0, 0)]);
        // empty grid
        let got: Vec<usize> = parallel_2d(0, 4, &[1, 1, 1, 1], 4, |_, b| b);
        assert!(got.is_empty());
    }

    #[test]
    fn chunk_bounds_balance_causal_triangle() {
        // a causal triangle of weights (row block bi visits bi+1 tiles):
        // equal-count chunking would give the last chunk ~44% of the
        // total weight; weighted chunking must keep every chunk near
        // its 25% share, so the tail rows can't stall one thread
        let weights: Vec<u64> = (0..64u64).map(|b| b + 1).collect();
        let total: u64 = weights.iter().sum();
        let bounds = chunk_bounds(&weights, 1, 4);
        assert_eq!(*bounds.last().unwrap(), 64);
        assert!(bounds.len() <= 4);
        let mut start = 0;
        for &end in &bounds {
            assert!(end > start, "chunks must be non-empty");
            let cw: u64 = weights[start..end].iter().sum();
            assert!(
                (cw as f64) < 0.40 * total as f64,
                "chunk [{start},{end}) holds {cw} of {total}"
            );
            start = end;
        }
    }

    #[test]
    fn chunk_bounds_handles_dominant_item() {
        // one item worth more than everything else: it absorbs several
        // proportional shares, and the remaining chunks stay non-empty
        let weights = vec![1u64, 1, 1000, 1, 1, 1];
        let bounds = chunk_bounds(&weights, 1, 4);
        assert_eq!(*bounds.last().unwrap(), 6);
        assert!(bounds.len() <= 4);
        assert!(bounds.windows(2).all(|w| w[1] > w[0]));
    }
}
