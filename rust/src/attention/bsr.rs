//! FlashInfer-like block-sparse-row (BSR) attention baseline
//! (paper appendix B, Tables 10–14).
//!
//! FlashInfer's `BlockSparseAttentionWrapper` takes a mask at block
//! granularity `R x C`: a block is either entirely visible or entirely
//! masked (the paper adapts its datasets so document boundaries land on
//! multiples of 64).  Small `R/C` fragments the work into many tiny
//! blocks — the padded-batch / tiny-gemm inefficiency the paper's
//! Tables 12–14 show — which this CPU engine reproduces naturally: the
//! per-block loop overhead and degenerate gemm shapes dominate at
//! `R = C = 1` and amortize away by `R = C = 64`.

use super::gemm;
use super::{AttnOutput, TileStats};
use anyhow::{ensure, Result};

/// CSR-of-blocks mask at granularity `rc x rc`.
pub struct BsrMask {
    pub rc: usize,
    pub n_blocks: usize, // per side
    /// CSR: for row-block `bi`, visible column blocks are
    /// `cols[row_ptr[bi]..row_ptr[bi+1]]`.
    pub row_ptr: Vec<usize>,
    pub cols: Vec<usize>,
}

impl BsrMask {
    /// Build from a predicate, requiring block alignment: every
    /// `rc x rc` block must be uniformly visible or uniformly masked.
    pub fn build(
        pred: &(dyn Fn(usize, usize) -> bool + Sync),
        n: usize,
        rc: usize,
    ) -> Result<BsrMask> {
        ensure!(n % rc == 0, "sequence {n} not divisible by block size {rc}");
        let nb = n / rc;
        let mut row_ptr = Vec::with_capacity(nb + 1);
        let mut cols = Vec::new();
        row_ptr.push(0);
        for bi in 0..nb {
            for bj in 0..nb {
                let first = pred(bi * rc, bj * rc);
                for i in bi * rc..(bi + 1) * rc {
                    for j in bj * rc..(bj + 1) * rc {
                        ensure!(
                            pred(i, j) == first,
                            "mask not aligned to {rc}-blocks at ({i},{j})"
                        );
                    }
                }
                if first {
                    cols.push(bj);
                }
            }
            row_ptr.push(cols.len());
        }
        Ok(BsrMask { rc, n_blocks: nb, row_ptr, cols })
    }

    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz_blocks() as f64 / (self.n_blocks * self.n_blocks) as f64
    }

    /// Index storage bytes (row_ptr + cols as i32 — FlashInfer's BSR ABI).
    pub fn bytes(&self) -> usize {
        4 * (self.row_ptr.len() + self.cols.len())
    }
}

/// BSR sparse attention forward: iterate only visible blocks, online
/// softmax per row-block of `rc` rows.  No element masking is ever
/// needed (block-aligned contract).
pub fn bsr_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    bsr: &BsrMask,
    scale: f32,
) -> (AttnOutput, TileStats) {
    let rc = bsr.rc;
    let nb = bsr.n_blocks;
    let mut out = vec![0f32; n * d];
    let mut lse = vec![f32::NEG_INFINITY; n];
    let mut stats = TileStats { tiles_total: nb * nb, ..Default::default() };

    let mut s = vec![0f32; rc * rc];
    let mut o_acc = vec![0f32; rc * d];
    let mut m_run = vec![f32::NEG_INFINITY; rc];
    let mut l_run = vec![0f32; rc];
    let mut alpha = vec![0f32; rc];

    for bi in 0..nb {
        let row0 = bi * rc;
        o_acc.fill(0.0);
        m_run.fill(f32::NEG_INFINITY);
        l_run.fill(0.0);
        let blocks = &bsr.cols[bsr.row_ptr[bi]..bsr.row_ptr[bi + 1]];
        stats.tiles_skipped += nb - blocks.len();
        for &bj in blocks {
            let col0 = bj * rc;
            s.fill(0.0);
            gemm::matmul_nt_acc(
                &q[row0 * d..(row0 + rc) * d],
                &k[col0 * d..(col0 + rc) * d],
                rc,
                d,
                rc,
                &mut s,
            );
            stats.macs += (rc * rc * d) as u64;
            for sv in s.iter_mut() {
                *sv *= scale;
            }
            for x in 0..rc {
                let srow = &mut s[x * rc..(x + 1) * rc];
                let mut row_max = f32::NEG_INFINITY;
                for &sv in srow.iter() {
                    row_max = row_max.max(sv);
                }
                let m_new = m_run[x].max(row_max);
                let a = if m_run[x].is_finite() { (m_run[x] - m_new).exp() } else { 0.0 };
                let mut row_sum = 0f32;
                for sv in srow.iter_mut() {
                    let p = (*sv - m_new).exp();
                    *sv = p;
                    row_sum += p;
                }
                l_run[x] = a * l_run[x] + row_sum;
                m_run[x] = m_new;
                alpha[x] = a;
            }
            gemm::scale_rows(&mut o_acc, &alpha, rc, d);
            gemm::matmul_nn_acc(&s, &v[col0 * d..(col0 + rc) * d], rc, rc, d, &mut o_acc);
            stats.macs += (rc * rc * d) as u64;
            stats.tiles_unmasked += 1;
        }
        for x in 0..rc {
            let i = row0 + x;
            if l_run[x] > 0.0 {
                let inv = 1.0 / l_run[x];
                for dd in 0..d {
                    out[i * d + dd] = o_acc[x * d + dd] * inv;
                }
                lse[i] = m_run[x] + l_run[x].ln();
            }
        }
    }
    (AttnOutput { o: out, lse }, stats)
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::testutil::rand_vec;
    use crate::attention::dense;
    use crate::mask::builders;
    use crate::util::rng::Rng;

    fn aligned_doc_mask(n: usize, rc: usize) -> crate::mask::FlashMask {
        // two docs with lengths divisible by rc
        let half = (n / 2 / rc) * rc;
        builders::document(n, &[half, n - half])
    }

    #[test]
    fn bsr_matches_dense_on_aligned_doc_mask() {
        let (n, d, rc) = (128, 8, 16);
        let mask = aligned_doc_mask(n, rc);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let bsr = BsrMask::build(&pred, n, rc).unwrap();
        let mut rng = Rng::new(1);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let (got, _) = bsr_forward(&q, &k, &v, n, d, &bsr, 0.35);
        let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), 0.35);
        for (a, b) in got.o.iter().zip(&want.o) {
            assert!((a - b).abs() < 2e-5);
        }
    }

    #[test]
    fn rejects_unaligned_mask() {
        let n = 64;
        let mask = builders::causal(n); // diagonal never block-aligned
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        assert!(BsrMask::build(&pred, n, 16).is_err());
    }

    #[test]
    fn sparsity_accounting() {
        let n = 64;
        let mask = aligned_doc_mask(n, 8);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let bsr = BsrMask::build(&pred, n, 8).unwrap();
        assert!((bsr.sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(bsr.nnz_blocks(), 32);
    }

    #[test]
    fn same_result_across_block_sizes() {
        let (n, d) = (128, 8);
        let mask = aligned_doc_mask(n, 32);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let mut rng = Rng::new(2);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let mut reference: Option<Vec<f32>> = None;
        for rc in [1usize, 2, 4, 8, 16, 32] {
            let bsr = BsrMask::build(&pred, n, rc).unwrap();
            let (got, _) = bsr_forward(&q, &k, &v, n, d, &bsr, 0.3);
            if let Some(r) = &reference {
                for (a, b) in got.o.iter().zip(r) {
                    assert!((a - b).abs() < 2e-5, "rc={rc}");
                }
            } else {
                reference = Some(got.o);
            }
        }
    }

    #[test]
    fn smaller_blocks_same_flops_more_blocks() {
        let n = 128;
        let mask = aligned_doc_mask(n, 32);
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let small = BsrMask::build(&pred, n, 4).unwrap();
        let large = BsrMask::build(&pred, n, 32).unwrap();
        // identical covered area, very different block counts
        assert_eq!(small.nnz_blocks() * 16, large.nnz_blocks() * 1024);
    }
}
