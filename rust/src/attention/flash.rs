//! FLASHMASK blocked attention — paper Algorithm 1 (forward) and
//! Algorithm 2 (backward), executed tile-for-tile on the CPU.
//!
//! `skip = true` enables the Eq. 4 classification (the contribution);
//! `skip = false` is the "FlashAttention dense mask" baseline: identical
//! arithmetic over *all* tiles, so the two are bitwise-equal — the
//! paper's §4.4 exactness claim, asserted in the tests below.

use super::api::{self, Backend as _};
use super::gemm;
use super::{AttnConfig, AttnGrads, AttnOutput, HeadLayout, TileStats};
use crate::mask::{BlockClass, BlockTable, FlashMask};

const NEG_INF: f32 = f32::NEG_INFINITY;

/// Apply the element-wise interval mask to a score tile
/// (paper Alg. 1 lines 19/23 + implicit-causal diagonal test).
#[inline]
fn apply_tile_mask(
    s: &mut [f32],
    mask: &FlashMask,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    stats: &mut TileStats,
) {
    for x in 0..rows {
        let i = (row0 + x) as i32;
        let srow = &mut s[x * cols..(x + 1) * cols];
        for (y, sv) in srow.iter_mut().enumerate() {
            let j = col0 + y;
            let mut masked = mask.causal && i < j as i32;
            masked |= i >= mask.lts[j] && i < mask.lte[j];
            if !mask.causal {
                masked |= i >= mask.uts[j] && i < mask.ute[j];
            }
            if masked {
                *sv = NEG_INF;
            }
        }
    }
    stats.mask_evals += (rows * cols) as u64;
}

/// Replay a precomputed per-tile mask byte map (1 = masked) over a
/// score tile — the cache-hit path of [`apply_tile_mask`].  Branch-free
/// select so the pass vectorizes.
#[inline]
fn apply_tile_mask_cached(s: &mut [f32], bits: &[u8]) {
    debug_assert_eq!(s.len(), bits.len());
    for (sv, &m) in s.iter_mut().zip(bits) {
        *sv = if m != 0 { NEG_INF } else { *sv };
    }
}

/// Tile decision shared by forward and backward.
#[inline]
pub(crate) fn tile_class(
    mask: &FlashMask,
    table: &BlockTable,
    bi: usize,
    br: usize,
    bj: usize,
    bc: usize,
    skip: bool,
) -> BlockClass {
    if skip {
        table.classify(mask, bi, br, bj, bc)
    } else {
        // dense-mask baseline: every tile computes + element-masks
        BlockClass::PartiallyMasked
    }
}

/// Interval-driven tile schedule: the Eq. 4 classification of every
/// `(bi, bj)` tile **plus** a per-row-block visit range `[bj_lo,
/// bj_hi)` bounding the non-fully-masked column blocks, derived in the
/// same single sweep over the column intervals.  The compute loop runs
/// `bj in bj_lo..bj_hi` instead of the dense `0..tc` scan, so tiles
/// outside the range are never visited at all (the
/// visit-only-needed-tiles observation of Binary Block Masking,
/// PAPERS.md) — for contiguous-visibility masks (causal, windows,
/// documents) the trip count equals the executed-tile count.
///
/// The schedule is a property of the mask alone — no head data enters
/// it — which is what lets the grouped kernel build it once per KV
/// head and reuse it across the whole query group (and the serving
/// engine share one schedule across all heads of a request).  The
/// per-row-block executed-tile counts double as the
/// [`super::parallel_2d`] cost weights.
pub(crate) struct TileSchedule {
    pub tr: usize,
    pub tc: usize,
    classes: Vec<BlockClass>,
    ranges: Vec<(usize, usize)>,
    /// Executed (non-fully-masked) tiles per row block — the
    /// work-partitioning weight.
    executed: Vec<u64>,
    /// Per-tile mask cache: for every `Partial` tile (when the Eq. 4
    /// classification is on) the element-wise interval tests are run
    /// **once here** and materialized as a `rows*cols` byte map
    /// (1 = masked).  Every compute pass — each query head of a GQA
    /// group, each row-block thread, each repeated call through a
    /// cached [`api::ExecutionPlan`] — applies the precomputed map
    /// instead of re-testing the intervals.  `tile_off[t]..tile_off[t+1]`
    /// indexes tile `t`'s bytes; non-partial tiles have zero extent.
    /// Bounded by [`Self::MASK_CACHE_BYTES`] — partial tiles past the
    /// budget stay uncached and fall back to per-pass element-wise
    /// tests.  Empty in dense-baseline schedules (`skip = false`),
    /// which keep the per-pass element-wise masking the baseline is
    /// meant to pay.
    masked: Vec<u8>,
    tile_off: Vec<usize>,
    /// Interval tests performed building the cache (the one-time cost a
    /// plan charges as `mask_evals`).
    build_mask_evals: u64,
}

impl TileSchedule {
    /// Cap on materialized mask-cache bytes per schedule.  Partial
    /// tiles are worst-case O(n²) elements (e.g. eviction masks where
    /// most lower-triangle tiles are partial), so an unbounded cache
    /// would trade the representation's O(n) memory story away at long
    /// context — and `PlanCache` retains up to 64 plans.  Tiles past
    /// the budget simply stay uncached and fall back to the per-pass
    /// element-wise interval tests (bitwise-identical results).
    pub const MASK_CACHE_BYTES: usize = 4 << 20;

    pub fn build(
        mask: &FlashMask,
        table: &BlockTable,
        n: usize,
        cfg: AttnConfig,
        skip: bool,
    ) -> TileSchedule {
        let sp = crate::telemetry::trace::span("plan.classify");
        let (br, bc) = (cfg.br, cfg.bc);
        let (tr, tc) = (n.div_ceil(br), n.div_ceil(bc));
        let mut classes = Vec::with_capacity(tr * tc);
        let mut ranges = Vec::with_capacity(tr);
        let mut executed = Vec::with_capacity(tr);
        let mut masked = Vec::new();
        let mut tile_off = Vec::with_capacity(tr * tc + 1);
        tile_off.push(0);
        let mut build_mask_evals = 0u64;
        for bi in 0..tr {
            let (mut lo, mut hi) = (0usize, 0usize);
            let mut exec = 0u64;
            let row0 = bi * br;
            let rows = br.min(n - row0);
            for bj in 0..tc {
                let class = tile_class(mask, table, bi, br, bj, bc, skip);
                if class != BlockClass::FullyMasked {
                    if exec == 0 {
                        lo = bj;
                    }
                    hi = bj + 1;
                    exec += 1;
                }
                if skip && class == BlockClass::PartiallyMasked {
                    let col0 = bj * bc;
                    let cols = bc.min(n - col0);
                    if masked.len() + rows * cols <= Self::MASK_CACHE_BYTES {
                        // run the interval tests once; every pass
                        // replays the byte map
                        for x in 0..rows {
                            let i = row0 + x;
                            for y in 0..cols {
                                masked.push(u8::from(!mask.allowed(i, col0 + y)));
                            }
                        }
                        build_mask_evals += (rows * cols) as u64;
                    }
                    // over budget: tile stays uncached; compute passes
                    // keep the element-wise tests for it
                }
                tile_off.push(masked.len());
                classes.push(class);
            }
            // a fully-masked row block never set lo/hi: range stays (0, 0)
            ranges.push((lo, hi));
            executed.push(exec);
        }
        sp.add("mask_evals", build_mask_evals);
        TileSchedule { tr, tc, classes, ranges, executed, masked, tile_off, build_mask_evals }
    }

    #[inline]
    pub fn class(&self, bi: usize, bj: usize) -> BlockClass {
        self.classes[bi * self.tc + bj]
    }

    /// Column-block visit range `[bj_lo, bj_hi)` for row block `bi`.
    #[inline]
    pub fn range(&self, bi: usize) -> (usize, usize) {
        self.ranges[bi]
    }

    /// Per-row-block executed-tile counts ([`super::parallel_2d`] weights).
    pub fn weights(&self) -> &[u64] {
        &self.executed
    }

    /// All tile classes, row-major (`tr * tc`) — the census input.
    pub fn classes(&self) -> &[BlockClass] {
        &self.classes
    }

    pub fn build_mask_evals(&self) -> u64 {
        self.build_mask_evals
    }

    /// The cached `rows*cols` mask bytes of tile `(bi, bj)`, if the
    /// tile is partial and the cache was built (`skip = true`).
    #[inline]
    pub fn tile_mask(&self, bi: usize, bj: usize) -> Option<&[u8]> {
        let t = bi * self.tc + bj;
        let (s, e) = (self.tile_off[t], self.tile_off[t + 1]);
        if s == e {
            None
        } else {
            Some(&self.masked[s..e])
        }
    }

    /// One classification pass's tile census plus the cache build cost
    /// — what [`api::ExecutionPlan`] charges per KV head.
    pub fn census(&self) -> TileStats {
        let mut stats = TileStats::default();
        add_census(&mut stats, &self.classes);
        stats.mask_evals = self.build_mask_evals;
        stats
    }
}

/// Charge one classification pass's tile census to `stats`.  Every
/// non-skipped tile is executed, so the census equals the per-tile
/// counters the execution loop would have accumulated.
fn add_census(stats: &mut TileStats, classes: &[BlockClass]) {
    stats.tiles_total += classes.len();
    for c in classes {
        match c {
            BlockClass::FullyMasked => stats.tiles_skipped += 1,
            BlockClass::PartiallyMasked => stats.tiles_partial += 1,
            BlockClass::Unmasked => stats.tiles_unmasked += 1,
        }
    }
}

/// Algorithm 1 compute loop for **one row block** of one query head
/// against one (packed) KV head, driven by the interval schedule.
/// Returns the row block's `[rows, d]` output and `[rows]` logsumexp;
/// accumulates the compute-side counters (`macs`, `mask_evals`,
/// `tiles_visited`) into `stats`.  This is the unit of
/// [`super::parallel_2d`] work partitioning — row blocks are
/// independent, so the parallel and sequential paths are
/// bitwise-identical.
///
/// Partial tiles replay the schedule's per-tile mask cache (interval
/// tests run once at schedule build and are shared across the whole
/// query group and across repeated plan-cached calls — the decode
/// kernels' classify-once reuse, brought to prefill); dense-baseline
/// schedules (`skip = false`) fall back to per-pass element-wise tests.
pub(crate) fn forward_row_block(
    q: &[f32],
    kt: &gemm::PackedKt,
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    bi: usize,
    stats: &mut TileStats,
) -> (Vec<f32>, Vec<f32>) {
    let (br, bc) = (cfg.br, cfg.bc);
    debug_assert_eq!(kt.bc(), bc);
    let row0 = bi * br;
    let rows = br.min(n - row0);

    // pack the Q row block once; every visited tile streams it
    let mut q_pack = gemm::PackedBlock::new();
    q_pack.pack(&q[row0 * d..(row0 + rows) * d], rows, d);

    let mut out = vec![0f32; rows * d];
    let mut lse = vec![NEG_INF; rows];
    let mut s = vec![0f32; rows * bc];
    let mut o_acc = vec![0f32; rows * d];
    let mut m_run = vec![NEG_INF; rows];
    let mut l_run = vec![0f32; rows];
    let mut alpha = vec![0f32; rows];

    let (bj_lo, bj_hi) = sched.range(bi);
    for bj in bj_lo..bj_hi {
        stats.tiles_visited += 1;
        let class = sched.class(bi, bj);
        if class == BlockClass::FullyMasked {
            continue; // interior hole (non-contiguous mask): branch only
        }
        let col0 = bj * bc;
        let cols = bc.min(n - col0);

        // S = (Q_i K_j^T) * scale — scale fused into the microkernel,
        // no zeroing pass (the kernel writes, not accumulates)
        let s_tile = &mut s[..rows * cols];
        gemm::matmul_nt_packed(&q_pack, kt.block(bj), cfg.scale, s_tile);
        stats.macs += (rows * cols * d) as u64;

        if class == BlockClass::PartiallyMasked {
            if let Some(bits) = sched.tile_mask(bi, bj) {
                // per-tile mask cache: interval tests ran once at
                // schedule build; replay the byte map (same positions,
                // bitwise-identical scores)
                apply_tile_mask_cached(s_tile, bits);
                stats.mask_cache_hits += 1;
            } else {
                apply_tile_mask(s_tile, mask, row0, rows, col0, cols, stats);
            }
        }

        // online softmax update (Alg. 1 lines 25-26): one lane-parallel
        // max sweep + one fused exp/accumulate sweep per row
        for x in 0..rows {
            let srow = &mut s_tile[x * cols..(x + 1) * cols];
            let m_new = m_run[x].max(gemm::row_max(srow));
            let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
            let a = if m_run[x].is_finite() { (m_run[x] - m_safe).exp() } else { 0.0 };
            l_run[x] = a * l_run[x] + gemm::exp_sub_sum(srow, m_safe);
            m_run[x] = m_new;
            alpha[x] = a;
        }
        gemm::scale_rows(&mut o_acc, &alpha[..rows], rows, d);
        // O += P V_j
        gemm::matmul_nn_acc(s_tile, &v[col0 * d..(col0 + cols) * d], rows, cols, d, &mut o_acc);
        stats.macs += (rows * cols * d) as u64;
    }

    // finalize (Alg. 1 lines 28-29)
    for x in 0..rows {
        if l_run[x] > 0.0 {
            let inv = 1.0 / l_run[x];
            for dd in 0..d {
                out[x * d + dd] = o_acc[x * d + dd] * inv;
            }
            let m_safe = if m_run[x].is_finite() { m_run[x] } else { 0.0 };
            lse[x] = m_safe + l_run[x].ln();
        } // fully-masked row: output stays 0, lse stays -inf
    }
    (out, lse)
}

/// Algorithm 1 compute loop for one query head against one packed KV
/// head — the sequential row-block walk over [`forward_row_block`].
pub(crate) fn forward_tiles(
    q: &[f32],
    kt: &gemm::PackedKt,
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    stats: &mut TileStats,
) -> AttnOutput {
    let mut out = vec![0f32; n * d];
    let mut lse = vec![NEG_INF; n];
    for bi in 0..sched.tr {
        let row0 = bi * cfg.br;
        let (ob, lb) = forward_row_block(q, kt, v, n, d, mask, cfg, sched, bi, stats);
        out[row0 * d..row0 * d + ob.len()].copy_from_slice(&ob);
        lse[row0..row0 + lb.len()].copy_from_slice(&lb);
    }
    AttnOutput { o: out, lse }
}

/// Algorithm 1 — forward pass for a single head.
///
/// `q,k,v`: row-major `[n, d]`.  Returns output, per-row logsumexp, and
/// tile/work counters.
///
/// Deprecated shim over [`api`]: builds a one-shot
/// [`api::AttnProblem`] and runs [`api::CpuBackend`], so the
/// differential suites pinned to this entry point double as migration
/// tests.  The passed `table` is ignored — the plan rebuilds an
/// identical one from the same mask and `cfg.bc` (deterministic).
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).mask(&mask).tile(br, bc) + CpuBackend::prefill (DESIGN.md §Public API)"
)]
pub fn flashmask_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnOutput, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .plan()
        .expect("flashmask_forward: invalid problem");
    let out = api::CpuBackend
        .prefill(
            &plan,
            api::QViews::new(q, 1, n, d).expect("flashmask_forward: q must be [n, d]"),
            api::KvViews::new(k, v, 1, n, d).expect("flashmask_forward: k/v must be [n, d]"),
        )
        .expect("flashmask_forward: CPU prefill");
    let mut outs = out.outs;
    (outs.remove(0), out.stats)
}

/// Algorithm 1 forward over a grouped head layout: Q `[q_heads, n, d]`
/// against shared K/V `[kv_heads, n, d]`.
///
/// The Eq. 4 tile classification is computed **once per KV head** and
/// reused by that head's whole query group — the skip decision is a
/// property of the key columns alone (§4.1), so sharing KV heads also
/// shares the classification.  `TileStats` tile denominators therefore
/// count `kv_heads · tiles`, not `q_heads · tiles`: at group size `g`
/// the classification cost and the skip-accounting denominators drop
/// by `g` while per-query-head MACs are unchanged.
///
/// Returns one [`AttnOutput`] per query head, in query-head order.
/// With an MHA layout this is bitwise-identical to calling
/// [`flashmask_forward`] once per head.
///
/// Deprecated shim over [`api`] (single-threaded); see
/// [`flashmask_forward`] for the migration contract.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).layout(layout).mask(&mask) + CpuBackend::prefill_grouped (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_forward_grouped(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (Vec<AttnOutput>, TileStats) {
    grouped_shim(q, k, v, n, d, layout, mask, table, cfg, skip, 1)
}

/// [`flashmask_forward_grouped`] with (head × row-block) work
/// partitioning across up to `max_threads` OS threads.
///
/// The grid of `q_heads · ⌈n/Br⌉` row-block items is cut into
/// cost-weighted contiguous chunks by [`super::parallel_2d`] (weight =
/// executed tiles per row block from the interval schedule), so a
/// single long 1-head sequence saturates every core and causal
/// workloads don't tail-stall on the heavy last rows.  Row blocks are
/// independent in Algorithm 1, so the result is **bitwise identical**
/// to the sequential kernel at any thread count (asserted in the tests
/// below).  The Eq. 4 schedule is built once per mask and each KV
/// head's K is packed once; both are shared read-only across all
/// threads and all query heads of the head's group.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).layout(layout).mask(&mask).threads(t) + CpuBackend::prefill_grouped (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_forward_grouped_parallel(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
    max_threads: usize,
) -> (Vec<AttnOutput>, TileStats) {
    grouped_shim(q, k, v, n, d, layout, mask, table, cfg, skip, max_threads)
}

/// Shared body of the two deprecated grouped entry points: build a
/// one-shot [`api::AttnProblem`] and run [`api::CpuBackend`].
#[allow(clippy::too_many_arguments)]
fn grouped_shim(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
    max_threads: usize,
) -> (Vec<AttnOutput>, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .layout(layout)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .threads(max_threads)
        .plan()
        .expect("grouped forward: invalid problem");
    let out = api::CpuBackend
        .prefill_grouped(
            &plan,
            api::QViews::new(q, layout.q_heads, n, d)
                .expect("grouped forward: q must be [q_heads, n, d]"),
            api::KvViews::new(k, v, layout.kv_heads, n, d)
                .expect("grouped forward: k/v must be [kv_heads, n, d]"),
        )
        .expect("grouped forward: CPU prefill");
    (out.outs, out.stats)
}

/// Algorithm 2 — backward pass for a single head.
///
/// Deprecated shim over [`api`]; see [`flashmask_forward`] for the
/// migration contract.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).mask(&mask) + CpuBackend::backward (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnGrads, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .plan()
        .expect("flashmask_backward: invalid problem");
    api::CpuBackend
        .backward(&plan, q, k, v, o, do_, lse)
        .expect("flashmask_backward: CPU backward")
}

/// Algorithm 2 backward body, driven by the interval schedule.
///
/// Column-parallel over key blocks exactly like the paper: `K_j`/`V_j`
/// and the interval vectors stay resident across the inner row loop, and
/// `dQ_i` is accumulated in the output buffer (Alg. 2 line 31).
/// Partial tiles replay the schedule's per-tile mask cache when it was
/// built (`skip = true`), so the element-wise interval tests run once
/// per plan instead of once per tile visit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_impl(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
) -> (AttnGrads, TileStats) {
    let (br, bc) = (cfg.br, cfg.bc);
    let tr = sched.tr;
    let tc = sched.tc;
    let mut dq = vec![0f32; n * d];
    let mut dk = vec![0f32; n * d];
    let mut dv = vec![0f32; n * d];
    let mut stats = TileStats {
        tiles_total: tr * tc,
        mask_evals: sched.build_mask_evals(),
        ..Default::default()
    };

    // D = rowsum(dO ∘ O)  (Alg. 2 line 4)
    let mut dvec = vec![0f32; n];
    for i in 0..n {
        let mut acc = 0f32;
        for dd in 0..d {
            acc += do_[i * d + dd] * o[i * d + dd];
        }
        dvec[i] = acc;
    }

    let mut s = vec![0f32; br * bc];
    let mut dp = vec![0f32; br * bc];

    for bj in 0..tc {
        let col0 = bj * bc;
        let cols = bc.min(n - col0);
        let kj = &k[col0 * d..(col0 + cols) * d];
        let vj = &v[col0 * d..(col0 + cols) * d];

        for bi in 0..tr {
            let class = sched.class(bi, bj);
            if class == BlockClass::FullyMasked {
                stats.tiles_skipped += 1;
                continue;
            }
            let row0 = bi * br;
            let rows = br.min(n - row0);
            let qi = &q[row0 * d..(row0 + rows) * d];
            let doi = &do_[row0 * d..(row0 + rows) * d];

            // S = Q_i K_j^T * scale (Alg. 2 line 20)
            let s_tile = &mut s[..rows * cols];
            s_tile.fill(0.0);
            gemm::matmul_nt_acc(qi, kj, rows, d, cols, s_tile);
            stats.macs += (rows * cols * d) as u64;
            for sv in s_tile.iter_mut() {
                *sv *= cfg.scale;
            }
            if class == BlockClass::PartiallyMasked {
                if let Some(bits) = sched.tile_mask(bi, bj) {
                    apply_tile_mask_cached(s_tile, bits);
                    stats.mask_cache_hits += 1;
                } else {
                    apply_tile_mask(s_tile, mask, row0, rows, col0, cols, &mut stats);
                }
                stats.tiles_partial += 1;
            } else {
                stats.tiles_unmasked += 1;
            }

            // P = exp(S - L_i) (Alg. 2 line 27); masked rows have
            // lse = -inf => P = 0
            for x in 0..rows {
                let l = lse[row0 + x];
                let srow = &mut s_tile[x * cols..(x + 1) * cols];
                if l.is_finite() {
                    for sv in srow.iter_mut() {
                        *sv = (*sv - l).exp();
                    }
                } else {
                    srow.fill(0.0);
                }
            }

            // dV_j += P^T dO_i (line 28)
            gemm::matmul_tn_acc(s_tile, doi, rows, cols, d, &mut dv[col0 * d..(col0 + cols) * d]);
            stats.macs += (rows * cols * d) as u64;

            // dP = dO_i V_j^T (line 29)
            let dp_tile = &mut dp[..rows * cols];
            dp_tile.fill(0.0);
            gemm::matmul_nt_acc(doi, vj, rows, d, cols, dp_tile);
            stats.macs += (rows * cols * d) as u64;

            // dS = P ∘ (dP - D_i) * scale (line 30)
            for x in 0..rows {
                let dv_i = dvec[row0 + x];
                for y in 0..cols {
                    let idx = x * cols + y;
                    dp_tile[idx] = s_tile[idx] * (dp_tile[idx] - dv_i) * cfg.scale;
                }
            }

            // dQ_i += dS K_j (line 31)
            gemm::matmul_nn_acc(dp_tile, kj, rows, cols, d, &mut dq[row0 * d..(row0 + rows) * d]);
            stats.macs += (rows * cols * d) as u64;
            // dK_j += dS^T Q_i (line 32)
            gemm::matmul_tn_acc(dp_tile, qi, rows, cols, d, &mut dk[col0 * d..(col0 + cols) * d]);
            stats.macs += (rows * cols * d) as u64;
        }
    }
    (AttnGrads { dq, dk, dv }, stats)
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::dense;
    use crate::attention::testutil::rand_vec;
    use crate::mask::builders;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng))
    }

    #[test]
    fn forward_matches_dense_all_masks() {
        let (n, d) = (128, 16);
        let (q, k, v) = setup(n, d, 1);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 3) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
            for (i, (a, b)) in got.o.iter().zip(&want.o).enumerate() {
                assert!((a - b).abs() < 2e-5, "{kind} o[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn skip_is_bitwise_noop() {
        // the paper's §4.4 exactness claim, on this engine
        let (n, d) = (128, 16);
        let (q, k, v) = setup(n, d, 2);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 5) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (a, sa) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let (b, sb) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, false);
            assert_eq!(a.o, b.o, "{kind}: forward outputs differ");
            assert_eq!(a.lse, b.lse, "{kind}: lse differ");
            assert!(sa.macs <= sb.macs, "{kind}: skip did not reduce work");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let (n, d) = (32, 8);
        let (q, k, v) = setup(n, d, 3);
        let mask = builders::causal_document(n, &[14, 10, 8]);
        let cfg = AttnConfig::new(8, 8, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (fwd, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        // loss = sum(O * W) with fixed random W
        let mut rng = Rng::new(9);
        let w = rand_vec(n * d, &mut rng);
        let do_: Vec<f32> = w.clone();
        let (grads, _) = flashmask_backward(
            &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, true,
        );
        let loss = |q_: &[f32], k_: &[f32], v_: &[f32]| -> f32 {
            let (f, _) = flashmask_forward(q_, k_, v_, n, d, &mask, &table, cfg, true);
            f.o.iter().zip(&w).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        let fd_q = crate::attention::finite_diff_loss(|x| loss(x, &k, &v), &q, eps);
        let fd_k = crate::attention::finite_diff_loss(|x| loss(&q, x, &v), &k, eps);
        let fd_v = crate::attention::finite_diff_loss(|x| loss(&q, &k, x), &v, eps);
        for (name, got, want) in
            [("dq", &grads.dq, &fd_q), ("dk", &grads.dk, &fd_k), ("dv", &grads.dv, &fd_v)]
        {
            for i in 0..n * d {
                assert!(
                    (got[i] - want[i]).abs() < 5e-3,
                    "{name}[{i}]: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn backward_skip_bitwise_noop() {
        let (n, d) = (64, 8);
        let (q, k, v) = setup(n, d, 4);
        for (kind, mask) in builders::benchmark_suite(n, 6) {
            let cfg = AttnConfig::new(16, 16, d);
            let table = BlockTable::build(&mask, cfg.bc);
            let (fwd, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let mut rng = Rng::new(10);
            let do_ = rand_vec(n * d, &mut rng);
            let (g1, _) = flashmask_backward(
                &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, true,
            );
            let (g2, _) = flashmask_backward(
                &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, false,
            );
            assert_eq!(g1.dq, g2.dq, "{kind} dq");
            assert_eq!(g1.dk, g2.dk, "{kind} dk");
            assert_eq!(g1.dv, g2.dv, "{kind} dv");
        }
    }

    #[test]
    fn grouped_forward_matches_per_head_bitwise() {
        // GQA: each query head scored against its group's shared KV head
        // must equal the single-head kernel on that (q, kv) pair bitwise,
        // and the tile census must count KV heads, not query heads
        let (n, d) = (96, 8);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(21);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 7) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (outs, gs) =
                flashmask_forward_grouped(&q, &k, &v, n, d, layout, &mask, &table, cfg, true);
            assert_eq!(outs.len(), layout.q_heads);
            let mut per_head = TileStats::default();
            for h in 0..layout.q_heads {
                let kh = layout.kv_head_of(h);
                let (want, st) = flashmask_forward(
                    &q[h * n * d..(h + 1) * n * d],
                    &k[kh * n * d..(kh + 1) * n * d],
                    &v[kh * n * d..(kh + 1) * n * d],
                    n,
                    d,
                    &mask,
                    &table,
                    cfg,
                    true,
                );
                per_head.merge(&st);
                assert_eq!(outs[h].o, want.o, "{kind} head {h}: outputs differ");
                assert_eq!(outs[h].lse, want.lse, "{kind} head {h}: lse differ");
            }
            // classification reuse: tile denominators shrink by the group
            // factor while per-query-head MACs are unchanged
            assert_eq!(gs.tiles_total * layout.group(), per_head.tiles_total, "{kind}");
            assert_eq!(gs.tiles_skipped * layout.group(), per_head.tiles_skipped, "{kind}");
            assert_eq!(gs.macs, per_head.macs, "{kind}: MACs must not change");
            // per-tile mask cache: the element-wise interval tests run
            // once per KV head (at schedule build), not once per query
            // head — the whole group replays the cached byte maps
            assert_eq!(
                gs.mask_evals * layout.group(),
                per_head.mask_evals,
                "{kind}: interval tests must be shared across the query group"
            );
            if gs.tiles_partial > 0 {
                assert!(gs.mask_cache_hits > 0, "{kind}: partial tiles must hit the cache");
            }
        }
    }

    #[test]
    fn grouped_forward_mha_layout_matches_single_head_kernel() {
        // kv_heads == q_heads must reproduce the ungrouped path bitwise
        let (n, d) = (64, 8);
        let heads = 3;
        let mut rng = Rng::new(22);
        let q = rand_vec(heads * n * d, &mut rng);
        let k = rand_vec(heads * n * d, &mut rng);
        let v = rand_vec(heads * n * d, &mut rng);
        let mask = builders::causal_document(n, &[30, 20, 14]);
        let cfg = AttnConfig::new(16, 16, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (outs, _) = flashmask_forward_grouped(
            &q, &k, &v, n, d, HeadLayout::mha(heads), &mask, &table, cfg, true,
        );
        for h in 0..heads {
            let r = h * n * d..(h + 1) * n * d;
            let (want, _) = flashmask_forward(
                &q[r.clone()], &k[r.clone()], &v[r], n, d, &mask, &table, cfg, true,
            );
            assert_eq!(outs[h].o, want.o, "head {h}");
        }
    }

    #[test]
    fn stats_reflect_sparsity() {
        let n = 256;
        let mask = builders::causal(n);
        let cfg = AttnConfig::new(32, 32, 16);
        let table = BlockTable::build(&mask, cfg.bc);
        let (q, k, v) = setup(n, 16, 5);
        let (_, st) = flashmask_forward(&q, &k, &v, n, 16, &mask, &table, cfg, true);
        assert_eq!(st.tiles_total, 64);
        assert_eq!(st.tiles_skipped, 28); // strictly-above-diagonal tiles
        assert_eq!(st.tiles_partial, 8); // diagonal tiles
        assert_eq!(st.tiles_unmasked, 28);
    }

    #[test]
    fn ragged_tail_tiles() {
        // n not divisible by tile sizes
        let (n, d) = (100, 8);
        let (q, k, v) = setup(n, d, 6);
        let mask = builders::causal_document(n, &[37, 63]);
        let cfg = AttnConfig::new(32, 16, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
        for (a, b) in got.o.iter().zip(&want.o) {
            assert!((a - b).abs() < 2e-5);
        }
    }

    /// The pre-refactor forward path, kept verbatim as a differential
    /// oracle: loose-layout `matmul_nt_acc`, separate scale pass,
    /// scalar per-row online softmax, dense `for bj in 0..tc` scan with
    /// per-tile branch skipping.  The register-blocked/packed/
    /// interval-scheduled kernel must match it within 1e-5 (different
    /// float-accumulation order, identical math).
    #[allow(clippy::too_many_arguments)]
    fn reference_forward(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        mask: &FlashMask,
        table: &BlockTable,
        cfg: AttnConfig,
        skip: bool,
    ) -> AttnOutput {
        let (br, bc) = (cfg.br, cfg.bc);
        let (tr, tc) = (n.div_ceil(br), n.div_ceil(bc));
        let mut out = vec![0f32; n * d];
        let mut lse = vec![NEG_INF; n];
        let mut s = vec![0f32; br * bc];
        for bi in 0..tr {
            let row0 = bi * br;
            let rows = br.min(n - row0);
            let mut o_acc = vec![0f32; rows * d];
            let mut m_run = vec![NEG_INF; rows];
            let mut l_run = vec![0f32; rows];
            for bj in 0..tc {
                if tile_class(mask, table, bi, br, bj, bc, skip) == BlockClass::FullyMasked {
                    continue;
                }
                let col0 = bj * bc;
                let cols = bc.min(n - col0);
                let s_tile = &mut s[..rows * cols];
                s_tile.fill(0.0);
                gemm::matmul_nt_acc(
                    &q[row0 * d..(row0 + rows) * d],
                    &k[col0 * d..(col0 + cols) * d],
                    rows,
                    d,
                    cols,
                    s_tile,
                );
                for sv in s_tile.iter_mut() {
                    *sv *= cfg.scale;
                }
                let mut dummy = TileStats::default();
                apply_tile_mask(s_tile, mask, row0, rows, col0, cols, &mut dummy);
                for x in 0..rows {
                    let srow = &mut s_tile[x * cols..(x + 1) * cols];
                    let mut row_max = NEG_INF;
                    for &sv in srow.iter() {
                        row_max = row_max.max(sv);
                    }
                    let m_new = m_run[x].max(row_max);
                    let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
                    let a = if m_run[x].is_finite() { (m_run[x] - m_safe).exp() } else { 0.0 };
                    let mut row_sum = 0f32;
                    for sv in srow.iter_mut() {
                        let p = (*sv - m_safe).exp();
                        *sv = p;
                        row_sum += p;
                    }
                    l_run[x] = a * l_run[x] + row_sum;
                    m_run[x] = m_new;
                    for dd in 0..d {
                        o_acc[x * d + dd] *= a;
                    }
                }
                gemm::matmul_nn_acc(
                    s_tile,
                    &v[col0 * d..(col0 + cols) * d],
                    rows,
                    cols,
                    d,
                    &mut o_acc,
                );
            }
            for x in 0..rows {
                if l_run[x] > 0.0 {
                    let inv = 1.0 / l_run[x];
                    for dd in 0..d {
                        out[(row0 + x) * d + dd] = o_acc[x * d + dd] * inv;
                    }
                    let m_safe = if m_run[x].is_finite() { m_run[x] } else { 0.0 };
                    lse[row0 + x] = m_safe + l_run[x].ln();
                }
            }
        }
        AttnOutput { o: out, lse }
    }

    #[test]
    fn forward_matches_pre_refactor_reference_all_masks_odd_shapes() {
        // satellite: output + lse within 1e-5 of the pre-refactor path
        // for every benchmark mask kind, at odd head dim (d = 80) and n
        // not a multiple of the tile size, plus the visit-count
        // invariant executed <= visited <= dense trip count
        for (n, d) in [(100usize, 80usize), (96, 16)] {
            let (q, k, v) = setup(n, d, 31);
            let cfg = AttnConfig::new(32, 32, d);
            for (kind, mask) in builders::benchmark_suite(n, 11) {
                let table = BlockTable::build(&mask, cfg.bc);
                let (got, st) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                let want = reference_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                for (i, (a, b)) in got.o.iter().zip(&want.o).enumerate() {
                    assert!((a - b).abs() < 1e-5, "{kind} n={n} d={d} o[{i}]: {a} vs {b}");
                }
                for (i, (a, b)) in got.lse.iter().zip(&want.lse).enumerate() {
                    if a.is_finite() || b.is_finite() {
                        assert!((a - b).abs() < 1e-5, "{kind} lse[{i}]: {a} vs {b}");
                    }
                }
                // interval scheduling: never fewer trips than executed
                // tiles, never more than the old dense scan paid
                assert!(
                    st.tiles_partial + st.tiles_unmasked <= st.tiles_visited,
                    "{kind}: visited {} < executed {}",
                    st.tiles_visited,
                    st.tiles_partial + st.tiles_unmasked
                );
                assert!(
                    st.tiles_visited <= st.tiles_total,
                    "{kind}: visited {} > dense trips {}",
                    st.tiles_visited,
                    st.tiles_total
                );
            }
        }
    }

    #[test]
    fn schedule_ranges_exclude_only_fully_masked_tiles() {
        // soundness of the per-row-block visit ranges: everything
        // outside [bj_lo, bj_hi) is FullyMasked, and the executed
        // weights agree with the class table
        let n = 128;
        let cfg = AttnConfig::new(32, 32, 8);
        for (kind, mask) in builders::benchmark_suite(n, 19) {
            let table = BlockTable::build(&mask, cfg.bc);
            let sched = TileSchedule::build(&mask, &table, n, cfg, true);
            for bi in 0..sched.tr {
                let (lo, hi) = sched.range(bi);
                let mut exec = 0u64;
                for bj in 0..sched.tc {
                    let class = sched.class(bi, bj);
                    assert_eq!(
                        class,
                        tile_class(&mask, &table, bi, cfg.br, bj, cfg.bc, true),
                        "{kind} ({bi},{bj})"
                    );
                    if bj < lo || bj >= hi {
                        assert_eq!(
                            class,
                            BlockClass::FullyMasked,
                            "{kind} ({bi},{bj}): outside range but not masked"
                        );
                    } else if class != BlockClass::FullyMasked {
                        exec += 1;
                    }
                }
                assert_eq!(exec, sched.weights()[bi], "{kind} row block {bi}");
                // tight endpoints: a non-empty range starts and ends on
                // executed tiles
                if lo < hi {
                    assert_ne!(sched.class(bi, lo), BlockClass::FullyMasked, "{kind} {bi}");
                    assert_ne!(sched.class(bi, hi - 1), BlockClass::FullyMasked, "{kind} {bi}");
                }
            }
        }
    }

    #[test]
    fn tile_mask_cache_matches_interval_tests() {
        // the cached byte maps must reproduce the element-wise interval
        // tests exactly (same masked positions), exist for every partial
        // tile of a skip schedule, and never exist elsewhere
        let n = 100;
        let cfg = AttnConfig::new(32, 16, 8);
        for (kind, mask) in builders::benchmark_suite(n, 29) {
            let table = BlockTable::build(&mask, cfg.bc);
            let sched = TileSchedule::build(&mask, &table, n, cfg, true);
            let mut cached_evals = 0u64;
            for bi in 0..sched.tr {
                for bj in 0..sched.tc {
                    let bits = sched.tile_mask(bi, bj);
                    if sched.class(bi, bj) != BlockClass::PartiallyMasked {
                        assert!(bits.is_none(), "{kind}: non-partial tile ({bi},{bj}) cached");
                        continue;
                    }
                    let bits =
                        bits.unwrap_or_else(|| panic!("{kind}: partial ({bi},{bj}) not cached"));
                    let row0 = bi * cfg.br;
                    let rows = cfg.br.min(n - row0);
                    let col0 = bj * cfg.bc;
                    let cols = cfg.bc.min(n - col0);
                    assert_eq!(bits.len(), rows * cols, "{kind} ({bi},{bj})");
                    for x in 0..rows {
                        for y in 0..cols {
                            assert_eq!(
                                bits[x * cols + y] != 0,
                                !mask.allowed(row0 + x, col0 + y),
                                "{kind} tile ({bi},{bj}) elem ({x},{y})"
                            );
                        }
                    }
                    cached_evals += (rows * cols) as u64;
                }
            }
            assert_eq!(cached_evals, sched.build_mask_evals(), "{kind}: build census");
        }
        // dense-baseline schedules build no cache: the baseline keeps
        // paying the per-pass element-wise masking it is meant to model
        let mask = builders::causal(64);
        let table = BlockTable::build(&mask, 16);
        let sched = TileSchedule::build(&mask, &table, 64, AttnConfig::new(16, 16, 8), false);
        for bi in 0..sched.tr {
            for bj in 0..sched.tc {
                assert!(sched.tile_mask(bi, bj).is_none());
            }
        }
    }

    #[test]
    fn mask_cache_budget_falls_back_bitwise() {
        // a mask whose partial-tile volume exceeds MASK_CACHE_BYTES:
        // per-column random half-height intervals make essentially every
        // tile partial, so an unbounded cache would hold ~n^2 bytes.
        // Tiles past the budget must stay uncached (per-pass
        // element-wise fallback) and the mixed cached/uncached forward
        // must still be bitwise equal to the dense baseline.
        let (n, d) = (3072, 2);
        let mut mask = FlashMask::empty(n, false);
        let mut x = 1u64;
        for j in 0..n {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let a = (x >> 33) as usize % (n / 2);
            mask.lts[j] = a as i32;
            mask.lte[j] = (a + n / 2) as i32;
        }
        mask.validate().unwrap();
        let cfg = AttnConfig::new(32, 32, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let sched = TileSchedule::build(&mask, &table, n, cfg, true);
        let mut cached_bytes = 0usize;
        let mut uncached_partial = 0usize;
        for bi in 0..sched.tr {
            for bj in 0..sched.tc {
                if sched.class(bi, bj) == BlockClass::PartiallyMasked {
                    match sched.tile_mask(bi, bj) {
                        Some(bits) => cached_bytes += bits.len(),
                        None => uncached_partial += 1,
                    }
                }
            }
        }
        assert!(
            cached_bytes <= TileSchedule::MASK_CACHE_BYTES,
            "cache exceeded its budget: {cached_bytes}"
        );
        assert!(uncached_partial > 0, "workload too small to exercise the budget");
        let (q, k, v) = setup(n, d, 53);
        let (a, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        let (b, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, false);
        assert_eq!(a.o, b.o, "mixed cached/uncached masking changed the result");
    }

    #[test]
    fn grouped_parallel_matches_sequential_bitwise() {
        // row blocks are independent, so any thread count must
        // reproduce the sequential kernel bit for bit — outputs, lse
        // and stats
        let (n, d) = (100, 8);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(41);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let cfg = AttnConfig::new(32, 16, d);
        for (kind, mask) in builders::benchmark_suite(n, 23) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (want, ws) =
                flashmask_forward_grouped(&q, &k, &v, n, d, layout, &mask, &table, cfg, true);
            for threads in [2usize, 3, 8] {
                let (got, gs) = flashmask_forward_grouped_parallel(
                    &q, &k, &v, n, d, layout, &mask, &table, cfg, true, threads,
                );
                for h in 0..layout.q_heads {
                    assert_eq!(got[h].o, want[h].o, "{kind} t={threads} head {h}");
                    assert_eq!(got[h].lse, want[h].lse, "{kind} t={threads} head {h} lse");
                }
                assert_eq!(gs, ws, "{kind} t={threads}: stats diverged");
            }
        }
    }

    #[test]
    fn prop_forward_matches_dense_random_docs() {
        prop::check(
            "flash-vs-dense",
            crate::util::prop::PropConfig { cases: 16, base_seed: 77 },
            |rng| {
                let n = 64;
                let d = *rng.choose(&[4usize, 8, 16]);
                let k_docs = rng.range(1, 5) as usize;
                let lens = crate::workload::docgen::sample_doc_lens(n, k_docs, 1, rng);
                let mask = if rng.f64() < 0.5 {
                    builders::causal_document(n, &lens)
                } else {
                    builders::document(n, &lens)
                };
                let q = rand_vec(n * d, rng);
                let k = rand_vec(n * d, rng);
                let v = rand_vec(n * d, rng);
                let cfg = AttnConfig::new(*rng.choose(&[16usize, 32]), *rng.choose(&[16usize, 32]), d);
                let table = BlockTable::build(&mask, cfg.bc);
                let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
                for (a, b) in got.o.iter().zip(&want.o) {
                    if (a - b).abs() > 3e-5 {
                        return Err(format!("mismatch {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}
