//! FLASHMASK blocked attention — paper Algorithm 1 (forward) and
//! Algorithm 2 (backward), executed tile-for-tile on the CPU.
//!
//! `skip = true` enables the Eq. 4 classification (the contribution);
//! `skip = false` is the "FlashAttention dense mask" baseline: identical
//! arithmetic over *all* tiles, so the two are bitwise-equal — the
//! paper's §4.4 exactness claim, asserted in the tests below.

// lint: allow-file(hot-path-panic:index) — tile indices are bounded by
// the Eq. 4 block schedule: every `s[x * cols ..]` / `lts[j]` access is
// confined to `rows × cols` tiles cut from `n` by the plan, and the
// oracle suites compare each path bitwise against the dense reference.
// lint: allow-file(hot-path-panic:expect) — the only `.expect(` users
// in this file are the deprecated one-shot shims (kept as migration
// oracles); they are documented to panic on invalid input, while the
// `attention::api` path returns typed `AttnError`s.

use super::api::{self, Backend as _};
use super::gemm;
use super::{AttnConfig, AttnGrads, AttnOutput, HeadLayout, TileStats};
use crate::mask::{BlockClass, BlockTable, FlashMask};

const NEG_INF: f32 = f32::NEG_INFINITY;

/// Apply the element-wise interval mask to a score tile
/// (paper Alg. 1 lines 19/23 + implicit-causal diagonal test).
#[inline]
fn apply_tile_mask(
    s: &mut [f32],
    mask: &FlashMask,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    stats: &mut TileStats,
) {
    for x in 0..rows {
        let i = (row0 + x) as i32;
        let srow = &mut s[x * cols..(x + 1) * cols];
        for (y, sv) in srow.iter_mut().enumerate() {
            let j = col0 + y;
            let mut masked = mask.causal && i < j as i32;
            masked |= i >= mask.lts[j] && i < mask.lte[j];
            if !mask.causal {
                masked |= i >= mask.uts[j] && i < mask.ute[j];
            }
            if masked {
                *sv = NEG_INF;
            }
        }
    }
    stats.mask_evals += (rows * cols) as u64;
}

/// Replay a precomputed per-tile mask byte map (1 = masked) over a
/// score tile — the cache-hit path of [`apply_tile_mask`].  Branch-free
/// select so the pass vectorizes.
#[inline]
fn apply_tile_mask_cached(s: &mut [f32], bits: &[u8]) {
    debug_assert_eq!(s.len(), bits.len());
    for (sv, &m) in s.iter_mut().zip(bits) {
        *sv = if m != 0 { NEG_INF } else { *sv };
    }
}

/// Run the element-wise interval tests of one tile into a byte map
/// (1 = masked) — the uncached-tile analogue of the schedule's mask
/// cache.  The backward pass classifies each tile **once per KV head**
/// and replays the map across every query head of the group (and both
/// the S recompute and nothing else — P/dS reuse the already-masked
/// scores), so over-budget and dense-baseline tiles still pay the
/// interval tests only once per tile visit, not once per group member.
fn tile_mask_bits(
    mask: &FlashMask,
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
    out: &mut Vec<u8>,
) {
    out.clear();
    out.reserve(rows * cols);
    for x in 0..rows {
        let i = row0 + x;
        for y in 0..cols {
            out.push(u8::from(!mask.allowed(i, col0 + y)));
        }
    }
}

/// Tile decision shared by forward and backward.
#[inline]
pub(crate) fn tile_class(
    mask: &FlashMask,
    table: &BlockTable,
    bi: usize,
    br: usize,
    bj: usize,
    bc: usize,
    skip: bool,
) -> BlockClass {
    if skip {
        table.classify(mask, bi, br, bj, bc)
    } else {
        // dense-mask baseline: every tile computes + element-masks
        BlockClass::PartiallyMasked
    }
}

/// Interval-driven tile schedule: the Eq. 4 classification of every
/// `(bi, bj)` tile **plus** a per-row-block visit range `[bj_lo,
/// bj_hi)` bounding the non-fully-masked column blocks, derived in the
/// same single sweep over the column intervals.  The compute loop runs
/// `bj in bj_lo..bj_hi` instead of the dense `0..tc` scan, so tiles
/// outside the range are never visited at all (the
/// visit-only-needed-tiles observation of Binary Block Masking,
/// PAPERS.md) — for contiguous-visibility masks (causal, windows,
/// documents) the trip count equals the executed-tile count.
///
/// The schedule is a property of the mask alone — no head data enters
/// it — which is what lets the grouped kernel build it once per KV
/// head and reuse it across the whole query group (and the serving
/// engine share one schedule across all heads of a request).  The
/// per-row-block executed-tile counts double as the
/// [`super::parallel_2d`] cost weights.
pub(crate) struct TileSchedule {
    pub tr: usize,
    pub tc: usize,
    classes: Vec<BlockClass>,
    ranges: Vec<(usize, usize)>,
    /// Executed (non-fully-masked) tiles per row block — the
    /// work-partitioning weight of the forward pass.
    executed: Vec<u64>,
    /// Executed tiles per **column** block — the work-partitioning
    /// weight of the column-parallel backward pass (causal masks make
    /// early key columns heavy and late ones light; the transpose of
    /// the row-block skew).
    executed_cols: Vec<u64>,
    /// Per-tile mask cache: for every `Partial` tile (when the Eq. 4
    /// classification is on) the element-wise interval tests are run
    /// **once here** and materialized as a `rows*cols` byte map
    /// (1 = masked).  Every compute pass — each query head of a GQA
    /// group, each row-block thread, each repeated call through a
    /// cached [`api::ExecutionPlan`] — applies the precomputed map
    /// instead of re-testing the intervals.  `tile_off[t]..tile_off[t+1]`
    /// indexes tile `t`'s bytes; non-partial tiles have zero extent.
    /// Bounded by [`Self::MASK_CACHE_BYTES`] — partial tiles past the
    /// budget stay uncached and fall back to per-pass element-wise
    /// tests.  Empty in dense-baseline schedules (`skip = false`),
    /// which keep the per-pass element-wise masking the baseline is
    /// meant to pay.
    masked: Vec<u8>,
    tile_off: Vec<usize>,
    /// Interval tests performed building the cache (the one-time cost a
    /// plan charges as `mask_evals`).
    build_mask_evals: u64,
}

impl TileSchedule {
    /// Cap on materialized mask-cache bytes per schedule.  Partial
    /// tiles are worst-case O(n²) elements (e.g. eviction masks where
    /// most lower-triangle tiles are partial), so an unbounded cache
    /// would trade the representation's O(n) memory story away at long
    /// context — and `PlanCache` retains up to 64 plans.  Tiles past
    /// the budget simply stay uncached and fall back to the per-pass
    /// element-wise interval tests (bitwise-identical results).
    pub const MASK_CACHE_BYTES: usize = 4 << 20;

    pub fn build(
        mask: &FlashMask,
        table: &BlockTable,
        n: usize,
        cfg: AttnConfig,
        skip: bool,
    ) -> TileSchedule {
        let sp = crate::telemetry::trace::span(crate::telemetry::names::PLAN_CLASSIFY);
        let (br, bc) = (cfg.br, cfg.bc);
        let (tr, tc) = (n.div_ceil(br), n.div_ceil(bc));
        let mut classes = Vec::with_capacity(tr * tc);
        let mut ranges = Vec::with_capacity(tr);
        let mut executed = Vec::with_capacity(tr);
        let mut executed_cols = vec![0u64; tc];
        let mut masked = Vec::new();
        let mut tile_off = Vec::with_capacity(tr * tc + 1);
        tile_off.push(0);
        let mut build_mask_evals = 0u64;
        for bi in 0..tr {
            let (mut lo, mut hi) = (0usize, 0usize);
            let mut exec = 0u64;
            let row0 = bi * br;
            let rows = br.min(n - row0);
            for bj in 0..tc {
                let class = tile_class(mask, table, bi, br, bj, bc, skip);
                if class != BlockClass::FullyMasked {
                    if exec == 0 {
                        lo = bj;
                    }
                    hi = bj + 1;
                    exec += 1;
                    executed_cols[bj] += 1;
                }
                if skip && class == BlockClass::PartiallyMasked {
                    let col0 = bj * bc;
                    let cols = bc.min(n - col0);
                    if masked.len() + rows * cols <= Self::MASK_CACHE_BYTES {
                        // run the interval tests once; every pass
                        // replays the byte map
                        for x in 0..rows {
                            let i = row0 + x;
                            for y in 0..cols {
                                masked.push(u8::from(!mask.allowed(i, col0 + y)));
                            }
                        }
                        build_mask_evals += (rows * cols) as u64;
                    }
                    // over budget: tile stays uncached; compute passes
                    // keep the element-wise tests for it
                }
                tile_off.push(masked.len());
                classes.push(class);
            }
            // a fully-masked row block never set lo/hi: range stays (0, 0)
            ranges.push((lo, hi));
            executed.push(exec);
        }
        sp.add("mask_evals", build_mask_evals);
        TileSchedule {
            tr,
            tc,
            classes,
            ranges,
            executed,
            executed_cols,
            masked,
            tile_off,
            build_mask_evals,
        }
    }

    #[inline]
    pub fn class(&self, bi: usize, bj: usize) -> BlockClass {
        self.classes[bi * self.tc + bj]
    }

    /// Column-block visit range `[bj_lo, bj_hi)` for row block `bi`.
    #[inline]
    pub fn range(&self, bi: usize) -> (usize, usize) {
        self.ranges[bi]
    }

    /// Per-row-block executed-tile counts ([`super::parallel_2d`] weights).
    pub fn weights(&self) -> &[u64] {
        &self.executed
    }

    /// Per-column-block executed-tile counts — the [`super::parallel_2d`]
    /// weights of the column-parallel backward pass.
    pub fn col_weights(&self) -> &[u64] {
        &self.executed_cols
    }

    /// All tile classes, row-major (`tr * tc`) — the census input.
    pub fn classes(&self) -> &[BlockClass] {
        &self.classes
    }

    pub fn build_mask_evals(&self) -> u64 {
        self.build_mask_evals
    }

    /// The cached `rows*cols` mask bytes of tile `(bi, bj)`, if the
    /// tile is partial and the cache was built (`skip = true`).
    #[inline]
    pub fn tile_mask(&self, bi: usize, bj: usize) -> Option<&[u8]> {
        let t = bi * self.tc + bj;
        let (s, e) = (self.tile_off[t], self.tile_off[t + 1]);
        if s == e {
            None
        } else {
            Some(&self.masked[s..e])
        }
    }

    /// One classification pass's tile census plus the cache build cost
    /// — what [`api::ExecutionPlan`] charges per KV head.
    pub fn census(&self) -> TileStats {
        let mut stats = TileStats::default();
        add_census(&mut stats, &self.classes);
        stats.mask_evals = self.build_mask_evals;
        stats
    }
}

/// Charge one classification pass's tile census to `stats`.  Every
/// non-skipped tile is executed, so the census equals the per-tile
/// counters the execution loop would have accumulated.
fn add_census(stats: &mut TileStats, classes: &[BlockClass]) {
    stats.tiles_total += classes.len();
    for c in classes {
        match c {
            BlockClass::FullyMasked => stats.tiles_skipped += 1,
            BlockClass::PartiallyMasked => stats.tiles_partial += 1,
            BlockClass::Unmasked => stats.tiles_unmasked += 1,
        }
    }
}

/// Algorithm 1 compute loop for **one row block** of one query head
/// against one (packed) KV head, driven by the interval schedule.
/// Returns the row block's `[rows, d]` output and `[rows]` logsumexp;
/// accumulates the compute-side counters (`macs`, `mask_evals`,
/// `tiles_visited`) into `stats`.  This is the unit of
/// [`super::parallel_2d`] work partitioning — row blocks are
/// independent, so the parallel and sequential paths are
/// bitwise-identical.
///
/// Partial tiles replay the schedule's per-tile mask cache (interval
/// tests run once at schedule build and are shared across the whole
/// query group and across repeated plan-cached calls — the decode
/// kernels' classify-once reuse, brought to prefill); dense-baseline
/// schedules (`skip = false`) fall back to per-pass element-wise tests.
pub(crate) fn forward_row_block(
    q: &[f32],
    kt: &gemm::PackedKt,
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    bi: usize,
    stats: &mut TileStats,
) -> (Vec<f32>, Vec<f32>) {
    let (br, bc) = (cfg.br, cfg.bc);
    debug_assert_eq!(kt.bc(), bc);
    let row0 = bi * br;
    let rows = br.min(n - row0);

    // pack the Q row block once; every visited tile streams it
    let mut q_pack = gemm::PackedBlock::new();
    q_pack.pack(&q[row0 * d..(row0 + rows) * d], rows, d);

    let mut out = vec![0f32; rows * d];
    let mut lse = vec![NEG_INF; rows];
    let mut s = vec![0f32; rows * bc];
    let mut o_acc = vec![0f32; rows * d];
    let mut m_run = vec![NEG_INF; rows];
    let mut l_run = vec![0f32; rows];
    let mut alpha = vec![0f32; rows];

    let (bj_lo, bj_hi) = sched.range(bi);
    for bj in bj_lo..bj_hi {
        stats.tiles_visited += 1;
        let class = sched.class(bi, bj);
        if class == BlockClass::FullyMasked {
            continue; // interior hole (non-contiguous mask): branch only
        }
        let col0 = bj * bc;
        let cols = bc.min(n - col0);

        // S = (Q_i K_j^T) * scale — scale fused into the microkernel,
        // no zeroing pass (the kernel writes, not accumulates)
        let s_tile = &mut s[..rows * cols];
        gemm::matmul_nt_packed(&q_pack, kt.block(bj), cfg.scale, s_tile);
        stats.macs += (rows * cols * d) as u64;

        if class == BlockClass::PartiallyMasked {
            if let Some(bits) = sched.tile_mask(bi, bj) {
                // per-tile mask cache: interval tests ran once at
                // schedule build; replay the byte map (same positions,
                // bitwise-identical scores)
                apply_tile_mask_cached(s_tile, bits);
                stats.mask_cache_hits += 1;
            } else {
                apply_tile_mask(s_tile, mask, row0, rows, col0, cols, stats);
            }
        }

        // online softmax update (Alg. 1 lines 25-26): one lane-parallel
        // max sweep + one fused exp/accumulate sweep per row
        for x in 0..rows {
            let srow = &mut s_tile[x * cols..(x + 1) * cols];
            let m_new = m_run[x].max(gemm::row_max(srow));
            let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
            let a = if m_run[x].is_finite() { (m_run[x] - m_safe).exp() } else { 0.0 };
            l_run[x] = a * l_run[x] + gemm::exp_sub_sum(srow, m_safe);
            m_run[x] = m_new;
            alpha[x] = a;
        }
        gemm::scale_rows(&mut o_acc, &alpha[..rows], rows, d);
        // O += P V_j
        gemm::matmul_nn_acc(s_tile, &v[col0 * d..(col0 + cols) * d], rows, cols, d, &mut o_acc);
        stats.macs += (rows * cols * d) as u64;
    }

    // finalize (Alg. 1 lines 28-29)
    for x in 0..rows {
        if l_run[x] > 0.0 {
            let inv = 1.0 / l_run[x];
            for dd in 0..d {
                out[x * d + dd] = o_acc[x * d + dd] * inv;
            }
            let m_safe = if m_run[x].is_finite() { m_run[x] } else { 0.0 };
            lse[x] = m_safe + l_run[x].ln();
        } // fully-masked row: output stays 0, lse stays -inf
    }
    (out, lse)
}

/// Algorithm 1 compute loop for one query head against one packed KV
/// head — the sequential row-block walk over [`forward_row_block`].
pub(crate) fn forward_tiles(
    q: &[f32],
    kt: &gemm::PackedKt,
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    stats: &mut TileStats,
) -> AttnOutput {
    let mut out = vec![0f32; n * d];
    let mut lse = vec![NEG_INF; n];
    for bi in 0..sched.tr {
        let row0 = bi * cfg.br;
        let (ob, lb) = forward_row_block(q, kt, v, n, d, mask, cfg, sched, bi, stats);
        out[row0 * d..row0 * d + ob.len()].copy_from_slice(&ob);
        lse[row0..row0 + lb.len()].copy_from_slice(&lb);
    }
    AttnOutput { o: out, lse }
}

/// Algorithm 1 — forward pass for a single head.
///
/// `q,k,v`: row-major `[n, d]`.  Returns output, per-row logsumexp, and
/// tile/work counters.
///
/// Deprecated shim over [`api`]: builds a one-shot
/// [`api::AttnProblem`] and runs [`api::CpuBackend`], so the
/// differential suites pinned to this entry point double as migration
/// tests.  The passed `table` is ignored — the plan rebuilds an
/// identical one from the same mask and `cfg.bc` (deterministic).
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).mask(&mask).tile(br, bc) + CpuBackend::prefill (DESIGN.md §Public API)"
)]
pub fn flashmask_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnOutput, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .plan()
        .expect("flashmask_forward: invalid problem");
    let out = api::CpuBackend
        .prefill(
            &plan,
            api::QViews::new(q, 1, n, d).expect("flashmask_forward: q must be [n, d]"),
            api::KvViews::new(k, v, 1, n, d).expect("flashmask_forward: k/v must be [n, d]"),
        )
        .expect("flashmask_forward: CPU prefill");
    let mut outs = out.outs;
    (outs.remove(0), out.stats)
}

/// Algorithm 1 forward over a grouped head layout: Q `[q_heads, n, d]`
/// against shared K/V `[kv_heads, n, d]`.
///
/// The Eq. 4 tile classification is computed **once per KV head** and
/// reused by that head's whole query group — the skip decision is a
/// property of the key columns alone (§4.1), so sharing KV heads also
/// shares the classification.  `TileStats` tile denominators therefore
/// count `kv_heads · tiles`, not `q_heads · tiles`: at group size `g`
/// the classification cost and the skip-accounting denominators drop
/// by `g` while per-query-head MACs are unchanged.
///
/// Returns one [`AttnOutput`] per query head, in query-head order.
/// With an MHA layout this is bitwise-identical to calling
/// [`flashmask_forward`] once per head.
///
/// Deprecated shim over [`api`] (single-threaded); see
/// [`flashmask_forward`] for the migration contract.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).layout(layout).mask(&mask) + CpuBackend::prefill_grouped (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_forward_grouped(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (Vec<AttnOutput>, TileStats) {
    grouped_shim(q, k, v, n, d, layout, mask, table, cfg, skip, 1)
}

/// [`flashmask_forward_grouped`] with (head × row-block) work
/// partitioning across up to `max_threads` OS threads.
///
/// The grid of `q_heads · ⌈n/Br⌉` row-block items is cut into
/// cost-weighted contiguous chunks by [`super::parallel_2d`] (weight =
/// executed tiles per row block from the interval schedule), so a
/// single long 1-head sequence saturates every core and causal
/// workloads don't tail-stall on the heavy last rows.  Row blocks are
/// independent in Algorithm 1, so the result is **bitwise identical**
/// to the sequential kernel at any thread count (asserted in the tests
/// below).  The Eq. 4 schedule is built once per mask and each KV
/// head's K is packed once; both are shared read-only across all
/// threads and all query heads of the head's group.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).layout(layout).mask(&mask).threads(t) + CpuBackend::prefill_grouped (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_forward_grouped_parallel(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
    max_threads: usize,
) -> (Vec<AttnOutput>, TileStats) {
    grouped_shim(q, k, v, n, d, layout, mask, table, cfg, skip, max_threads)
}

/// Shared body of the two deprecated grouped entry points: build a
/// one-shot [`api::AttnProblem`] and run [`api::CpuBackend`].
#[allow(clippy::too_many_arguments)]
fn grouped_shim(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
    max_threads: usize,
) -> (Vec<AttnOutput>, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .layout(layout)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .threads(max_threads)
        .plan()
        .expect("grouped forward: invalid problem");
    let out = api::CpuBackend
        .prefill_grouped(
            &plan,
            api::QViews::new(q, layout.q_heads, n, d)
                .expect("grouped forward: q must be [q_heads, n, d]"),
            api::KvViews::new(k, v, layout.kv_heads, n, d)
                .expect("grouped forward: k/v must be [kv_heads, n, d]"),
        )
        .expect("grouped forward: CPU prefill");
    (out.outs, out.stats)
}

/// Algorithm 2 — backward pass for a single head.
///
/// Deprecated shim over [`api`]; see [`flashmask_forward`] for the
/// migration contract.
#[deprecated(
    note = "use attention::api — AttnProblem::new(n, d).mask(&mask) + CpuBackend::backward (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn flashmask_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    table: &BlockTable,
    cfg: AttnConfig,
    skip: bool,
) -> (AttnGrads, TileStats) {
    let _ = table;
    let plan = api::AttnProblem::new(n, d)
        .mask(mask)
        .tile(cfg.br, cfg.bc)
        .scale(cfg.scale)
        .skip(skip)
        .plan()
        .expect("flashmask_backward: invalid problem");
    api::CpuBackend
        .backward(&plan, q, k, v, o, do_, lse)
        .expect("flashmask_backward: CPU backward")
}

/// Packed per-key-block operands for the backward pass, built **once
/// per KV head** and shared read-only by every column stripe and every
/// query head of the head's group.
struct BackwardKvPack {
    /// K row panels per `bc` block (depth `d`) — the S = Q·Kᵀ recompute
    /// right operand (same layout the forward pass packs).
    kt: gemm::PackedKt,
    /// V row panels per `bc` block (depth `d`) — the transposed-operand
    /// "PackedVt" right operand of dP = dO·Vᵀ.
    vt: gemm::PackedKt,
    /// `K_jᵀ` panels (rows = `d`, depth = `cols`) — the dQ += dS·K
    /// right operand.
    kt_t: Vec<gemm::PackedBlock>,
}

impl BackwardKvPack {
    fn pack(k: &[f32], v: &[f32], n: usize, d: usize, bc: usize) -> BackwardKvPack {
        let kt = gemm::PackedKt::pack(k, n, d, bc);
        let vt = gemm::PackedKt::pack(v, n, d, bc);
        let tc = n.div_ceil(bc);
        let mut kt_t = Vec::with_capacity(tc);
        for bj in 0..tc {
            let col0 = bj * bc;
            let cols = bc.min(n - col0);
            let mut p = gemm::PackedBlock::new();
            p.pack_transposed(&k[col0 * d..(col0 + cols) * d], cols, d);
            kt_t.push(p);
        }
        BackwardKvPack { kt, vt, kt_t }
    }
}

/// Packed per-row-block operands for one query head's backward pass.
struct BackwardQPack {
    /// Q row panels per `br` block (depth `d`) — S = Q·Kᵀ left operand.
    qt: gemm::PackedKt,
    /// dO row panels per `br` block (depth `d`) — dP = dO·Vᵀ left operand.
    dot: gemm::PackedKt,
    /// `Q_iᵀ` panels (rows = `d`, depth = `rows`) — dK += dSᵀ·Q right
    /// operand.
    qt_t: Vec<gemm::PackedBlock>,
    /// `dO_iᵀ` panels (rows = `d`, depth = `rows`) — dV += Pᵀ·dO right
    /// operand.
    dot_t: Vec<gemm::PackedBlock>,
    /// D = rowsum(dO ∘ O) (Alg. 2 line 4).
    dvec: Vec<f32>,
}

impl BackwardQPack {
    fn pack(q: &[f32], do_: &[f32], o: &[f32], n: usize, d: usize, br: usize) -> BackwardQPack {
        let qt = gemm::PackedKt::pack(q, n, d, br);
        let dot = gemm::PackedKt::pack(do_, n, d, br);
        let tr = n.div_ceil(br);
        let mut qt_t = Vec::with_capacity(tr);
        let mut dot_t = Vec::with_capacity(tr);
        for bi in 0..tr {
            let row0 = bi * br;
            let rows = br.min(n - row0);
            let mut pq = gemm::PackedBlock::new();
            pq.pack_transposed(&q[row0 * d..(row0 + rows) * d], rows, d);
            qt_t.push(pq);
            let mut pd = gemm::PackedBlock::new();
            pd.pack_transposed(&do_[row0 * d..(row0 + rows) * d], rows, d);
            dot_t.push(pd);
        }
        let mut dvec = vec![0f32; n];
        for (i, dst) in dvec.iter_mut().enumerate() {
            let mut acc = 0f32;
            for dd in 0..d {
                acc += do_[i * d + dd] * o[i * d + dd];
            }
            *dst = acc;
        }
        BackwardQPack { qt, dot, qt_t, dot_t, dvec }
    }
}

/// One column stripe's backward output: the stripe **owns** its dK_j /
/// dV_j columns outright, and carries windowed dQ *partials* that the
/// caller folds in ascending-stripe order (the deterministic
/// reduction).
struct ColStripeOut {
    /// dK for this stripe's key columns, `[cols, d]` (grouped layouts:
    /// summed across the query group in ascending query-head order).
    dk: Vec<f32>,
    /// dV for this stripe's key columns, `[cols, d]`.
    dv: Vec<f32>,
    /// First row covered by the dQ partials (a `br` multiple).
    row_lo: usize,
    /// Per-group-member dQ partial over rows `row_lo..`, `[span, d]`
    /// each — only the row window this stripe's executed tiles touch.
    dq: Vec<Vec<f32>>,
    stats: TileStats,
}

/// Algorithm 2 backward body over a grouped head layout, column-parallel
/// over key blocks on the packed microkernels.
///
/// **Work item = one (KV head, key-column stripe) pair.**  A stripe owns
/// its dK_j/dV_j columns (no reduction needed — FlashAttention-2's
/// backward partitioning), recomputes S and P per tile from the packed
/// panels, and accumulates the query group's dK/dV in ascending
/// query-head order.  dQ is row-indexed, so every stripe produces
/// windowed dQ *partials*; the caller folds them **in ascending (kv
/// head, stripe) order on the calling thread**, and the sequential path
/// runs the identical stripe-then-fold code.  Parallel output is
/// therefore bitwise-identical to sequential at any thread count *by
/// construction* (each stripe's arithmetic is independent and
/// deterministic; only the fold adds floats across stripes, and its
/// order never depends on the thread count) — asserted in the tests and
/// the backward bench.
///
/// Mask classification runs **once per KV-head tile**: partial tiles
/// replay the schedule's byte map (or, when uncached, materialize it
/// once via [`tile_mask_bits`]) across all `group` query heads, so the
/// classification/mask-eval denominator shrinks by the group factor
/// exactly as in the grouped forward path.  All five tile GEMMs ride
/// the 4×2 packed NT register tile via transposed-operand packing
/// (see [`gemm::matmul_tn_packed_acc`] / [`gemm::matmul_nn_packed_acc`]);
/// the per-tile pack cost is O(rows·cols) against O(rows·cols·d) of
/// GEMM work.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_grouped_impl(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    threads: usize,
) -> (super::GroupedGrads, TileStats) {
    let (br, bc) = (cfg.br, cfg.bc);
    let (tr, tc) = (sched.tr, sched.tc);
    let (q_heads, kv_heads) = (layout.q_heads, layout.kv_heads);
    let group = layout.group();
    let hd = n * d;

    let kv_packs: Vec<BackwardKvPack> = (0..kv_heads)
        .map(|kh| BackwardKvPack::pack(&k[kh * hd..(kh + 1) * hd], &v[kh * hd..(kh + 1) * hd], n, d, bc))
        .collect();
    let q_packs: Vec<BackwardQPack> = (0..q_heads)
        .map(|h| {
            BackwardQPack::pack(
                &q[h * hd..(h + 1) * hd],
                &do_[h * hd..(h + 1) * hd],
                &o[h * hd..(h + 1) * hd],
                n,
                d,
                br,
            )
        })
        .collect();

    // classification denominators are charged once per KV head — the
    // grouped forward's accounting, carried to the backward
    let mut stats = TileStats {
        tiles_total: kv_heads * tr * tc,
        mask_evals: kv_heads as u64 * sched.build_mask_evals(),
        ..Default::default()
    };

    let worker = |kh: usize, bj: usize| -> ColStripeOut {
        let col0 = bj * bc;
        let cols = bc.min(n - col0);
        let kvp = &kv_packs[kh];
        let mut st = TileStats::default();
        let mut dk = vec![0f32; cols * d];
        let mut dv = vec![0f32; cols * d];

        // row window: only the rows this stripe's executed tiles touch
        let (mut lo, mut hi) = (tr, 0usize);
        for bi in 0..tr {
            if sched.class(bi, bj) != BlockClass::FullyMasked {
                lo = lo.min(bi);
                hi = bi + 1;
            }
        }
        let (row_lo, row_hi) = if hi == 0 { (0, 0) } else { (lo * br, (hi * br).min(n)) };
        let span = row_hi - row_lo;
        let mut dq: Vec<Vec<f32>> = (0..group).map(|_| vec![0f32; span * d]).collect();

        let mut s = vec![0f32; br.min(n) * cols];
        let mut dp = vec![0f32; br.min(n) * cols];
        let mut bits_scratch: Vec<u8> = Vec::new();
        let mut p_t = gemm::PackedBlock::new();
        let mut ds_p = gemm::PackedBlock::new();
        let mut ds_t = gemm::PackedBlock::new();

        for bi in 0..tr {
            let class = sched.class(bi, bj);
            if class == BlockClass::FullyMasked {
                st.tiles_skipped += 1;
                continue;
            }
            let row0 = bi * br;
            let rows = br.min(n - row0);

            // mask bits computed/fetched once per KV-head tile, replayed
            // across the whole query group
            let (bits, from_cache): (Option<&[u8]>, bool) = if class == BlockClass::PartiallyMasked
            {
                st.tiles_partial += 1;
                if let Some(b) = sched.tile_mask(bi, bj) {
                    (Some(b), true)
                } else {
                    tile_mask_bits(mask, row0, rows, col0, cols, &mut bits_scratch);
                    st.mask_evals += (rows * cols) as u64;
                    (Some(bits_scratch.as_slice()), false)
                }
            } else {
                st.tiles_unmasked += 1;
                (None, false)
            };

            for (g, dq_g) in dq.iter_mut().enumerate() {
                let h = kh * group + g;
                let qp = &q_packs[h];

                // S = (Q_i K_jᵀ)·scale (Alg. 2 line 20), packed recompute
                let s_tile = &mut s[..rows * cols];
                gemm::matmul_nt_packed(qp.qt.block(bi), kvp.kt.block(bj), cfg.scale, s_tile);
                st.macs += (rows * cols * d) as u64;
                if let Some(b) = bits {
                    apply_tile_mask_cached(s_tile, b);
                    if from_cache {
                        st.mask_cache_hits += 1;
                    }
                }

                // P = exp(S - L_i) (line 27); masked rows have
                // lse = -inf => P = 0
                let lse_h = &lse[h * n..(h + 1) * n];
                for x in 0..rows {
                    let l = lse_h[row0 + x];
                    let srow = &mut s_tile[x * cols..(x + 1) * cols];
                    if l.is_finite() {
                        for sv in srow.iter_mut() {
                            *sv = (*sv - l).exp();
                        }
                    } else {
                        srow.fill(0.0);
                    }
                }

                // dV_j += Pᵀ dO_i (line 28)
                p_t.pack_transposed(s_tile, rows, cols);
                gemm::matmul_tn_packed_acc(&p_t, &qp.dot_t[bi], 1.0, &mut dv);
                st.macs += (rows * cols * d) as u64;

                // dP = dO_i V_jᵀ (line 29)
                let dp_tile = &mut dp[..rows * cols];
                gemm::matmul_nt_packed(qp.dot.block(bi), kvp.vt.block(bj), 1.0, dp_tile);
                st.macs += (rows * cols * d) as u64;

                // dS = P ∘ (dP - D_i) · scale (line 30)
                for x in 0..rows {
                    let dv_i = qp.dvec[row0 + x];
                    for y in 0..cols {
                        let idx = x * cols + y;
                        dp_tile[idx] = s_tile[idx] * (dp_tile[idx] - dv_i) * cfg.scale;
                    }
                }

                // dQ_i += dS K_j (line 31) — into this stripe's partial
                ds_p.pack(dp_tile, rows, cols);
                let off = (row0 - row_lo) * d;
                gemm::matmul_nn_packed_acc(&ds_p, &kvp.kt_t[bj], 1.0, &mut dq_g[off..off + rows * d]);
                st.macs += (rows * cols * d) as u64;

                // dK_j += dSᵀ Q_i (line 32)
                ds_t.pack_transposed(dp_tile, rows, cols);
                gemm::matmul_tn_packed_acc(&ds_t, &qp.qt_t[bi], 1.0, &mut dk);
                st.macs += (rows * cols * d) as u64;
            }
        }
        ColStripeOut { dk, dv, row_lo, dq, stats: st }
    };

    let results: Vec<ColStripeOut> = if threads <= 1 {
        let mut r = Vec::with_capacity(kv_heads * tc);
        for kh in 0..kv_heads {
            for bj in 0..tc {
                r.push(worker(kh, bj));
            }
        }
        r
    } else {
        super::parallel_2d(kv_heads, tc, sched.col_weights(), threads, &worker)
    };

    // deterministic reduction: fold stripe outputs in ascending
    // (kv head, stripe) order on the calling thread — the one float
    // addition across stripes, and its order never depends on the
    // thread count
    let mut dq_heads = vec![vec![0f32; hd]; q_heads];
    let mut dk_heads = vec![vec![0f32; hd]; kv_heads];
    let mut dv_heads = vec![vec![0f32; hd]; kv_heads];
    for (it, out) in results.into_iter().enumerate() {
        let (kh, bj) = (it / tc, it % tc);
        let col0 = bj * bc;
        let cols = bc.min(n - col0);
        dk_heads[kh][col0 * d..(col0 + cols) * d].copy_from_slice(&out.dk);
        dv_heads[kh][col0 * d..(col0 + cols) * d].copy_from_slice(&out.dv);
        for (g, part) in out.dq.iter().enumerate() {
            let h = kh * group + g;
            let dst = &mut dq_heads[h][out.row_lo * d..out.row_lo * d + part.len()];
            for (a, b) in dst.iter_mut().zip(part) {
                *a += *b;
            }
        }
        stats.merge(&out.stats);
    }
    (super::GroupedGrads { dq: dq_heads, dk: dk_heads, dv: dv_heads }, stats)
}

/// Algorithm 2 backward body for a single head — the MHA special case
/// of [`backward_grouped_impl`] (one query head, one KV head), keeping
/// the column-parallel stripe-then-fold path so single-head callers get
/// the same packed kernels and the same bitwise-determinism guarantee.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_impl(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    mask: &FlashMask,
    cfg: AttnConfig,
    sched: &TileSchedule,
    threads: usize,
) -> (AttnGrads, TileStats) {
    let (mut gg, stats) = backward_grouped_impl(
        q,
        k,
        v,
        o,
        do_,
        lse,
        n,
        d,
        HeadLayout::mha(1),
        mask,
        cfg,
        sched,
        threads,
    );
    (
        AttnGrads { dq: gg.dq.remove(0), dk: gg.dk.remove(0), dv: gg.dv.remove(0) },
        stats,
    )
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::dense;
    use crate::attention::testutil::rand_vec;
    use crate::mask::builders;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng), rand_vec(n * d, &mut rng))
    }

    #[test]
    fn forward_matches_dense_all_masks() {
        let (n, d) = (128, 16);
        let (q, k, v) = setup(n, d, 1);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 3) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
            for (i, (a, b)) in got.o.iter().zip(&want.o).enumerate() {
                assert!((a - b).abs() < 2e-5, "{kind} o[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn skip_is_bitwise_noop() {
        // the paper's §4.4 exactness claim, on this engine
        let (n, d) = (128, 16);
        let (q, k, v) = setup(n, d, 2);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 5) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (a, sa) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let (b, sb) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, false);
            assert_eq!(a.o, b.o, "{kind}: forward outputs differ");
            assert_eq!(a.lse, b.lse, "{kind}: lse differ");
            assert!(sa.macs <= sb.macs, "{kind}: skip did not reduce work");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let (n, d) = (32, 8);
        let (q, k, v) = setup(n, d, 3);
        let mask = builders::causal_document(n, &[14, 10, 8]);
        let cfg = AttnConfig::new(8, 8, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (fwd, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        // loss = sum(O * W) with fixed random W
        let mut rng = Rng::new(9);
        let w = rand_vec(n * d, &mut rng);
        let do_: Vec<f32> = w.clone();
        let (grads, _) = flashmask_backward(
            &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, true,
        );
        let loss = |q_: &[f32], k_: &[f32], v_: &[f32]| -> f32 {
            let (f, _) = flashmask_forward(q_, k_, v_, n, d, &mask, &table, cfg, true);
            f.o.iter().zip(&w).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2;
        let fd_q = crate::attention::finite_diff_loss(|x| loss(x, &k, &v), &q, eps);
        let fd_k = crate::attention::finite_diff_loss(|x| loss(&q, x, &v), &k, eps);
        let fd_v = crate::attention::finite_diff_loss(|x| loss(&q, &k, x), &v, eps);
        for (name, got, want) in
            [("dq", &grads.dq, &fd_q), ("dk", &grads.dk, &fd_k), ("dv", &grads.dv, &fd_v)]
        {
            for i in 0..n * d {
                assert!(
                    (got[i] - want[i]).abs() < 5e-3,
                    "{name}[{i}]: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn backward_skip_bitwise_noop() {
        let (n, d) = (64, 8);
        let (q, k, v) = setup(n, d, 4);
        for (kind, mask) in builders::benchmark_suite(n, 6) {
            let cfg = AttnConfig::new(16, 16, d);
            let table = BlockTable::build(&mask, cfg.bc);
            let (fwd, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
            let mut rng = Rng::new(10);
            let do_ = rand_vec(n * d, &mut rng);
            let (g1, _) = flashmask_backward(
                &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, true,
            );
            let (g2, _) = flashmask_backward(
                &q, &k, &v, &fwd.o, &do_, &fwd.lse, n, d, &mask, &table, cfg, false,
            );
            assert_eq!(g1.dq, g2.dq, "{kind} dq");
            assert_eq!(g1.dk, g2.dk, "{kind} dk");
            assert_eq!(g1.dv, g2.dv, "{kind} dv");
        }
    }

    #[test]
    fn grouped_forward_matches_per_head_bitwise() {
        // GQA: each query head scored against its group's shared KV head
        // must equal the single-head kernel on that (q, kv) pair bitwise,
        // and the tile census must count KV heads, not query heads
        let (n, d) = (96, 8);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(21);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let cfg = AttnConfig::new(32, 32, d);
        for (kind, mask) in builders::benchmark_suite(n, 7) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (outs, gs) =
                flashmask_forward_grouped(&q, &k, &v, n, d, layout, &mask, &table, cfg, true);
            assert_eq!(outs.len(), layout.q_heads);
            let mut per_head = TileStats::default();
            for h in 0..layout.q_heads {
                let kh = layout.kv_head_of(h);
                let (want, st) = flashmask_forward(
                    &q[h * n * d..(h + 1) * n * d],
                    &k[kh * n * d..(kh + 1) * n * d],
                    &v[kh * n * d..(kh + 1) * n * d],
                    n,
                    d,
                    &mask,
                    &table,
                    cfg,
                    true,
                );
                per_head.merge(&st);
                assert_eq!(outs[h].o, want.o, "{kind} head {h}: outputs differ");
                assert_eq!(outs[h].lse, want.lse, "{kind} head {h}: lse differ");
            }
            // classification reuse: tile denominators shrink by the group
            // factor while per-query-head MACs are unchanged
            assert_eq!(gs.tiles_total * layout.group(), per_head.tiles_total, "{kind}");
            assert_eq!(gs.tiles_skipped * layout.group(), per_head.tiles_skipped, "{kind}");
            assert_eq!(gs.macs, per_head.macs, "{kind}: MACs must not change");
            // per-tile mask cache: the element-wise interval tests run
            // once per KV head (at schedule build), not once per query
            // head — the whole group replays the cached byte maps
            assert_eq!(
                gs.mask_evals * layout.group(),
                per_head.mask_evals,
                "{kind}: interval tests must be shared across the query group"
            );
            if gs.tiles_partial > 0 {
                assert!(gs.mask_cache_hits > 0, "{kind}: partial tiles must hit the cache");
            }
        }
    }

    #[test]
    fn grouped_forward_mha_layout_matches_single_head_kernel() {
        // kv_heads == q_heads must reproduce the ungrouped path bitwise
        let (n, d) = (64, 8);
        let heads = 3;
        let mut rng = Rng::new(22);
        let q = rand_vec(heads * n * d, &mut rng);
        let k = rand_vec(heads * n * d, &mut rng);
        let v = rand_vec(heads * n * d, &mut rng);
        let mask = builders::causal_document(n, &[30, 20, 14]);
        let cfg = AttnConfig::new(16, 16, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (outs, _) = flashmask_forward_grouped(
            &q, &k, &v, n, d, HeadLayout::mha(heads), &mask, &table, cfg, true,
        );
        for h in 0..heads {
            let r = h * n * d..(h + 1) * n * d;
            let (want, _) = flashmask_forward(
                &q[r.clone()], &k[r.clone()], &v[r], n, d, &mask, &table, cfg, true,
            );
            assert_eq!(outs[h].o, want.o, "head {h}");
        }
    }

    #[test]
    fn stats_reflect_sparsity() {
        let n = 256;
        let mask = builders::causal(n);
        let cfg = AttnConfig::new(32, 32, 16);
        let table = BlockTable::build(&mask, cfg.bc);
        let (q, k, v) = setup(n, 16, 5);
        let (_, st) = flashmask_forward(&q, &k, &v, n, 16, &mask, &table, cfg, true);
        assert_eq!(st.tiles_total, 64);
        assert_eq!(st.tiles_skipped, 28); // strictly-above-diagonal tiles
        assert_eq!(st.tiles_partial, 8); // diagonal tiles
        assert_eq!(st.tiles_unmasked, 28);
    }

    #[test]
    fn ragged_tail_tiles() {
        // n not divisible by tile sizes
        let (n, d) = (100, 8);
        let (q, k, v) = setup(n, d, 6);
        let mask = builders::causal_document(n, &[37, 63]);
        let cfg = AttnConfig::new(32, 16, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
        for (a, b) in got.o.iter().zip(&want.o) {
            assert!((a - b).abs() < 2e-5);
        }
    }

    /// The pre-refactor forward path, kept verbatim as a differential
    /// oracle: loose-layout `matmul_nt_acc`, separate scale pass,
    /// scalar per-row online softmax, dense `for bj in 0..tc` scan with
    /// per-tile branch skipping.  The register-blocked/packed/
    /// interval-scheduled kernel must match it within 1e-5 (different
    /// float-accumulation order, identical math).
    #[allow(clippy::too_many_arguments)]
    fn reference_forward(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        mask: &FlashMask,
        table: &BlockTable,
        cfg: AttnConfig,
        skip: bool,
    ) -> AttnOutput {
        let (br, bc) = (cfg.br, cfg.bc);
        let (tr, tc) = (n.div_ceil(br), n.div_ceil(bc));
        let mut out = vec![0f32; n * d];
        let mut lse = vec![NEG_INF; n];
        let mut s = vec![0f32; br * bc];
        for bi in 0..tr {
            let row0 = bi * br;
            let rows = br.min(n - row0);
            let mut o_acc = vec![0f32; rows * d];
            let mut m_run = vec![NEG_INF; rows];
            let mut l_run = vec![0f32; rows];
            for bj in 0..tc {
                if tile_class(mask, table, bi, br, bj, bc, skip) == BlockClass::FullyMasked {
                    continue;
                }
                let col0 = bj * bc;
                let cols = bc.min(n - col0);
                let s_tile = &mut s[..rows * cols];
                s_tile.fill(0.0);
                gemm::matmul_nt_acc(
                    &q[row0 * d..(row0 + rows) * d],
                    &k[col0 * d..(col0 + cols) * d],
                    rows,
                    d,
                    cols,
                    s_tile,
                );
                for sv in s_tile.iter_mut() {
                    *sv *= cfg.scale;
                }
                let mut dummy = TileStats::default();
                apply_tile_mask(s_tile, mask, row0, rows, col0, cols, &mut dummy);
                for x in 0..rows {
                    let srow = &mut s_tile[x * cols..(x + 1) * cols];
                    let mut row_max = NEG_INF;
                    for &sv in srow.iter() {
                        row_max = row_max.max(sv);
                    }
                    let m_new = m_run[x].max(row_max);
                    let m_safe = if m_new.is_finite() { m_new } else { 0.0 };
                    let a = if m_run[x].is_finite() { (m_run[x] - m_safe).exp() } else { 0.0 };
                    let mut row_sum = 0f32;
                    for sv in srow.iter_mut() {
                        let p = (*sv - m_safe).exp();
                        *sv = p;
                        row_sum += p;
                    }
                    l_run[x] = a * l_run[x] + row_sum;
                    m_run[x] = m_new;
                    for dd in 0..d {
                        o_acc[x * d + dd] *= a;
                    }
                }
                gemm::matmul_nn_acc(
                    s_tile,
                    &v[col0 * d..(col0 + cols) * d],
                    rows,
                    cols,
                    d,
                    &mut o_acc,
                );
            }
            for x in 0..rows {
                if l_run[x] > 0.0 {
                    let inv = 1.0 / l_run[x];
                    for dd in 0..d {
                        out[(row0 + x) * d + dd] = o_acc[x * d + dd] * inv;
                    }
                    let m_safe = if m_run[x].is_finite() { m_run[x] } else { 0.0 };
                    lse[row0 + x] = m_safe + l_run[x].ln();
                }
            }
        }
        AttnOutput { o: out, lse }
    }

    #[test]
    fn forward_matches_pre_refactor_reference_all_masks_odd_shapes() {
        // satellite: output + lse within 1e-5 of the pre-refactor path
        // for every benchmark mask kind, at odd head dim (d = 80) and n
        // not a multiple of the tile size, plus the visit-count
        // invariant executed <= visited <= dense trip count
        for (n, d) in [(100usize, 80usize), (96, 16)] {
            let (q, k, v) = setup(n, d, 31);
            let cfg = AttnConfig::new(32, 32, d);
            for (kind, mask) in builders::benchmark_suite(n, 11) {
                let table = BlockTable::build(&mask, cfg.bc);
                let (got, st) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                let want = reference_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                for (i, (a, b)) in got.o.iter().zip(&want.o).enumerate() {
                    assert!((a - b).abs() < 1e-5, "{kind} n={n} d={d} o[{i}]: {a} vs {b}");
                }
                for (i, (a, b)) in got.lse.iter().zip(&want.lse).enumerate() {
                    if a.is_finite() || b.is_finite() {
                        assert!((a - b).abs() < 1e-5, "{kind} lse[{i}]: {a} vs {b}");
                    }
                }
                // interval scheduling: never fewer trips than executed
                // tiles, never more than the old dense scan paid
                assert!(
                    st.tiles_partial + st.tiles_unmasked <= st.tiles_visited,
                    "{kind}: visited {} < executed {}",
                    st.tiles_visited,
                    st.tiles_partial + st.tiles_unmasked
                );
                assert!(
                    st.tiles_visited <= st.tiles_total,
                    "{kind}: visited {} > dense trips {}",
                    st.tiles_visited,
                    st.tiles_total
                );
            }
        }
    }

    #[test]
    fn schedule_ranges_exclude_only_fully_masked_tiles() {
        // soundness of the per-row-block visit ranges: everything
        // outside [bj_lo, bj_hi) is FullyMasked, and the executed
        // weights agree with the class table
        let n = 128;
        let cfg = AttnConfig::new(32, 32, 8);
        for (kind, mask) in builders::benchmark_suite(n, 19) {
            let table = BlockTable::build(&mask, cfg.bc);
            let sched = TileSchedule::build(&mask, &table, n, cfg, true);
            for bi in 0..sched.tr {
                let (lo, hi) = sched.range(bi);
                let mut exec = 0u64;
                for bj in 0..sched.tc {
                    let class = sched.class(bi, bj);
                    assert_eq!(
                        class,
                        tile_class(&mask, &table, bi, cfg.br, bj, cfg.bc, true),
                        "{kind} ({bi},{bj})"
                    );
                    if bj < lo || bj >= hi {
                        assert_eq!(
                            class,
                            BlockClass::FullyMasked,
                            "{kind} ({bi},{bj}): outside range but not masked"
                        );
                    } else if class != BlockClass::FullyMasked {
                        exec += 1;
                    }
                }
                assert_eq!(exec, sched.weights()[bi], "{kind} row block {bi}");
                // tight endpoints: a non-empty range starts and ends on
                // executed tiles
                if lo < hi {
                    assert_ne!(sched.class(bi, lo), BlockClass::FullyMasked, "{kind} {bi}");
                    assert_ne!(sched.class(bi, hi - 1), BlockClass::FullyMasked, "{kind} {bi}");
                }
            }
        }
    }

    #[test]
    fn tile_mask_cache_matches_interval_tests() {
        // the cached byte maps must reproduce the element-wise interval
        // tests exactly (same masked positions), exist for every partial
        // tile of a skip schedule, and never exist elsewhere
        let n = 100;
        let cfg = AttnConfig::new(32, 16, 8);
        for (kind, mask) in builders::benchmark_suite(n, 29) {
            let table = BlockTable::build(&mask, cfg.bc);
            let sched = TileSchedule::build(&mask, &table, n, cfg, true);
            let mut cached_evals = 0u64;
            for bi in 0..sched.tr {
                for bj in 0..sched.tc {
                    let bits = sched.tile_mask(bi, bj);
                    if sched.class(bi, bj) != BlockClass::PartiallyMasked {
                        assert!(bits.is_none(), "{kind}: non-partial tile ({bi},{bj}) cached");
                        continue;
                    }
                    let bits =
                        bits.unwrap_or_else(|| panic!("{kind}: partial ({bi},{bj}) not cached"));
                    let row0 = bi * cfg.br;
                    let rows = cfg.br.min(n - row0);
                    let col0 = bj * cfg.bc;
                    let cols = cfg.bc.min(n - col0);
                    assert_eq!(bits.len(), rows * cols, "{kind} ({bi},{bj})");
                    for x in 0..rows {
                        for y in 0..cols {
                            assert_eq!(
                                bits[x * cols + y] != 0,
                                !mask.allowed(row0 + x, col0 + y),
                                "{kind} tile ({bi},{bj}) elem ({x},{y})"
                            );
                        }
                    }
                    cached_evals += (rows * cols) as u64;
                }
            }
            assert_eq!(cached_evals, sched.build_mask_evals(), "{kind}: build census");
        }
        // dense-baseline schedules build no cache: the baseline keeps
        // paying the per-pass element-wise masking it is meant to model
        let mask = builders::causal(64);
        let table = BlockTable::build(&mask, 16);
        let sched = TileSchedule::build(&mask, &table, 64, AttnConfig::new(16, 16, 8), false);
        for bi in 0..sched.tr {
            for bj in 0..sched.tc {
                assert!(sched.tile_mask(bi, bj).is_none());
            }
        }
    }

    #[test]
    fn mask_cache_budget_falls_back_bitwise() {
        // a mask whose partial-tile volume exceeds MASK_CACHE_BYTES:
        // per-column random half-height intervals make essentially every
        // tile partial, so an unbounded cache would hold ~n^2 bytes.
        // Tiles past the budget must stay uncached (per-pass
        // element-wise fallback) and the mixed cached/uncached forward
        // must still be bitwise equal to the dense baseline.
        let (n, d) = (3072, 2);
        let mut mask = FlashMask::empty(n, false);
        let mut x = 1u64;
        for j in 0..n {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let a = (x >> 33) as usize % (n / 2);
            mask.lts[j] = a as i32;
            mask.lte[j] = (a + n / 2) as i32;
        }
        mask.validate().unwrap();
        let cfg = AttnConfig::new(32, 32, d);
        let table = BlockTable::build(&mask, cfg.bc);
        let sched = TileSchedule::build(&mask, &table, n, cfg, true);
        let mut cached_bytes = 0usize;
        let mut uncached_partial = 0usize;
        for bi in 0..sched.tr {
            for bj in 0..sched.tc {
                if sched.class(bi, bj) == BlockClass::PartiallyMasked {
                    match sched.tile_mask(bi, bj) {
                        Some(bits) => cached_bytes += bits.len(),
                        None => uncached_partial += 1,
                    }
                }
            }
        }
        assert!(
            cached_bytes <= TileSchedule::MASK_CACHE_BYTES,
            "cache exceeded its budget: {cached_bytes}"
        );
        assert!(uncached_partial > 0, "workload too small to exercise the budget");
        let (q, k, v) = setup(n, d, 53);
        let (a, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
        let (b, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, false);
        assert_eq!(a.o, b.o, "mixed cached/uncached masking changed the result");
    }

    #[test]
    fn grouped_parallel_matches_sequential_bitwise() {
        // row blocks are independent, so any thread count must
        // reproduce the sequential kernel bit for bit — outputs, lse
        // and stats
        let (n, d) = (100, 8);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(41);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let cfg = AttnConfig::new(32, 16, d);
        for (kind, mask) in builders::benchmark_suite(n, 23) {
            let table = BlockTable::build(&mask, cfg.bc);
            let (want, ws) =
                flashmask_forward_grouped(&q, &k, &v, n, d, layout, &mask, &table, cfg, true);
            for threads in [2usize, 3, 8] {
                let (got, gs) = flashmask_forward_grouped_parallel(
                    &q, &k, &v, n, d, layout, &mask, &table, cfg, true, threads,
                );
                for h in 0..layout.q_heads {
                    assert_eq!(got[h].o, want[h].o, "{kind} t={threads} head {h}");
                    assert_eq!(got[h].lse, want[h].lse, "{kind} t={threads} head {h} lse");
                }
                assert_eq!(gs, ws, "{kind} t={threads}: stats diverged");
            }
        }
    }

    #[test]
    fn prop_forward_matches_dense_random_docs() {
        prop::check(
            "flash-vs-dense",
            crate::util::prop::PropConfig { cases: 16, base_seed: 77 },
            |rng| {
                let n = 64;
                let d = *rng.choose(&[4usize, 8, 16]);
                let k_docs = rng.range(1, 5) as usize;
                let lens = crate::workload::docgen::sample_doc_lens(n, k_docs, 1, rng);
                let mask = if rng.f64() < 0.5 {
                    builders::causal_document(n, &lens)
                } else {
                    builders::document(n, &lens)
                };
                let q = rand_vec(n * d, rng);
                let k = rand_vec(n * d, rng);
                let v = rand_vec(n * d, rng);
                let cfg = AttnConfig::new(*rng.choose(&[16usize, 32]), *rng.choose(&[16usize, 32]), d);
                let table = BlockTable::build(&mask, cfg.bc);
                let (got, _) = flashmask_forward(&q, &k, &v, n, d, &mask, &table, cfg, true);
                let want = dense::dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), cfg.scale);
                for (a, b) in got.o.iter().zip(&want.o) {
                    if (a - b).abs() > 3e-5 {
                        return Err(format!("mismatch {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}
