//! Unified attention API: problem builder, cached execution plans, and
//! pluggable backends (DESIGN.md §Public API).
//!
//! The kernel layer grew one free function per (engine × layout ×
//! threading) combination, each taking 9–11 positional arguments,
//! validating by `assert!`, and rebuilding the Eq. 4 tile schedule and
//! the packed-K layout on every call.  This module replaces that
//! surface with three nouns:
//!
//! * [`AttnProblem`] — a builder describing *what* to compute
//!   (`n`, `d`, [`HeadLayout`], mask, tile sizes, threads).  Validation
//!   is typed: every misuse returns an [`AttnError`] instead of
//!   panicking.
//! * [`ExecutionPlan`] — the compiled form of a problem: the Eq. 4
//!   [`TileSchedule`](crate::attention::flash) with its per-tile mask
//!   cache, the tile census, and reusable per-KV-head packed-K buffers.
//!   Repeated calls over the same mask/shape — every layer of a model,
//!   every step of a decode session — reuse classification, the
//!   element-wise interval tests, and packing storage instead of
//!   recomputing them.  [`PlanCache`] keys plans by content (shape +
//!   mask bytes), the seam prefix caching will later hash into.
//! * [`Backend`] — *where* to compute: [`CpuBackend`] (the packed /
//!   parallel blocked kernels), [`DenseRefBackend`] (the O(N²) oracle),
//!   and [`PjrtBackend`] (the AOT `attn_fwd` artifact), each honestly
//!   reporting its [`Capabilities`] so callers fall back explicitly
//!   rather than ad hoc.
//!
//! The pre-existing free functions (`flashmask_forward`,
//! `decode_step_group`, …) remain as deprecated shims delegating here,
//! so every differential oracle pinned to them doubles as a migration
//! test.
//!
//! ```
//! use flashmask::attention::api::{AttnProblem, Backend, CpuBackend, KvViews, QViews};
//! use flashmask::mask::builders;
//!
//! let (n, d) = (64, 8);
//! let mask = builders::causal_document(n, &[40, 24]);
//! let q = vec![0.1f32; n * d];
//! let k = vec![0.2f32; n * d];
//! let v = vec![0.3f32; n * d];
//!
//! let plan = AttnProblem::new(n, d).mask(&mask).tile(16, 16).plan()?;
//! let out = CpuBackend.prefill(
//!     &plan,
//!     QViews::new(&q, 1, n, d)?,
//!     KvViews::new(&k, &v, 1, n, d)?,
//! )?;
//! assert_eq!(out.outs.len(), 1);
//! assert_eq!(out.outs[0].o.len(), n * d);
//! assert!(out.stats.tiles_skipped > 0); // Eq. 4 pruned the dead tiles
//! # Ok::<(), flashmask::attention::api::AttnError>(())
//! ```

use super::flash::{self, TileSchedule};
use super::{
    dense, gemm, parallel_2d, AttnConfig, AttnGrads, AttnOutput, GroupedGrads, HeadLayout,
    TileStats,
};
use crate::decode::kvcache::{PagePool, PagedKv};
use crate::decode::step::DecodeStats;
use crate::mask::{BlockTable, FlashMask, IncrementalMaskView, TokenTree};
use crate::runtime::{Executable, HostTensor};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Largest packed-K working set an [`ExecutionPlan`] retains between
/// calls.  Small serving shapes amortize the packing allocations across
/// calls; past this bound the buffers are dropped after use so a
/// long-lived [`PlanCache`] never pins per-call K-derived memory.
const PACK_RETAIN_BYTES: usize = 4 << 20;

/// One backend operation, named for capability reporting and
/// [`AttnError::Unsupported`] diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Capability {
    /// Full-sequence forward over an MHA layout.
    Prefill,
    /// Full-sequence forward over a grouped (GQA/MQA) layout without
    /// host-side KV replication.
    PrefillGrouped,
    /// Single-token decode against a paged KV cache.
    DecodeStep,
    /// Multi-row speculative verify under a tree mask.
    Verify,
    /// Backward pass (gradients).
    Backward,
    /// Backward pass over a grouped (GQA/MQA) layout with dK/dV
    /// accumulated across the query group, without host-side KV
    /// replication.
    BackwardGrouped,
}

impl std::fmt::Display for Capability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Capability::Prefill => "prefill",
            Capability::PrefillGrouped => "prefill_grouped",
            Capability::DecodeStep => "decode_step",
            Capability::Verify => "verify",
            Capability::Backward => "backward",
            Capability::BackwardGrouped => "backward_grouped",
        })
    }
}

/// What a [`Backend`] can execute.  Callers (the serving engine, the
/// decode batcher) branch on this *before* dispatching, so a backend
/// that cannot run an operation is never asked to — and the fallback
/// that replaces it is recorded, not silent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Capabilities {
    pub prefill: bool,
    pub prefill_grouped: bool,
    pub decode: bool,
    pub verify: bool,
    pub backward: bool,
    pub backward_grouped: bool,
}

impl Capabilities {
    /// All operations supported (the CPU reference point).
    pub fn all() -> Capabilities {
        Capabilities {
            prefill: true,
            prefill_grouped: true,
            decode: true,
            verify: true,
            backward: true,
            backward_grouped: true,
        }
    }

    pub fn supports(&self, cap: Capability) -> bool {
        match cap {
            Capability::Prefill => self.prefill,
            Capability::PrefillGrouped => self.prefill_grouped,
            Capability::DecodeStep => self.decode,
            Capability::Verify => self.verify,
            Capability::Backward => self.backward,
            Capability::BackwardGrouped => self.backward_grouped,
        }
    }
}

/// Typed validation / dispatch error.  Every variant is reachable from
/// safe code through the builder (`tests/api_misuse.rs` constructs each
/// one); nothing in this module panics on caller input.
#[derive(Clone, Debug, PartialEq)]
pub enum AttnError {
    /// A tensor view's length or metadata disagrees with the problem.
    ShapeMismatch { what: &'static str, got: usize, want: usize },
    /// The problem was built without `.mask(&mask)`.
    MaskMissing,
    /// `mask.n()` differs from the problem's `n`.
    MaskSizeMismatch { got: usize, want: usize },
    /// The mask failed structural validation (inverted interval, out of
    /// bounds, causal with a UT interval, …).
    MaskInvalid { reason: String },
    /// `kv_heads == 0`, `q_heads == 0`, or `q_heads % kv_heads != 0`.
    UnsupportedLayout { q_heads: usize, kv_heads: usize },
    /// Zero tile size.
    InvalidTile { br: usize, bc: usize },
    /// Zero `n` or `d`.
    InvalidDim { what: &'static str },
    /// The backend does not implement this operation; consult
    /// [`Backend::capabilities`] before dispatching.
    Unsupported { backend: &'static str, capability: Capability },
    /// The backend accepted the problem but failed at runtime (e.g. a
    /// PJRT artifact signature mismatch).
    Backend { backend: &'static str, reason: String },
}

impl std::fmt::Display for AttnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttnError::ShapeMismatch { what, got, want } => {
                write!(f, "shape mismatch: {what} has {got} elements, expected {want}")
            }
            AttnError::MaskMissing => write!(f, "problem has no mask; call .mask(&mask)"),
            AttnError::MaskSizeMismatch { got, want } => {
                write!(f, "mask is over {got} columns but the problem has n = {want}")
            }
            AttnError::MaskInvalid { reason } => write!(f, "invalid mask: {reason}"),
            AttnError::UnsupportedLayout { q_heads, kv_heads } => write!(
                f,
                "unsupported head layout: {q_heads} query / {kv_heads} KV heads \
                 (need kv_heads >= 1 and q_heads a positive multiple of kv_heads)"
            ),
            AttnError::InvalidTile { br, bc } => {
                write!(f, "invalid tile sizes {br}x{bc} (both must be >= 1)")
            }
            AttnError::InvalidDim { what } => write!(f, "dimension '{what}' must be >= 1"),
            AttnError::Unsupported { backend, capability } => {
                write!(f, "backend '{backend}' does not support '{capability}'")
            }
            AttnError::Backend { backend, reason } => {
                write!(f, "backend '{backend}' failed: {reason}")
            }
        }
    }
}

impl std::error::Error for AttnError {}

/// Borrowed query tensor: head-major `[heads, n, d]` with its shape
/// metadata, length-checked at construction.
#[derive(Clone, Copy, Debug)]
pub struct QViews<'a> {
    pub data: &'a [f32],
    pub heads: usize,
    pub n: usize,
    pub d: usize,
}

impl<'a> QViews<'a> {
    pub fn new(data: &'a [f32], heads: usize, n: usize, d: usize) -> Result<QViews<'a>, AttnError> {
        if data.len() != heads * n * d {
            return Err(AttnError::ShapeMismatch {
                what: "q",
                got: data.len(),
                want: heads * n * d,
            });
        }
        Ok(QViews { data, heads, n, d })
    }

    /// Head `h`'s `[n, d]` rows.
    pub fn head(&self, h: usize) -> &'a [f32] {
        &self.data[h * self.n * self.d..(h + 1) * self.n * self.d]
    }
}

/// Borrowed key/value tensors: head-major `[heads, n, d]` each (KV
/// heads under GQA), length-checked at construction.
#[derive(Clone, Copy, Debug)]
pub struct KvViews<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub heads: usize,
    pub n: usize,
    pub d: usize,
}

impl<'a> KvViews<'a> {
    pub fn new(
        k: &'a [f32],
        v: &'a [f32],
        heads: usize,
        n: usize,
        d: usize,
    ) -> Result<KvViews<'a>, AttnError> {
        let want = heads * n * d;
        if k.len() != want {
            return Err(AttnError::ShapeMismatch { what: "k", got: k.len(), want });
        }
        if v.len() != want {
            return Err(AttnError::ShapeMismatch { what: "v", got: v.len(), want });
        }
        Ok(KvViews { k, v, heads, n, d })
    }

    /// KV head `h`'s `[n, d]` key rows.
    pub fn k_head(&self, h: usize) -> &'a [f32] {
        &self.k[h * self.n * self.d..(h + 1) * self.n * self.d]
    }

    /// KV head `h`'s `[n, d]` value rows.
    pub fn v_head(&self, h: usize) -> &'a [f32] {
        &self.v[h * self.n * self.d..(h + 1) * self.n * self.d]
    }
}

/// Builder describing one attention problem.  All setters are
/// chainable; nothing validates until [`plan`](Self::plan) /
/// [`key`](Self::key), which return typed [`AttnError`]s instead of
/// panicking.
///
/// ```
/// use flashmask::attention::api::AttnProblem;
/// use flashmask::attention::HeadLayout;
/// use flashmask::mask::builders;
///
/// let mask = builders::causal(128);
/// let plan = AttnProblem::new(128, 16)
///     .layout(HeadLayout::gqa(8, 2))
///     .mask(&mask)
///     .tile(32, 32)
///     .threads(4)
///     .plan()?;
/// assert_eq!(plan.layout().group(), 4);
/// # Ok::<(), flashmask::attention::api::AttnError>(())
/// ```
#[derive(Clone, Copy, Debug)]
pub struct AttnProblem<'m> {
    n: usize,
    d: usize,
    q_heads: usize,
    kv_heads: usize,
    mask: Option<&'m FlashMask>,
    br: usize,
    bc: usize,
    scale: Option<f32>,
    threads: usize,
    skip: bool,
}

impl<'m> AttnProblem<'m> {
    /// A single-head problem over an `n x n` score matrix at head dim
    /// `d`, with 64×64 tiles (clamped to `n`), softmax scale
    /// `1/sqrt(d)`, Eq. 4 skipping on, one thread.
    pub fn new(n: usize, d: usize) -> AttnProblem<'m> {
        AttnProblem {
            n,
            d,
            q_heads: 1,
            kv_heads: 1,
            mask: None,
            br: 64.min(n.max(1)),
            bc: 64.min(n.max(1)),
            scale: None,
            threads: 1,
            skip: true,
        }
    }

    /// Set the head layout from an already-validated [`HeadLayout`].
    pub fn layout(mut self, layout: HeadLayout) -> Self {
        self.q_heads = layout.q_heads;
        self.kv_heads = layout.kv_heads;
        self
    }

    /// Set raw head counts; validated at [`plan`](Self::plan) (an
    /// indivisible or zero count yields
    /// [`AttnError::UnsupportedLayout`] instead of the panic
    /// [`HeadLayout::new`] would raise).
    pub fn heads(mut self, q_heads: usize, kv_heads: usize) -> Self {
        self.q_heads = q_heads;
        self.kv_heads = kv_heads;
        self
    }

    /// Attach the column-interval mask (borrowed; the plan clones it).
    pub fn mask(mut self, mask: &'m FlashMask) -> Self {
        self.mask = Some(mask);
        self
    }

    /// Tile sizes (`Br` query rows × `Bc` key columns).
    pub fn tile(mut self, br: usize, bc: usize) -> Self {
        self.br = br;
        self.bc = bc;
        self
    }

    /// Override the softmax scale (default `1/sqrt(d)`).
    pub fn scale(mut self, scale: f32) -> Self {
        self.scale = Some(scale);
        self
    }

    /// Maximum OS threads for (head × row-block) work partitioning.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Eq. 4 tile skipping (`false` = the dense-mask baseline that
    /// computes and element-masks every tile).
    pub fn skip(mut self, skip: bool) -> Self {
        self.skip = skip;
        self
    }

    fn cfg(&self) -> AttnConfig {
        AttnConfig {
            br: self.br,
            bc: self.bc,
            scale: self.scale.unwrap_or(1.0 / (self.d.max(1) as f32).sqrt()),
        }
    }

    /// Typed validation; returns the resolved layout and mask.
    pub fn validate(&self) -> Result<(HeadLayout, &'m FlashMask), AttnError> {
        if self.n == 0 {
            return Err(AttnError::InvalidDim { what: "n" });
        }
        if self.d == 0 {
            return Err(AttnError::InvalidDim { what: "d" });
        }
        if self.br == 0 || self.bc == 0 {
            return Err(AttnError::InvalidTile { br: self.br, bc: self.bc });
        }
        if self.q_heads == 0 || self.kv_heads == 0 || self.q_heads % self.kv_heads != 0 {
            return Err(AttnError::UnsupportedLayout {
                q_heads: self.q_heads,
                kv_heads: self.kv_heads,
            });
        }
        let mask = self.mask.ok_or(AttnError::MaskMissing)?;
        if mask.n() != self.n {
            return Err(AttnError::MaskSizeMismatch { got: mask.n(), want: self.n });
        }
        mask.validate().map_err(|e| AttnError::MaskInvalid { reason: format!("{e:#}") })?;
        Ok((HeadLayout::new(self.q_heads, self.kv_heads), mask))
    }

    /// Compile the problem: build the [`BlockTable`], the Eq. 4 tile
    /// schedule with its per-tile mask cache, and the census.
    /// This is the cost [`PlanCache`] amortizes across repeated calls.
    pub fn plan(&self) -> Result<ExecutionPlan, AttnError> {
        let sp = crate::telemetry::trace::span(crate::telemetry::names::PLAN_BUILD);
        let (layout, mask) = self.validate()?;
        let cfg = self.cfg();
        let table = BlockTable::build(mask, cfg.bc);
        let sched = TileSchedule::build(mask, &table, self.n, cfg, self.skip);
        let census = sched.census();
        crate::telemetry::metrics::global().add(crate::telemetry::names::PLAN_BUILDS, 1);
        sp.add("tiles", (sched.tr * sched.tc) as u64);
        Ok(ExecutionPlan {
            n: self.n,
            d: self.d,
            layout,
            cfg,
            skip: self.skip,
            threads: self.threads,
            mask: mask.clone(),
            sched,
            census,
            packs: Mutex::new(Vec::new()),
        })
    }

    /// Content key for [`PlanCache`]: shape, layout, tiling, scale bits
    /// and an FNV-1a hash over a bounded stride-sample of the mask's
    /// four interval vectors (≤ ~64 probes per vector, so keying a hit
    /// costs O(1) rather than O(n) as sequences grow).  The hash is a
    /// fast path only: the cache verifies **full mask equality** on
    /// every hit, so sampling can at worst cause a rebuild, never a
    /// wrong plan.
    pub fn key(&self) -> Result<PlanKey, AttnError> {
        let (_, mask) = self.validate()?;
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let stride = (mask.n() / 64).max(1);
        for vs in [&mask.lts, &mask.lte, &mask.uts, &mask.ute] {
            h = fnv1a_sampled(h, vs, stride);
        }
        Ok(PlanKey {
            n: self.n,
            d: self.d,
            q_heads: self.q_heads,
            kv_heads: self.kv_heads,
            br: self.br,
            bc: self.bc,
            // execution policy, not content — but the plan carries it,
            // so two thread policies must not share one cached plan
            threads: self.threads,
            skip: self.skip,
            causal: mask.causal,
            scale_bits: self.cfg().scale.to_bits(),
            mask_hash: h,
        })
    }
}

fn fnv1a_i32(mut h: u64, v: i32) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over every `stride`-th element plus the last (tail changes —
/// a mask extended by one column — always perturb the key).
fn fnv1a_sampled(mut h: u64, vs: &[i32], stride: usize) -> u64 {
    let mut j = 0;
    while j < vs.len() {
        h = fnv1a_i32(h, vs[j]);
        j += stride;
    }
    if let Some(&last) = vs.last() {
        h = fnv1a_i32(h, last);
    }
    h
}

/// Content key of an [`ExecutionPlan`] (see [`AttnProblem::key`]).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    n: usize,
    d: usize,
    q_heads: usize,
    kv_heads: usize,
    br: usize,
    bc: usize,
    threads: usize,
    skip: bool,
    causal: bool,
    scale_bits: u32,
    mask_hash: u64,
}

/// A compiled attention problem: everything derivable from the mask
/// and shape alone, computed once and reused across calls.
///
/// Owns the Eq. 4 `TileSchedule` (classes, per-row-block visit
/// ranges, cost weights, and the per-tile mask cache), a clone of the
/// mask, the tile census, and the per-KV-head [`gemm::PackedKt`]
/// packing buffers.  Packing *contents*
/// are refreshed from the K views on every call (K is data, not part
/// of the plan key); the buffers themselves — and every mask-derived
/// structure — are reused.
pub struct ExecutionPlan {
    n: usize,
    d: usize,
    layout: HeadLayout,
    cfg: AttnConfig,
    skip: bool,
    threads: usize,
    mask: FlashMask,
    sched: TileSchedule,
    /// One classification pass's tile census (incl. the mask-cache
    /// build cost as `mask_evals`) — charged once per KV head per call.
    census: TileStats,
    /// Reusable per-KV-head packed-K buffers, refreshed per call,
    /// taken out under a scoped lock for the duration of a call (so
    /// concurrent sharers never serialize on compute), and returned
    /// only while under [`PACK_RETAIN_BYTES`] (so cached plans never
    /// pin large per-call K-derived memory).
    packs: Mutex<Vec<gemm::PackedKt>>,
}

impl ExecutionPlan {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn layout(&self) -> HeadLayout {
        self.layout
    }

    pub fn skip(&self) -> bool {
        self.skip
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn scale(&self) -> f32 {
        self.cfg.scale
    }

    /// Tile grid `(tr, tc)`.
    pub fn tiles(&self) -> (usize, usize) {
        (self.sched.tr, self.sched.tc)
    }

    /// The plan's owned copy of the mask.
    pub fn mask(&self) -> &FlashMask {
        &self.mask
    }

    /// One classification pass's tile census (see [`TileStats`]).
    pub fn census(&self) -> TileStats {
        self.census
    }

    fn same_mask(&self, mask: &FlashMask) -> bool {
        self.mask == *mask
    }

    fn check_views(&self, q: QViews<'_>, kv: KvViews<'_>) -> Result<(), AttnError> {
        if q.heads != self.layout.q_heads {
            return Err(AttnError::ShapeMismatch {
                what: "q view heads",
                got: q.heads,
                want: self.layout.q_heads,
            });
        }
        if kv.heads != self.layout.kv_heads {
            return Err(AttnError::ShapeMismatch {
                what: "kv view heads",
                got: kv.heads,
                want: self.layout.kv_heads,
            });
        }
        if q.n != self.n || kv.n != self.n {
            return Err(AttnError::ShapeMismatch {
                what: "view n",
                got: if q.n != self.n { q.n } else { kv.n },
                want: self.n,
            });
        }
        if q.d != self.d || kv.d != self.d {
            return Err(AttnError::ShapeMismatch {
                what: "view d",
                got: if q.d != self.d { q.d } else { kv.d },
                want: self.d,
            });
        }
        Ok(())
    }
}

/// Forward result: one [`AttnOutput`] per query head (query-head
/// order) plus the merged work counters.
#[derive(Clone, Debug)]
pub struct PrefillOutput {
    pub outs: Vec<AttnOutput>,
    pub stats: TileStats,
}

/// Arguments for one paged-cache decode step (the whole query group of
/// one KV head; see `decode::step`).
pub struct DecodeStep<'a> {
    /// `[group, d]` query rows, query-head order within the group.
    pub q_rows: &'a [f32],
    pub group: usize,
    pub cache: &'a PagedKv,
    pub pool: &'a PagePool,
    pub mask: &'a FlashMask,
    pub view: &'a IncrementalMaskView,
    /// Decode row (the cache already holds rows `0..=t`).
    pub t: usize,
    pub scale: f32,
    pub skip: bool,
}

/// Arguments for one speculative verify pass (all drafted rows of the
/// whole query group of one KV head; see `decode::spec`).
pub struct VerifyStep<'a> {
    /// `[group, tree.len(), d]` drafted query rows, query-head-major.
    pub q_rows: &'a [f32],
    pub group: usize,
    pub cache: &'a PagedKv,
    pub pool: &'a PagePool,
    pub base: &'a FlashMask,
    pub base_view: &'a IncrementalMaskView,
    pub tree: &'a TokenTree,
    pub tree_mask: &'a FlashMask,
    pub tree_view: &'a IncrementalMaskView,
    /// First drafted position (the committed prefix is `0..t0`).
    pub t0: usize,
    pub scale: f32,
    pub skip: bool,
}

/// An attention execution target.  Implementations report what they
/// can run via [`capabilities`](Self::capabilities); unsupported
/// operations return [`AttnError::Unsupported`] (the default method
/// bodies), never a silent wrong answer.
pub trait Backend {
    fn name(&self) -> &'static str;

    fn capabilities(&self) -> Capabilities;

    /// Full-sequence forward over an MHA layout.  The default treats
    /// MHA as a group-1 grouped layout.
    fn prefill(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError> {
        self.prefill_grouped(plan, q, kv)
    }

    /// Full-sequence forward over any [`HeadLayout`]: Q `[q_heads, n,
    /// d]` against shared K/V `[kv_heads, n, d]`.
    fn prefill_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError>;

    /// Decode one token for a query group against a paged KV cache.
    /// Returns the `[group, d]` output rows.
    fn decode_step(
        &self,
        step: DecodeStep<'_>,
        stats: &mut DecodeStats,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<f32>, AttnError> {
        let _ = (step, stats, scratch);
        Err(AttnError::Unsupported { backend: self.name(), capability: Capability::DecodeStep })
    }

    /// Score all drafted rows of a query group in one pass over the
    /// cache pages.  Returns the `[group, tree.len(), d]` output rows.
    fn verify(
        &self,
        step: VerifyStep<'_>,
        stats: &mut DecodeStats,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<f32>, AttnError> {
        let _ = (step, stats, scratch);
        Err(AttnError::Unsupported { backend: self.name(), capability: Capability::Verify })
    }

    /// Backward pass for a single head (`q,k,v,o,do,lse` as in paper
    /// Alg. 2).
    #[allow(clippy::too_many_arguments)]
    fn backward(
        &self,
        plan: &ExecutionPlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &[f32],
        do_: &[f32],
        lse: &[f32],
    ) -> Result<(AttnGrads, TileStats), AttnError> {
        let _ = (plan, q, k, v, o, do_, lse);
        Err(AttnError::Unsupported { backend: self.name(), capability: Capability::Backward })
    }

    /// Backward pass over any [`HeadLayout`]: per-query-head `o`/`do`
    /// `[q_heads, n, d]` and `lse` `[q_heads, n]` against shared K/V
    /// `[kv_heads, n, d]`.  Returns one dQ per query head and one
    /// dK/dV per KV head (accumulated across the query group).
    #[allow(clippy::too_many_arguments)]
    fn backward_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
        o: &[f32],
        do_: &[f32],
        lse: &[f32],
    ) -> Result<(GroupedGrads, TileStats), AttnError> {
        let _ = (plan, q, kv, o, do_, lse);
        Err(AttnError::Unsupported { backend: self.name(), capability: Capability::BackwardGrouped })
    }
}

/// The CPU blocked engine: register-blocked packed microkernels,
/// interval-driven tile scheduling, per-tile mask cache, and
/// cost-weighted (head × row-block) work partitioning.  Supports every
/// capability; the reference all other backends are pinned to.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpuBackend;

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::all()
    }

    fn prefill_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError> {
        plan.check_views(q, kv)?;
        let (n, d) = (plan.n, plan.d);
        let layout = plan.layout;
        let cfg = plan.cfg;
        let sched = &plan.sched;

        // Take the reusable packing buffers *out* of the plan under a
        // scoped lock, then compute unlocked: concurrent callers sharing
        // one cached Arc<ExecutionPlan> never serialize on the kernel —
        // a racing call simply finds the slot empty and packs into
        // fresh buffers.  Contents are always repacked (K is data, and
        // the plan key covers only mask/shape).
        let mut packs = {
            let mut slot = plan.packs.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *slot)
        };
        {
            let sp = crate::telemetry::trace::span(crate::telemetry::names::PREFILL_PACK);
            if packs.len() != layout.kv_heads {
                packs.clear();
                packs.resize_with(layout.kv_heads, || gemm::PackedKt::empty(cfg.bc));
            }
            for (kh, kt) in packs.iter_mut().enumerate() {
                kt.repack(kv.k_head(kh), n, d);
            }
            sp.add("kv_heads", layout.kv_heads as u64);
        }
        let kts: &[gemm::PackedKt] = &packs;
        let sp_tiles = crate::telemetry::trace::span(crate::telemetry::names::PREFILL_TILES);

        // one classification pass per KV head; the query group reuses
        // both the classes and the per-tile mask cache
        let mut stats = TileStats::default();
        for _ in 0..layout.kv_heads {
            stats.merge(&plan.census);
        }

        let tr = sched.tr;
        let mut outs: Vec<AttnOutput> = Vec::with_capacity(layout.q_heads);
        if plan.threads <= 1 {
            // sequential fast path: no thread-scope round trip
            for h in 0..layout.q_heads {
                let kh = layout.kv_head_of(h);
                let out = flash::forward_tiles(
                    q.head(h),
                    &kts[kh],
                    kv.v_head(kh),
                    n,
                    d,
                    &plan.mask,
                    cfg,
                    sched,
                    &mut stats,
                );
                outs.push(out);
            }
        } else {
            let results =
                parallel_2d(layout.q_heads, tr, sched.weights(), plan.threads, |h, bi| {
                    let kh = layout.kv_head_of(h);
                    let mut st = TileStats::default();
                    let (ob, lb) = flash::forward_row_block(
                        q.head(h),
                        &kts[kh],
                        kv.v_head(kh),
                        n,
                        d,
                        &plan.mask,
                        cfg,
                        sched,
                        bi,
                        &mut st,
                    );
                    (ob, lb, st)
                });
            // stitch head-major, row-block-minor items back into
            // per-head outputs; stats merge in item order (additive)
            let mut items = results.into_iter();
            for _h in 0..layout.q_heads {
                let mut o = vec![0f32; n * d];
                let mut lse = vec![f32::NEG_INFINITY; n];
                for bi in 0..tr {
                    let (ob, lb, st) = items.next().expect("one item per (head, row block)");
                    stats.merge(&st);
                    let row0 = bi * cfg.br;
                    o[row0 * d..row0 * d + ob.len()].copy_from_slice(&ob);
                    lse[row0..row0 + lb.len()].copy_from_slice(&lb);
                }
                outs.push(AttnOutput { o, lse });
            }
        }

        sp_tiles.add("tiles_visited", stats.tiles_visited as u64);
        drop(sp_tiles);
        stats.publish();

        // hand the buffers back for the next call — unless they are big
        // enough to matter as resident memory: a long-lived PlanCache
        // must not pin per-call K-derived bytes at long context, so
        // large buffers are dropped instead of cached
        let dp = d.div_ceil(gemm::LANES) * gemm::LANES;
        let packed_bytes = layout.kv_heads * n * dp * std::mem::size_of::<f32>();
        if packed_bytes <= PACK_RETAIN_BYTES {
            *plan.packs.lock().unwrap_or_else(|p| p.into_inner()) = packs;
        }
        Ok(PrefillOutput { outs, stats })
    }

    fn decode_step(
        &self,
        step: DecodeStep<'_>,
        stats: &mut DecodeStats,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<f32>, AttnError> {
        if step.group == 0 {
            return Err(AttnError::InvalidDim { what: "group" });
        }
        let want = step.group * step.pool.d();
        if step.q_rows.len() != want {
            return Err(AttnError::ShapeMismatch {
                what: "decode q rows",
                got: step.q_rows.len(),
                want,
            });
        }
        if step.view.page_size() != step.pool.page_size() {
            return Err(AttnError::ShapeMismatch {
                what: "mask view page size",
                got: step.view.page_size(),
                want: step.pool.page_size(),
            });
        }
        if step.t >= step.mask.n() {
            // the kernel indexes the interval vectors at row t; an
            // out-of-range row must be a typed error, not a panic
            return Err(AttnError::MaskSizeMismatch {
                got: step.mask.n(),
                want: step.t + 1,
            });
        }
        Ok(crate::decode::step::decode_step_group_impl(
            step.q_rows,
            step.group,
            step.cache,
            step.pool,
            step.mask,
            step.view,
            step.t,
            step.scale,
            step.skip,
            stats,
            scratch,
        ))
    }

    fn verify(
        &self,
        step: VerifyStep<'_>,
        stats: &mut DecodeStats,
        scratch: &mut Vec<f32>,
    ) -> Result<Vec<f32>, AttnError> {
        if step.group == 0 {
            return Err(AttnError::InvalidDim { what: "group" });
        }
        let want = step.group * step.tree.len() * step.pool.d();
        if step.q_rows.len() != want {
            return Err(AttnError::ShapeMismatch {
                what: "verify q rows",
                got: step.q_rows.len(),
                want,
            });
        }
        if step.tree_mask.n() != step.t0 + step.tree.len() {
            return Err(AttnError::MaskSizeMismatch {
                got: step.tree_mask.n(),
                want: step.t0 + step.tree.len(),
            });
        }
        if step.t0 + step.tree.max_path_len() > step.base.n() {
            // drafted rows evaluate the base mask at their *logical*
            // positions t0 + depth(node); a path running past the mask
            // end must be a typed error, not an indexing panic.  (Node
            // *count* may legitimately exceed the remaining rows —
            // rejected sibling branches share depths.)
            return Err(AttnError::MaskSizeMismatch {
                got: step.base.n(),
                want: step.t0 + step.tree.max_path_len(),
            });
        }
        Ok(crate::decode::spec::verify_rows_group_impl(
            step.q_rows,
            step.group,
            step.cache,
            step.pool,
            step.base,
            step.base_view,
            step.tree,
            step.tree_mask,
            step.tree_view,
            step.t0,
            step.scale,
            step.skip,
            stats,
            scratch,
        ))
    }

    fn backward(
        &self,
        plan: &ExecutionPlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &[f32],
        do_: &[f32],
        lse: &[f32],
    ) -> Result<(AttnGrads, TileStats), AttnError> {
        let (n, d) = (plan.n, plan.d);
        for (what, buf) in [("q", q), ("k", k), ("v", v), ("o", o), ("do", do_)] {
            if buf.len() != n * d {
                return Err(AttnError::ShapeMismatch { what, got: buf.len(), want: n * d });
            }
        }
        if lse.len() != n {
            return Err(AttnError::ShapeMismatch { what: "lse", got: lse.len(), want: n });
        }
        let sp = crate::telemetry::trace::span(crate::telemetry::names::PLAN_BACKWARD);
        let t0 = std::time::Instant::now();
        let (grads, stats) = flash::backward_impl(
            q,
            k,
            v,
            o,
            do_,
            lse,
            n,
            d,
            &plan.mask,
            plan.cfg,
            &plan.sched,
            plan.threads,
        );
        crate::telemetry::metrics::global()
            .observe_ms(crate::telemetry::names::TRAIN_BACKWARD_MS, t0.elapsed().as_secs_f64() * 1e3);
        sp.add("tiles_partial", stats.tiles_partial as u64);
        sp.add("macs", stats.macs);
        stats.publish();
        Ok((grads, stats))
    }

    fn backward_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
        o: &[f32],
        do_: &[f32],
        lse: &[f32],
    ) -> Result<(GroupedGrads, TileStats), AttnError> {
        plan.check_views(q, kv)?;
        let (n, d) = (plan.n, plan.d);
        let q_heads = plan.layout.q_heads;
        for (what, buf) in [("o", o), ("do", do_)] {
            if buf.len() != q_heads * n * d {
                return Err(AttnError::ShapeMismatch {
                    what,
                    got: buf.len(),
                    want: q_heads * n * d,
                });
            }
        }
        if lse.len() != q_heads * n {
            return Err(AttnError::ShapeMismatch { what: "lse", got: lse.len(), want: q_heads * n });
        }
        let sp = crate::telemetry::trace::span(crate::telemetry::names::PLAN_BACKWARD);
        let t0 = std::time::Instant::now();
        let (grads, stats) = flash::backward_grouped_impl(
            q.data,
            kv.k,
            kv.v,
            o,
            do_,
            lse,
            n,
            d,
            plan.layout,
            &plan.mask,
            plan.cfg,
            &plan.sched,
            plan.threads,
        );
        crate::telemetry::metrics::global()
            .observe_ms(crate::telemetry::names::TRAIN_BACKWARD_MS, t0.elapsed().as_secs_f64() * 1e3);
        sp.add("tiles_partial", stats.tiles_partial as u64);
        sp.add("macs", stats.macs);
        stats.publish();
        Ok((grads, stats))
    }
}

/// The vanilla O(N²) dense oracle (paper Eq. 2) behind the same trait —
/// what differential suites pin the blocked kernels to.  No paged-cache
/// path: `decode`/`verify` are honestly unsupported.
#[derive(Clone, Copy, Debug, Default)]
pub struct DenseRefBackend;

impl DenseRefBackend {
    /// Dense forward from an explicit additive bias (`0 / -inf`,
    /// row-major `n*n`) — the raw entry the deprecated
    /// `dense_forward*` free functions delegate to.  `threads <= 1`
    /// runs the sequential per-head loop; otherwise rows are
    /// partitioned with `parallel_2d` (bitwise identical).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_bias(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        layout: HeadLayout,
        bias: &[f32],
        scale: f32,
        threads: usize,
    ) -> Vec<AttnOutput> {
        if threads <= 1 {
            (0..layout.q_heads)
                .map(|h| {
                    let kh = layout.kv_head_of(h);
                    dense::forward_impl(
                        &q[h * n * d..(h + 1) * n * d],
                        &k[kh * n * d..(kh + 1) * n * d],
                        &v[kh * n * d..(kh + 1) * n * d],
                        n,
                        d,
                        bias,
                        scale,
                    )
                })
                .collect()
        } else {
            dense::grouped_parallel_impl(q, k, v, n, d, layout, bias, scale, threads)
        }
    }
}

impl Backend for DenseRefBackend {
    fn name(&self) -> &'static str {
        "dense-ref"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            prefill: true,
            prefill_grouped: true,
            decode: false,
            verify: false,
            backward: true,
            backward_grouped: false, // single-head oracle; grouped suites replicate KV themselves
        }
    }

    fn prefill_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError> {
        plan.check_views(q, kv)?;
        let (n, d) = (plan.n, plan.d);
        let bias = plan.mask.dense_bias();
        let outs = self.forward_bias(
            q.data,
            kv.k,
            kv.v,
            n,
            d,
            plan.layout,
            &bias,
            plan.cfg.scale,
            plan.threads,
        );
        // the dense engine has no tile census; it computes every score
        let stats = TileStats {
            macs: 2 * (plan.layout.q_heads * n * n * d) as u64,
            mask_evals: (n * n) as u64,
            ..TileStats::default()
        };
        Ok(PrefillOutput { outs, stats })
    }

    fn backward(
        &self,
        plan: &ExecutionPlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &[f32],
        do_: &[f32],
        lse: &[f32],
    ) -> Result<(AttnGrads, TileStats), AttnError> {
        let (n, d) = (plan.n, plan.d);
        for (what, buf) in [("q", q), ("k", k), ("v", v), ("o", o), ("do", do_)] {
            if buf.len() != n * d {
                return Err(AttnError::ShapeMismatch { what, got: buf.len(), want: n * d });
            }
        }
        if lse.len() != n {
            return Err(AttnError::ShapeMismatch { what: "lse", got: lse.len(), want: n });
        }
        let bias = plan.mask.dense_bias();
        let grads = dense::dense_backward(q, k, v, o, do_, lse, n, d, &bias, plan.cfg.scale);
        Ok((grads, TileStats::default()))
    }
}

/// The AOT-compiled Pallas `attn_fwd` artifact via PJRT.  Wraps
/// today's artifact path honestly: the compiled signature is MHA-only
/// and single-problem, returns no logsumexp residuals, and there is no
/// decode/verify/backward artifact yet — all reported through
/// [`Capabilities`] so `ServeEngine` falls back *explicitly* (counted
/// and logged) instead of ad hoc.
pub struct PjrtBackend {
    exe: Executable,
}

impl PjrtBackend {
    pub fn new(exe: Executable) -> PjrtBackend {
        PjrtBackend { exe }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            prefill: true,
            prefill_grouped: false, // grouped decode artifact: ROADMAP
            decode: false,          // no AOT decode artifact compiled yet
            verify: false,
            backward: false,         // train-step artifacts fuse their own backward
            backward_grouped: false, // ditto
        }
    }

    fn prefill(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError> {
        plan.check_views(q, kv)?;
        if !plan.layout.is_mha() {
            return Err(AttnError::Unsupported {
                backend: self.name(),
                capability: Capability::PrefillGrouped,
            });
        }
        let (n, d, heads) = (plan.n, plan.d, plan.layout.q_heads);
        let shape4 = vec![1, heads, n, d];
        let vec_t = |v: &Vec<i32>| HostTensor::I32 { shape: vec![1, n], data: v.clone() };
        let out = self
            .exe
            .run(&[
                HostTensor::F32 { shape: shape4.clone(), data: q.data.to_vec() },
                HostTensor::F32 { shape: shape4.clone(), data: kv.k.to_vec() },
                HostTensor::F32 { shape: shape4, data: kv.v.to_vec() },
                vec_t(&plan.mask.lts),
                vec_t(&plan.mask.lte),
                vec_t(&plan.mask.uts),
                vec_t(&plan.mask.ute),
            ])
            .map_err(|e| AttnError::Backend { backend: "pjrt", reason: format!("{e:#}") })?;
        let flat = out
            .first()
            .ok_or_else(|| AttnError::Backend {
                backend: "pjrt",
                reason: "empty result tuple".into(),
            })?
            .as_f32()
            .map_err(|e| AttnError::Backend { backend: "pjrt", reason: format!("{e:#}") })?;
        if flat.len() != heads * n * d {
            return Err(AttnError::ShapeMismatch {
                what: "pjrt output",
                got: flat.len(),
                want: heads * n * d,
            });
        }
        let outs = (0..heads)
            .map(|h| AttnOutput {
                o: flat[h * n * d..(h + 1) * n * d].to_vec(),
                // the artifact returns no logsumexp residuals
                lse: Vec::new(),
            })
            .collect();
        // work accounting from the plan census: the Eq. 4 skip decision
        // is a property of the mask, identical on device
        let mut stats = TileStats::default();
        for _ in 0..heads {
            stats.merge(&plan.census);
        }
        Ok(PrefillOutput { outs, stats })
    }

    fn prefill_grouped(
        &self,
        plan: &ExecutionPlan,
        q: QViews<'_>,
        kv: KvViews<'_>,
    ) -> Result<PrefillOutput, AttnError> {
        if plan.layout.is_mha() {
            return self.prefill(plan, q, kv);
        }
        Err(AttnError::Unsupported {
            backend: self.name(),
            capability: Capability::PrefillGrouped,
        })
    }
}

/// Content-keyed cache of [`ExecutionPlan`]s with FIFO eviction.
///
/// Keyed by [`AttnProblem::key`] (shape + tiling + mask-byte hash); a
/// hash hit is double-checked against the stored plan's mask bytes, so
/// a 64-bit collision degrades to a rebuild, never a wrong plan.  Hit
/// and miss counters feed the serving report and the bench's
/// plan-cache section.
pub struct PlanCache {
    cap: usize,
    map: HashMap<PlanKey, Arc<ExecutionPlan>>,
    order: VecDeque<PlanKey>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PlanCache {
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Hits / lookups, 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Return the cached plan for `problem`, building (and caching) it
    /// on miss.
    pub fn get_or_build(
        &mut self,
        problem: &AttnProblem<'_>,
    ) -> Result<Arc<ExecutionPlan>, AttnError> {
        let key = problem.key()?;
        let mut collided = false;
        if let Some(plan) = self.map.get(&key) {
            // key() already validated, so the mask is present
            let mask = problem.mask.expect("validated problem has a mask");
            if plan.same_mask(mask) {
                self.hits += 1;
                crate::telemetry::metrics::global().add(crate::telemetry::names::PLAN_CACHE_HITS, 1);
                return Ok(Arc::clone(plan));
            }
            // hash collision (the sampled key aliased two masks): the
            // rebuild below overwrites the slot in place — the key is
            // already in the FIFO queue, so it must NOT be re-queued
            // (a duplicate would corrupt the eviction accounting)
            collided = true;
        }
        self.misses += 1;
        crate::telemetry::metrics::global().add(crate::telemetry::names::PLAN_CACHE_MISSES, 1);
        let plan = Arc::new(problem.plan()?);
        if !collided {
            if self.map.len() >= self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                    self.evictions += 1;
                    crate::telemetry::metrics::global().add(crate::telemetry::names::PLAN_CACHE_EVICTIONS, 1);
                }
            }
            self.order.push_back(key.clone());
        }
        self.map.insert(key, Arc::clone(&plan));
        Ok(plan)
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new(64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::builders;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.5).collect()
    }

    #[test]
    fn builder_validates_typed_errors() {
        let mask = builders::causal(32);
        // happy path
        assert!(AttnProblem::new(32, 4).mask(&mask).plan().is_ok());
        // missing mask
        assert_eq!(AttnProblem::new(32, 4).plan().unwrap_err(), AttnError::MaskMissing);
        // wrong mask size
        assert_eq!(
            AttnProblem::new(64, 4).mask(&mask).plan().unwrap_err(),
            AttnError::MaskSizeMismatch { got: 32, want: 64 }
        );
        // degenerate layouts
        assert_eq!(
            AttnProblem::new(32, 4).heads(4, 0).mask(&mask).plan().unwrap_err(),
            AttnError::UnsupportedLayout { q_heads: 4, kv_heads: 0 }
        );
        assert_eq!(
            AttnProblem::new(32, 4).heads(6, 4).mask(&mask).plan().unwrap_err(),
            AttnError::UnsupportedLayout { q_heads: 6, kv_heads: 4 }
        );
        // zero dims / tiles
        assert_eq!(
            AttnProblem::new(0, 4).mask(&mask).plan().unwrap_err(),
            AttnError::InvalidDim { what: "n" }
        );
        assert_eq!(
            AttnProblem::new(32, 4).mask(&mask).tile(0, 16).plan().unwrap_err(),
            AttnError::InvalidTile { br: 0, bc: 16 }
        );
        // structurally broken mask
        let mut bad = builders::causal(32);
        bad.lts[0] = 30;
        bad.lte[0] = 2;
        assert!(matches!(
            AttnProblem::new(32, 4).mask(&bad).plan().unwrap_err(),
            AttnError::MaskInvalid { .. }
        ));
    }

    #[test]
    fn views_check_lengths() {
        let buf = vec![0f32; 24];
        assert!(QViews::new(&buf, 2, 3, 4).is_ok());
        assert_eq!(
            QViews::new(&buf, 2, 3, 5).unwrap_err(),
            AttnError::ShapeMismatch { what: "q", got: 24, want: 30 }
        );
        assert!(KvViews::new(&buf, &buf, 1, 6, 4).is_ok());
        let short = vec![0f32; 23];
        assert!(matches!(
            KvViews::new(&buf, &short, 1, 6, 4).unwrap_err(),
            AttnError::ShapeMismatch { what: "v", .. }
        ));
    }

    #[test]
    fn cpu_prefill_matches_dense_ref() {
        let (n, d) = (96, 8);
        let layout = HeadLayout::gqa(4, 2);
        let mut rng = Rng::new(5);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        for (kind, mask) in builders::benchmark_suite(n, 9) {
            let problem =
                AttnProblem::new(n, d).layout(layout).mask(&mask).tile(32, 32).threads(2);
            let plan = problem.plan().unwrap();
            let qv = QViews::new(&q, layout.q_heads, n, d).unwrap();
            let kvv = KvViews::new(&k, &v, layout.kv_heads, n, d).unwrap();
            let cpu = CpuBackend.prefill_grouped(&plan, qv, kvv).unwrap();
            let oracle = DenseRefBackend.prefill_grouped(&plan, qv, kvv).unwrap();
            assert_eq!(cpu.outs.len(), layout.q_heads, "{kind}");
            for h in 0..layout.q_heads {
                for (i, (a, b)) in cpu.outs[h].o.iter().zip(&oracle.outs[h].o).enumerate() {
                    assert!((a - b).abs() < 3e-5, "{kind} head {h} o[{i}]: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn sequential_and_parallel_paths_bitwise_equal() {
        let (n, d) = (100, 8);
        let layout = HeadLayout::gqa(4, 2);
        let mut rng = Rng::new(7);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let mask = builders::causal_document(n, &[48, 52]);
        let qv = QViews::new(&q, layout.q_heads, n, d).unwrap();
        let kvv = KvViews::new(&k, &v, layout.kv_heads, n, d).unwrap();
        let base = AttnProblem::new(n, d).layout(layout).mask(&mask).tile(32, 16);
        let want = CpuBackend.prefill_grouped(&base.plan().unwrap(), qv, kvv).unwrap();
        for threads in [2usize, 3, 8] {
            let plan = base.threads(threads).plan().unwrap();
            let got = CpuBackend.prefill_grouped(&plan, qv, kvv).unwrap();
            for h in 0..layout.q_heads {
                assert_eq!(got.outs[h].o, want.outs[h].o, "threads={threads} head {h}");
                assert_eq!(got.outs[h].lse, want.outs[h].lse, "threads={threads} head {h}");
            }
            assert_eq!(got.stats, want.stats, "threads={threads}: stats diverged");
        }
    }

    #[test]
    fn plan_reuse_is_bitwise_stable() {
        // one plan, many calls (the per-layer reuse): outputs must be
        // bitwise identical call over call, packing buffers included
        let (n, d) = (64, 8);
        let mut rng = Rng::new(11);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let mask = builders::sliding_window(n, 12);
        let plan = AttnProblem::new(n, d).mask(&mask).tile(16, 16).plan().unwrap();
        let qv = QViews::new(&q, 1, n, d).unwrap();
        let kvv = KvViews::new(&k, &v, 1, n, d).unwrap();
        let first = CpuBackend.prefill(&plan, qv, kvv).unwrap();
        for _ in 0..3 {
            let again = CpuBackend.prefill(&plan, qv, kvv).unwrap();
            assert_eq!(again.outs[0].o, first.outs[0].o);
            assert_eq!(again.outs[0].lse, first.outs[0].lse);
            assert_eq!(again.stats, first.stats);
        }
        // different K through the same plan must not see stale packing
        let k2 = rand_vec(n * d, &mut rng);
        let kvv2 = KvViews::new(&k2, &v, 1, n, d).unwrap();
        let other = CpuBackend.prefill(&plan, qv, kvv2).unwrap();
        assert_ne!(other.outs[0].o, first.outs[0].o, "repack must refresh contents");
    }

    #[test]
    fn plan_cache_hits_and_collision_guard() {
        let n = 64;
        let mask_a = builders::causal(n);
        let mask_b = builders::sliding_window(n, 8);
        let mut cache = PlanCache::new(8);
        let pa = AttnProblem::new(n, 8).mask(&mask_a).tile(16, 16);
        let pb = AttnProblem::new(n, 8).mask(&mask_b).tile(16, 16);
        let a1 = cache.get_or_build(&pa).unwrap();
        let a2 = cache.get_or_build(&pa).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "same problem must share one plan");
        let b = cache.get_or_build(&pb).unwrap();
        assert!(!Arc::ptr_eq(&a1, &b));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 2);
        assert!((cache.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        // same mask content, different tiling => different plan
        let pc = AttnProblem::new(n, 8).mask(&mask_a).tile(32, 32);
        let c = cache.get_or_build(&pc).unwrap();
        assert!(!Arc::ptr_eq(&a1, &c));
    }

    #[test]
    fn plan_cache_evicts_fifo() {
        let n = 32;
        let masks: Vec<_> = (1..=4).map(|w| builders::sliding_window(n, w * 2)).collect();
        let mut cache = PlanCache::new(2);
        for m in &masks {
            cache.get_or_build(&AttnProblem::new(n, 4).mask(m)).unwrap();
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 2);
        // the two oldest were evicted; re-requesting them misses
        let before = cache.misses();
        cache.get_or_build(&AttnProblem::new(n, 4).mask(&masks[0])).unwrap();
        assert_eq!(cache.misses(), before + 1);
        assert_eq!(cache.evictions(), 3);
    }

    #[test]
    fn unsupported_capabilities_are_typed() {
        let n = 32;
        let mask = builders::causal(n);
        let plan = AttnProblem::new(n, 4).mask(&mask).plan().unwrap();
        assert!(!DenseRefBackend.capabilities().decode);
        assert!(DenseRefBackend.capabilities().supports(Capability::Prefill));
        assert!(!DenseRefBackend.capabilities().supports(Capability::DecodeStep));
        // default trait bodies surface Unsupported, never wrong answers
        let pool = PagePool::new(8, 4, 4);
        let cache = PagedKv::new();
        let view = IncrementalMaskView::new(&mask, 8);
        let mut stats = DecodeStats::default();
        let mut scratch = Vec::new();
        let err = DenseRefBackend
            .decode_step(
                DecodeStep {
                    q_rows: &[0.0; 4],
                    group: 1,
                    cache: &cache,
                    pool: &pool,
                    mask: &mask,
                    view: &view,
                    t: 0,
                    scale: 1.0,
                    skip: true,
                },
                &mut stats,
                &mut scratch,
            )
            .unwrap_err();
        assert_eq!(
            err,
            AttnError::Unsupported {
                backend: "dense-ref",
                capability: Capability::DecodeStep
            }
        );
        let _ = plan;
    }
}
