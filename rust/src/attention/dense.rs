//! Vanilla O(N²) attention with a dense additive mask (paper Eq. 2) —
//! the "vanilla attention" baseline of Fig. 2 and the semantic oracle
//! for the blocked engines.

use super::{api, gemm, parallel_2d, AttnGrads, AttnOutput, HeadLayout};

/// Rows `[row0, row0 + rows)` of the dense forward — the row-parallel
/// work unit shared by [`dense_forward`] and
/// [`dense_forward_grouped_parallel`].  Writes into the caller's
/// output slices (`o_rows` is `[rows, d]`, `lse_rows` is `[rows]`).
#[allow(clippy::too_many_arguments)]
fn dense_forward_rows(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    bias: &[f32],
    scale: f32,
    row0: usize,
    o_rows: &mut [f32],
    lse_rows: &mut [f32],
) {
    let rows = lse_rows.len();
    debug_assert_eq!(o_rows.len(), rows * d);
    let mut srow = vec![0f32; n];
    for x in 0..rows {
        let i = row0 + x;
        // S_i = q_i K^T * scale + bias_i
        for j in 0..n {
            let acc = gemm::dot(&q[i * d..(i + 1) * d], &k[j * d..(j + 1) * d]);
            srow[j] = acc * scale + bias[i * n + j];
        }
        let m = srow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let m_safe = if m.is_finite() { m } else { 0.0 };
        let mut l = 0f32;
        for sv in srow.iter_mut() {
            *sv = (*sv - m_safe).exp();
            l += *sv;
        }
        if l > 0.0 {
            let inv = 1.0 / l;
            for j in 0..n {
                let p = srow[j] * inv;
                if p != 0.0 {
                    for dd in 0..d {
                        o_rows[x * d + dd] += p * v[j * d + dd];
                    }
                }
            }
            lse_rows[x] = m_safe + l.ln();
        }
    }
}

/// Single-head dense forward body shared by the [`api::DenseRefBackend`]
/// and the deprecated free functions.
pub(crate) fn forward_impl(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    bias: &[f32],
    scale: f32,
) -> AttnOutput {
    assert_eq!(bias.len(), n * n);
    let mut o = vec![0f32; n * d];
    let mut lse = vec![f32::NEG_INFINITY; n];
    dense_forward_rows(q, k, v, n, d, bias, scale, 0, &mut o, &mut lse);
    AttnOutput { o, lse }
}

/// Softmax attention with dense bias; row-major `[n, d]` inputs,
/// `bias[n*n]` additive mask (0 / -inf).
///
/// Deprecated shim over [`api::DenseRefBackend`] (which also accepts a
/// FlashMask-backed [`api::ExecutionPlan`] via the [`api::Backend`]
/// trait).
#[deprecated(
    note = "use attention::api — DenseRefBackend::prefill with an AttnProblem, or DenseRefBackend::forward_bias for raw-bias calls (DESIGN.md §Public API)"
)]
pub fn dense_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    bias: &[f32],
    scale: f32,
) -> AttnOutput {
    let mut outs =
        api::DenseRefBackend.forward_bias(q, k, v, n, d, HeadLayout::mha(1), bias, scale, 1);
    outs.remove(0)
}

/// Dense forward over a grouped head layout: Q `[q_heads, n, d]`
/// against shared K/V `[kv_heads, n, d]`, each query head scored
/// against its group's KV head.  Returns one output per query head —
/// the GQA semantic oracle the grouped blocked kernels are pinned to.
///
/// Deprecated shim over [`api::DenseRefBackend`].
#[deprecated(
    note = "use attention::api — DenseRefBackend::prefill_grouped with an AttnProblem, or DenseRefBackend::forward_bias (DESIGN.md §Public API)"
)]
pub fn dense_forward_grouped(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    bias: &[f32],
    scale: f32,
) -> Vec<AttnOutput> {
    assert_eq!(q.len(), layout.q_heads * n * d, "q must be [q_heads, n, d]");
    assert_eq!(k.len(), layout.kv_heads * n * d, "k must be [kv_heads, n, d]");
    assert_eq!(v.len(), layout.kv_heads * n * d, "v must be [kv_heads, n, d]");
    api::DenseRefBackend.forward_bias(q, k, v, n, d, layout, bias, scale, 1)
}

/// Grouped dense forward with (head × row-chunk) work partitioning via
/// [`parallel_2d`] — the dense reference keeps up with multi-core
/// kernel runs, so oracle comparisons at bench sizes don't dominate
/// wall time.  Dense rows cost the same regardless of the mask, so the
/// chunk weights are uniform.  Bitwise identical to the sequential
/// path at any thread count (rows are independent).
///
/// Deprecated shim over [`api::DenseRefBackend`].
#[deprecated(
    note = "use attention::api — DenseRefBackend::prefill_grouped with an AttnProblem.threads(t), or DenseRefBackend::forward_bias (DESIGN.md §Public API)"
)]
#[allow(clippy::too_many_arguments)]
pub fn dense_forward_grouped_parallel(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    bias: &[f32],
    scale: f32,
    max_threads: usize,
) -> Vec<AttnOutput> {
    assert_eq!(q.len(), layout.q_heads * n * d, "q must be [q_heads, n, d]");
    assert_eq!(k.len(), layout.kv_heads * n * d, "k must be [kv_heads, n, d]");
    assert_eq!(v.len(), layout.kv_heads * n * d, "v must be [kv_heads, n, d]");
    assert_eq!(bias.len(), n * n);
    api::DenseRefBackend.forward_bias(q, k, v, n, d, layout, bias, scale, max_threads)
}

/// The parallel grouped dense body shared by [`api::DenseRefBackend`]
/// and the deprecated free functions.
#[allow(clippy::too_many_arguments)]
pub(crate) fn grouped_parallel_impl(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    layout: HeadLayout,
    bias: &[f32],
    scale: f32,
    max_threads: usize,
) -> Vec<AttnOutput> {
    assert_eq!(q.len(), layout.q_heads * n * d, "q must be [q_heads, n, d]");
    assert_eq!(k.len(), layout.kv_heads * n * d, "k must be [kv_heads, n, d]");
    assert_eq!(v.len(), layout.kv_heads * n * d, "v must be [kv_heads, n, d]");
    assert_eq!(bias.len(), n * n);
    const CHUNK: usize = 64;
    let blocks = n.div_ceil(CHUNK).max(1);
    let weights = vec![1u64; blocks];
    let results = parallel_2d(layout.q_heads, blocks, &weights, max_threads, |h, b| {
        let kh = layout.kv_head_of(h);
        let row0 = b * CHUNK;
        let rows = CHUNK.min(n - row0);
        let mut o_rows = vec![0f32; rows * d];
        let mut lse_rows = vec![f32::NEG_INFINITY; rows];
        dense_forward_rows(
            &q[h * n * d..(h + 1) * n * d],
            &k[kh * n * d..(kh + 1) * n * d],
            &v[kh * n * d..(kh + 1) * n * d],
            n,
            d,
            bias,
            scale,
            row0,
            &mut o_rows,
            &mut lse_rows,
        );
        (o_rows, lse_rows)
    });
    let mut outs = Vec::with_capacity(layout.q_heads);
    let mut items = results.into_iter();
    for _h in 0..layout.q_heads {
        let mut o = vec![0f32; n * d];
        let mut lse = vec![f32::NEG_INFINITY; n];
        for b in 0..blocks {
            let (ob, lb) = items.next().expect("one item per (head, chunk)");
            let row0 = b * CHUNK;
            o[row0 * d..row0 * d + ob.len()].copy_from_slice(&ob);
            lse[row0..row0 + lb.len()].copy_from_slice(&lb);
        }
        outs.push(AttnOutput { o, lse });
    }
    outs
}

/// Backward of [`dense_forward`] (textbook softmax-attention gradient).
#[allow(clippy::too_many_arguments)]
pub fn dense_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    do_: &[f32],
    lse: &[f32],
    n: usize,
    d: usize,
    bias: &[f32],
    scale: f32,
) -> AttnGrads {
    let mut dq = vec![0f32; n * d];
    let mut dk = vec![0f32; n * d];
    let mut dv = vec![0f32; n * d];
    let mut prow = vec![0f32; n];
    for i in 0..n {
        let l = lse[i];
        if !l.is_finite() {
            continue; // fully-masked row contributes nothing
        }
        // recompute P_i from lse (same trick as the kernels)
        for j in 0..n {
            let mut acc = 0f32;
            for dd in 0..d {
                acc += q[i * d + dd] * k[j * d + dd];
            }
            let s = acc * scale + bias[i * n + j];
            prow[j] = (s - l).exp();
        }
        // D_i = dO_i . O_i
        let mut dvec = 0f32;
        for dd in 0..d {
            dvec += do_[i * d + dd] * o[i * d + dd];
        }
        for j in 0..n {
            let p = prow[j];
            if p == 0.0 {
                continue;
            }
            // dV_j += p * dO_i
            // dP_ij = dO_i . V_j ; dS_ij = p (dP - D) scale
            let mut dp = 0f32;
            for dd in 0..d {
                dv[j * d + dd] += p * do_[i * d + dd];
                dp += do_[i * d + dd] * v[j * d + dd];
            }
            let ds = p * (dp - dvec) * scale;
            for dd in 0..d {
                dq[i * d + dd] += ds * k[j * d + dd];
                dk[j * d + dd] += ds * q[i * d + dd];
            }
        }
    }
    AttnGrads { dq, dk, dv }
}

#[cfg(test)]
#[allow(deprecated)] // the legacy entry points double as migration oracles
mod tests {
    use super::*;
    use crate::attention::testutil::rand_vec;
    use crate::mask::builders;
    use crate::util::rng::Rng;

    #[test]
    fn softmax_rows_sum_to_one_via_identity_v() {
        // with V = all-ones, output rows must be exactly rows of ones
        let n = 16;
        let d = 4;
        let mut rng = Rng::new(1);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = vec![1f32; n * d];
        let mask = builders::causal(n);
        let out = dense_forward(&q, &k, &v, n, d, &mask.dense_bias(), 0.5);
        for i in 0..n {
            for dd in 0..d {
                assert!((out.o[i * d + dd] - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fully_masked_row_is_zero() {
        let n = 8;
        let d = 2;
        let mut rng = Rng::new(2);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let mut bias = vec![0f32; n * n];
        for j in 0..n {
            bias[3 * n + j] = f32::NEG_INFINITY; // row 3 fully masked
        }
        let out = dense_forward(&q, &k, &v, n, d, &bias, 1.0);
        assert!(out.o[3 * d..4 * d].iter().all(|&x| x == 0.0));
        assert_eq!(out.lse[3], f32::NEG_INFINITY);
    }

    #[test]
    fn grouped_dense_matches_kv_replication() {
        // GQA oracle sanity: sharing a KV head is the same as replicating
        // it per query head and running MHA
        let (n, d) = (24, 4);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(9);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let mask = builders::causal(n);
        let outs = dense_forward_grouped(&q, &k, &v, n, d, layout, &mask.dense_bias(), 0.5);
        for h in 0..layout.q_heads {
            let kh = layout.kv_head_of(h);
            let want = dense_forward(
                &q[h * n * d..(h + 1) * n * d],
                &k[kh * n * d..(kh + 1) * n * d],
                &v[kh * n * d..(kh + 1) * n * d],
                n,
                d,
                &mask.dense_bias(),
                0.5,
            );
            assert_eq!(outs[h].o, want.o, "head {h}");
        }
    }

    #[test]
    fn grouped_parallel_matches_sequential_bitwise() {
        // row chunks are independent: any thread count reproduces the
        // sequential dense oracle bit for bit, including the ragged
        // tail chunk (n not a multiple of the 64-row chunk)
        let (n, d) = (100, 4);
        let layout = HeadLayout::new(4, 2);
        let mut rng = Rng::new(15);
        let q = rand_vec(layout.q_heads * n * d, &mut rng);
        let k = rand_vec(layout.kv_heads * n * d, &mut rng);
        let v = rand_vec(layout.kv_heads * n * d, &mut rng);
        let mask = builders::causal_document(n, &[60, 40]);
        let bias = mask.dense_bias();
        let want = dense_forward_grouped(&q, &k, &v, n, d, layout, &bias, 0.5);
        for threads in [1usize, 3, 8] {
            let got =
                dense_forward_grouped_parallel(&q, &k, &v, n, d, layout, &bias, 0.5, threads);
            for h in 0..layout.q_heads {
                assert_eq!(got[h].o, want[h].o, "threads={threads} head {h}");
                assert_eq!(got[h].lse, want[h].lse, "threads={threads} head {h} lse");
            }
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let n = 12;
        let d = 4;
        let mut rng = Rng::new(3);
        let q = rand_vec(n * d, &mut rng);
        let k = rand_vec(n * d, &mut rng);
        let v = rand_vec(n * d, &mut rng);
        let mask = builders::causal_document(n, &[7, 5]);
        let bias = mask.dense_bias();
        let scale = 0.5f32;
        let w = rand_vec(n * d, &mut rng);
        let fwd = dense_forward(&q, &k, &v, n, d, &bias, scale);
        let grads = dense_backward(&q, &k, &v, &fwd.o, &w, &fwd.lse, n, d, &bias, scale);
        let loss = |q_: &[f32], k_: &[f32], v_: &[f32]| -> f32 {
            dense_forward(q_, k_, v_, n, d, &bias, scale)
                .o
                .iter()
                .zip(&w)
                .map(|(a, b)| a * b)
                .sum()
        };
        let fd_q = crate::attention::finite_diff_loss(|x| loss(x, &k, &v), &q, 1e-2);
        for i in 0..n * d {
            assert!((grads.dq[i] - fd_q[i]).abs() < 5e-3, "dq[{i}]");
        }
        let fd_v = crate::attention::finite_diff_loss(|x| loss(&q, &k, x), &v, 1e-2);
        for i in 0..n * d {
            assert!((grads.dv[i] - fd_v[i]).abs() < 5e-3, "dv[{i}]");
        }
    }
}
