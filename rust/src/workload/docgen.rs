//! Document/sample construction per paper appendix A.2.1.
//!
//! A training sample at max sequence length `N` packs 1..=10 documents
//! (the last acting as padding), each split into a question plus `k`
//! answers where `k` depends on the task (SFT/LoRA: 1, DPO: 2, RM: 6)
//! and every answer is ~10–20% of the query length.

use crate::mask::builders::{self, SharedQuestionDoc};
use crate::mask::FlashMask;
use crate::util::rng::Rng;

/// Downstream training task (paper Fig. 2's four columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Sft,
    Lora,
    Dpo,
    Rm,
}

impl Task {
    pub fn answers_per_doc(&self) -> usize {
        match self {
            Task::Sft | Task::Lora => 1,
            Task::Dpo => 2,
            Task::Rm => 6,
        }
    }

    pub fn min_doc_len(&self) -> usize {
        match self {
            Task::Rm => 512,
            _ => 128,
        }
    }

    pub fn max_padding(&self) -> usize {
        match self {
            Task::Rm => 512,
            _ => 128,
        }
    }

    pub fn parse(s: &str) -> Result<Task, String> {
        match s {
            "sft" => Ok(Task::Sft),
            "lora" => Ok(Task::Lora),
            "dpo" => Ok(Task::Dpo),
            "rm" => Ok(Task::Rm),
            _ => Err(format!("unknown task '{s}' (sft|lora|dpo|rm)")),
        }
    }
}

impl std::fmt::Display for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Task::Sft => "sft",
            Task::Lora => "lora",
            Task::Dpo => "dpo",
            Task::Rm => "rm",
        })
    }
}

/// Sample `k` positive lengths summing to `n`, each >= `min_len`.
pub fn sample_doc_lens(n: usize, k: usize, min_len: usize, rng: &mut Rng) -> Vec<usize> {
    assert!(k >= 1 && k * min_len <= n, "cannot fit {k} docs of >= {min_len} in {n}");
    let free = n - k * min_len;
    // k-1 sorted cut points in [0, free]
    let mut cuts: Vec<usize> = (0..k - 1).map(|_| rng.gen_range(free as u64 + 1) as usize).collect();
    cuts.sort_unstable();
    let mut lens = Vec::with_capacity(k);
    let mut prev = 0;
    for c in cuts {
        lens.push(c - prev + min_len);
        prev = c;
    }
    lens.push(free - prev + min_len);
    debug_assert_eq!(lens.iter().sum::<usize>(), n);
    lens
}

/// One document inside a packed training sample.
#[derive(Clone, Debug)]
pub struct DocLayout {
    pub start: usize,
    pub question_len: usize,
    pub answer_lens: Vec<usize>,
    pub is_padding: bool,
}

impl DocLayout {
    pub fn len(&self) -> usize {
        self.question_len + self.answer_lens.iter().sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A packed training sample: document layout + the FlashMask it induces.
#[derive(Clone, Debug)]
pub struct TrainSample {
    pub n: usize,
    pub task: Task,
    pub docs: Vec<DocLayout>,
    pub mask: FlashMask,
    /// Block sparsity at the paper's 128x128 tiling (or n/4 if smaller).
    pub sparsity: f64,
}

/// Construct one training sample per appendix A.2.1.
pub fn gen_sample(n: usize, task: Task, rng: &mut Rng) -> TrainSample {
    let k_ans = task.answers_per_doc();
    let min_len = task.min_doc_len().min(n / 2).max(k_ans + 1);
    // paper: n_docs in [1,10], with task/length-specific caps
    let max_docs = match (task, n) {
        (Task::Rm, n) if n <= 4096 => 3,
        (Task::Rm, n) if n <= 8192 => 4,
        _ => 10,
    };
    let max_fit = (n / min_len).max(1);
    let n_docs = (rng.range(1, max_docs as i64 + 1) as usize).min(max_fit);
    let lens = sample_doc_lens(n, n_docs, min_len, rng);

    let mut docs = Vec::with_capacity(lens.len());
    let mut pos = 0;
    for (di, &len) in lens.iter().enumerate() {
        let is_padding = di + 1 == lens.len() && lens.len() > 1;
        // each answer ≈ 10-20% of the query length (appendix A.2.1)
        let lo = (len as f64 * 0.1 / (1.0 + 0.1 * k_ans as f64)) as usize;
        let hi = (len as f64 * 0.2 / (1.0 + 0.2 * k_ans as f64)) as usize;
        let mut answer_lens = Vec::with_capacity(k_ans);
        let mut remaining = len;
        for _ in 0..k_ans {
            let a = if hi > lo { rng.range(lo as i64, hi as i64 + 1) as usize } else { lo }
                .clamp(1, remaining.saturating_sub(1).max(1));
            answer_lens.push(a);
            remaining = remaining.saturating_sub(a);
        }
        let question_len = len - answer_lens.iter().sum::<usize>();
        docs.push(DocLayout { start: pos, question_len, answer_lens, is_padding });
        pos += len;
    }

    let mask = mask_for(n, task, &docs);
    let tile = (n / 4).clamp(1, 128);
    let sparsity = mask.block_sparsity(tile, tile);
    TrainSample { n, task, docs, mask, sparsity }
}

/// The attention mask induced by a document layout for a task.
///
/// SFT/LoRA use causal-document masks; DPO/RM use shared-question masks
/// (paper §2.1).
pub fn mask_for(n: usize, task: Task, docs: &[DocLayout]) -> FlashMask {
    match task {
        Task::Sft | Task::Lora => {
            let lens: Vec<usize> = docs.iter().map(|d| d.len()).collect();
            builders::causal_document(n, &lens)
        }
        Task::Dpo | Task::Rm => {
            let sq: Vec<SharedQuestionDoc> = docs
                .iter()
                .map(|d| SharedQuestionDoc {
                    question_len: d.question_len,
                    answer_lens: d.answer_lens.clone(),
                })
                .collect();
            builders::share_question(n, &sq)
        }
    }
}

/// Sparsity histogram over sampled data (paper Fig. 6): 10 equal-width
/// bins over the observed sparsity range of the task's mask family.
pub fn sparsity_histogram(n: usize, task: Task, samples: usize, seed: u64) -> Vec<(f64, usize)> {
    let mut rng = Rng::new(seed);
    let lo = 0.5; // causal families live in [0.5, 1.0] (appendix A.4.1)
    let hi = 1.0;
    let mut bins = vec![0usize; 10];
    for _ in 0..samples {
        let s = gen_sample(n, task, &mut rng);
        let b = (((s.sparsity - lo) / (hi - lo) * 10.0) as usize).min(9);
        bins[b] += 1;
    }
    bins.iter()
        .enumerate()
        .map(|(i, &c)| (lo + (hi - lo) * (i as f64 + 0.5) / 10.0, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn doc_lens_sum_and_min() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let lens = sample_doc_lens(1024, 5, 16, &mut rng);
            assert_eq!(lens.iter().sum::<usize>(), 1024);
            assert!(lens.iter().all(|&l| l >= 16));
        }
    }

    #[test]
    fn gen_sample_covers_sequence() {
        let mut rng = Rng::new(2);
        for task in [Task::Sft, Task::Dpo, Task::Rm] {
            let s = gen_sample(2048, task, &mut rng);
            assert_eq!(s.docs.iter().map(|d| d.len()).sum::<usize>(), 2048);
            assert_eq!(s.mask.n(), 2048);
            s.mask.validate().unwrap();
        }
    }

    #[test]
    fn answers_per_task() {
        let mut rng = Rng::new(3);
        let s = gen_sample(4096, Task::Rm, &mut rng);
        for d in &s.docs {
            assert_eq!(d.answer_lens.len(), 6);
        }
        let s = gen_sample(4096, Task::Dpo, &mut rng);
        for d in &s.docs {
            assert_eq!(d.answer_lens.len(), 2);
        }
    }

    #[test]
    fn sft_sparsity_at_least_causal() {
        // causal-document masks are at least as sparse as plain causal
        let mut rng = Rng::new(4);
        let s = gen_sample(1024, Task::Sft, &mut rng);
        assert!(s.sparsity >= 0.3, "sparsity={}", s.sparsity);
    }

    #[test]
    fn histogram_counts_sum() {
        let h = sparsity_histogram(1024, Task::Sft, 40, 5);
        assert_eq!(h.iter().map(|(_, c)| c).sum::<usize>(), 40);
        assert_eq!(h.len(), 10);
    }

    #[test]
    fn prop_sample_masks_wellformed() {
        prop::check_default("train-sample-mask-valid", |rng| {
            let task = *rng.choose(&[Task::Sft, Task::Lora, Task::Dpo, Task::Rm]);
            let s = gen_sample(1024, task, rng);
            s.mask.validate().map_err(|e| e.to_string())?;
            if !(0.0..=1.0).contains(&s.sparsity) {
                return Err(format!("sparsity {} out of range", s.sparsity));
            }
            Ok(())
        });
    }
}
