//! Synthetic workload generators reproducing the paper's data
//! construction (appendix A.2.1 for end-to-end training, A.4.1 for the
//! sparsity sweep, A.5.2 for the kernel benchmark).

pub mod corpus;
pub mod docgen;
pub mod sparsity_buckets;

pub use docgen::{sample_doc_lens, Task, TrainSample};
