//! Deterministic synthetic text corpus for the end-to-end training
//! example (byte-level LM).
//!
//! The paper trains on tulu-v2 / ultrafeedback; those are unavailable
//! offline, and the convergence experiment only needs *a* fixed, learnable
//! distribution (DESIGN.md §Substitutions).  We generate template-based
//! English-like Q/A text with heavy n-gram structure so a small byte-level
//! LM shows a clearly falling loss curve.

use crate::util::rng::Rng;

const SUBJECTS: &[&str] = &[
    "the scheduler", "a kernel", "the attention mask", "the optimizer",
    "a long sequence", "the key cache", "this document", "the query block",
    "the softmax", "a sliding window", "the gradient", "the pipeline",
];

const VERBS: &[&str] = &[
    "computes", "skips", "masks", "loads", "stores", "reduces",
    "accumulates", "partitions", "streams", "classifies", "updates",
];

const OBJECTS: &[&str] = &[
    "a tile of scores", "the masked block", "a column interval",
    "the row maximum", "every visible token", "the output buffer",
    "the minimum index", "a packed batch", "its own state",
    "the next block", "four sparse vectors", "the final logits",
];

const CONNECTIVES: &[&str] = &[
    "and then", "because", "so that", "while", "after which", "unless",
];

/// One generated sentence (ASCII, lowercase, ends with a period).
pub fn sentence(rng: &mut Rng) -> String {
    let mut s = format!(
        "{} {} {}",
        rng.choose(SUBJECTS),
        rng.choose(VERBS),
        rng.choose(OBJECTS)
    );
    if rng.f64() < 0.4 {
        s.push_str(&format!(
            " {} {} {} {}",
            rng.choose(CONNECTIVES),
            rng.choose(SUBJECTS),
            rng.choose(VERBS),
            rng.choose(OBJECTS)
        ));
    }
    s.push_str(". ");
    s
}

/// A question/answer pair: the question asks about a subject, the answer
/// repeats it with a template — giving the LM a copy/structure signal.
pub fn qa_pair(rng: &mut Rng) -> (String, String) {
    let subj = rng.choose(SUBJECTS).to_string();
    let verb = rng.choose(VERBS).to_string();
    let obj = rng.choose(OBJECTS).to_string();
    let q = format!("what does {subj} do? ");
    let mut a = format!("{subj} {verb} {obj}. ");
    while rng.f64() < 0.5 {
        a.push_str(&sentence(rng));
    }
    (q, a)
}

/// Fill exactly `len` bytes of text (truncating/padding with spaces).
pub fn text_bytes(len: usize, rng: &mut Rng) -> Vec<u8> {
    let mut buf = String::new();
    while buf.len() < len {
        buf.push_str(&sentence(rng));
    }
    let mut bytes = buf.into_bytes();
    bytes.truncate(len);
    bytes
}

/// Q/A document of exactly `q_len` question bytes + answer sections of
/// the given lengths (for shared-question masks).
pub fn qa_doc_bytes(q_len: usize, answer_lens: &[usize], rng: &mut Rng) -> (Vec<u8>, Vec<Vec<u8>>) {
    let (q, a) = qa_pair(rng);
    let mut qb = q.into_bytes();
    while qb.len() < q_len {
        qb.extend_from_slice(sentence(rng).as_bytes());
    }
    qb.truncate(q_len);
    let answers = answer_lens
        .iter()
        .map(|&al| {
            let mut ab = a.clone().into_bytes();
            while ab.len() < al {
                ab.extend_from_slice(sentence(rng).as_bytes());
            }
            ab.truncate(al);
            ab
        })
        .collect();
    (qb, answers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_length() {
        let mut rng = Rng::new(1);
        for len in [10usize, 100, 1000] {
            assert_eq!(text_bytes(len, &mut rng).len(), len);
        }
    }

    #[test]
    fn ascii_only() {
        let mut rng = Rng::new(2);
        assert!(text_bytes(5000, &mut rng).iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn deterministic() {
        assert_eq!(text_bytes(256, &mut Rng::new(7)), text_bytes(256, &mut Rng::new(7)));
    }

    #[test]
    fn qa_doc_lengths() {
        let mut rng = Rng::new(3);
        let (q, ans) = qa_doc_bytes(50, &[20, 30], &mut rng);
        assert_eq!(q.len(), 50);
        assert_eq!(ans[0].len(), 20);
        assert_eq!(ans[1].len(), 30);
    }

    #[test]
    fn corpus_is_compressible_structure() {
        // crude n-gram structure check: repeated trigrams should exist
        let mut rng = Rng::new(4);
        let bytes = text_bytes(4000, &mut rng);
        let mut seen = std::collections::HashSet::new();
        let mut repeats = 0;
        for w in bytes.windows(8) {
            if !seen.insert(w.to_vec()) {
                repeats += 1;
            }
        }
        assert!(repeats > 500, "repeats={repeats}");
    }
}
