//! Bucketed sparsity sampling per paper appendix A.4.1 (the Fig. 4(a)
//! "latency vs sparsity" experiment).
//!
//! Causal mask families have block sparsity in `[0.5, 1.0]` (10 buckets
//! of 0.05); bidirectional families span `[0.0, 1.0]` (20 buckets).  We
//! sample mask instances until every reachable bucket holds
//! `min_per_bucket..=max_per_bucket` samples, mirroring the paper's
//! 10..=20 per-bucket protocol.

use crate::mask::builders::{self, SharedQuestionDoc};
use crate::mask::{FlashMask, MaskKind};
use crate::util::rng::Rng;
use crate::workload::docgen::sample_doc_lens;

#[derive(Clone, Debug)]
pub struct BucketedSample {
    pub mask: FlashMask,
    pub sparsity: f64,
    pub bucket: usize,
}

pub struct BucketConfig {
    pub min_per_bucket: usize,
    pub max_per_bucket: usize,
    /// Give up after this many draws per bucket-fill pass (some buckets
    /// are unreachable for a family, e.g. rho < 0.5 for causal docs).
    pub max_draws: usize,
}

impl Default for BucketConfig {
    fn default() -> Self {
        BucketConfig { min_per_bucket: 2, max_per_bucket: 4, max_draws: 4000 }
    }
}

/// Sparsity range + bucket count for a mask family (appendix A.4.1).
pub fn bucket_range(kind: MaskKind) -> (f64, f64, usize) {
    if kind.is_causal() {
        (0.5, 1.0, 10)
    } else {
        (0.0, 1.0, 20)
    }
}

fn draw(kind: MaskKind, n: usize, rng: &mut Rng) -> FlashMask {
    match kind {
        // doc count ranges from appendix A.4.1
        MaskKind::CausalDocument => {
            let k = rng.range(2, 21) as usize;
            builders::causal_document(n, &sample_doc_lens(n, k.min(n / 2), 1, rng))
        }
        MaskKind::Document => {
            let k = rng.range(2, 11) as usize;
            builders::document(n, &sample_doc_lens(n, k.min(n / 2), 1, rng))
        }
        MaskKind::ShareQuestion => {
            let k = rng.range(1, 6) as usize;
            let lens = sample_doc_lens(n, k.min(n / 16).max(1), 12, rng);
            let docs: Vec<SharedQuestionDoc> = lens
                .iter()
                .map(|&dl| {
                    let n_ans = rng.range(2, 7) as usize;
                    let a_total = (dl / 2).max(n_ans);
                    SharedQuestionDoc {
                        question_len: dl - a_total,
                        answer_lens: sample_doc_lens(a_total, n_ans, 1, rng),
                    }
                })
                .collect();
            builders::share_question(n, &docs)
        }
        other => builders::build(other, n, rng),
    }
}

/// Fill sparsity buckets for `kind` at sequence length `n`, tile `b`.
pub fn sample_buckets(
    kind: MaskKind,
    n: usize,
    tile: usize,
    cfg: &BucketConfig,
    seed: u64,
) -> Vec<BucketedSample> {
    let (lo, hi, n_buckets) = bucket_range(kind);
    let mut rng = Rng::new(seed);
    let mut counts = vec![0usize; n_buckets];
    let mut out = Vec::new();
    for _ in 0..cfg.max_draws {
        if counts.iter().all(|&c| c >= cfg.min_per_bucket) {
            break;
        }
        let mask = draw(kind, n, &mut rng);
        let rho = mask.block_sparsity(tile, tile);
        let b = (((rho - lo) / (hi - lo) * n_buckets as f64) as usize).min(n_buckets - 1);
        if counts[b] >= cfg.max_per_bucket {
            continue;
        }
        counts[b] += 1;
        out.push(BucketedSample { mask, sparsity: rho, bucket: b });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_document_buckets_populated() {
        let cfg = BucketConfig { min_per_bucket: 1, max_per_bucket: 2, max_draws: 400 };
        let samples = sample_buckets(MaskKind::CausalDocument, 256, 32, &cfg, 1);
        assert!(!samples.is_empty());
        for s in &samples {
            // asymptotically >= 0.5; finite tiling gives Tc(Tc-1)/2Tc^2
            assert!(s.sparsity >= 0.40, "causal family rho={}", s.sparsity);
            assert!(s.bucket < 10);
        }
        // several distinct buckets reachable
        let mut buckets: Vec<usize> = samples.iter().map(|s| s.bucket).collect();
        buckets.sort_unstable();
        buckets.dedup();
        assert!(buckets.len() >= 3, "only buckets {buckets:?}");
    }

    #[test]
    fn document_buckets_span_wider() {
        let cfg = BucketConfig { min_per_bucket: 1, max_per_bucket: 2, max_draws: 400 };
        let samples = sample_buckets(MaskKind::Document, 256, 32, &cfg, 2);
        let (lo, hi, nb) = bucket_range(MaskKind::Document);
        assert_eq!((lo, hi, nb), (0.0, 1.0, 20));
        assert!(samples.iter().any(|s| s.sparsity > 0.5));
    }

    #[test]
    fn respects_max_per_bucket() {
        let cfg = BucketConfig { min_per_bucket: 1, max_per_bucket: 1, max_draws: 300 };
        let samples = sample_buckets(MaskKind::ShareQuestion, 256, 32, &cfg, 3);
        let mut counts = std::collections::HashMap::new();
        for s in &samples {
            *counts.entry(s.bucket).or_insert(0usize) += 1;
        }
        assert!(counts.values().all(|&c| c <= 1));
    }
}
