//! `flashmask` CLI — the L3 leader binary.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §5):
//!
//! ```text
//! flashmask info                          # artifacts + platform
//! flashmask train --steps 200 --task sft  # e2e training via PJRT
//! flashmask convergence --steps 30        # Fig 3: flashmask vs densemask
//! flashmask kernel-bench                  # Fig 5/8, Tables 4-9
//! flashmask sparsity-bench                # Fig 4(a)
//! flashmask inference-bench               # Tables 10-14
//! flashmask memory-model                  # Table 2, Fig 4(b), Fig 7
//! flashmask e2e-model                     # Fig 2 curves + Fig 6 histogram
//! flashmask gen-data --task dpo           # inspect synthetic samples
//! flashmask decode --requests 8           # paged-KV continuous batching
//! flashmask decode --speculate 4          # + tree-mask speculative decode
//! flashmask decode --heads 8 --kv-heads 2 # GQA: group-shared KV pages
//! flashmask serve --rate 200              # streaming router, Poisson load
//! flashmask metrics                       # telemetry snapshot (JSON)
//! flashmask lint --json                   # project-native static analysis
//! ```

use anyhow::{anyhow, Result};
use flashmask::coordinator::{Batcher, Trainer, TrainerOptions};
use flashmask::reports;
use flashmask::runtime::Runtime;
use flashmask::util::bench::BenchOpts;
use flashmask::util::cli::Args;
use flashmask::util::table::Table;
use flashmask::workload::docgen::{self, Task};
use std::path::PathBuf;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn bench_opts(args: &Args) -> Result<BenchOpts> {
    Ok(BenchOpts {
        warmup: args.get_usize("warmup", 1).map_err(|e| anyhow!(e))?,
        iters: args.get_usize("iters", 5).map_err(|e| anyhow!(e))?,
        max_seconds: args.get_f64("max-seconds", 20.0).map_err(|e| anyhow!(e))?,
    })
}

fn main() -> Result<()> {
    let args = Args::parse_env().map_err(|e| anyhow!(e))?;
    // log verbosity: the FLASHMASK_LOG env var sets the default, an
    // explicit --log-level flag overrides it (both accept
    // debug|info|warn|error)
    flashmask::telemetry::log::init_from_env();
    if let Some(lv) = args.get("log-level") {
        let level = flashmask::telemetry::log::parse_level(lv)
            .ok_or_else(|| anyhow!("--log-level must be debug|info|warn|error (got '{lv}')"))?;
        flashmask::telemetry::log::set_min_level(level);
    }
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "info" => cmd_info(&args)?,
        "train" => cmd_train(&args)?,
        "convergence" => cmd_convergence(&args)?,
        "kernel-bench" => {
            let n = args.get_usize("measure-n", 1024).map_err(|e| anyhow!(e))?;
            let hd = args.get_usize("head-dim", 128).map_err(|e| anyhow!(e))?;
            let _ = reports::kernel_mask_report(n, &[8192, 32768, 131072], hd, bench_opts(&args)?);
        }
        "sparsity-bench" => {
            let n = args.get_usize("n", 1024).map_err(|e| anyhow!(e))?;
            reports::sparsity_report(n, 32, bench_opts(&args)?, 7);
        }
        "inference-bench" => {
            let n = args.get_usize("n", 1024).map_err(|e| anyhow!(e))?;
            reports::inference_report(n, 64, bench_opts(&args)?, 7);
        }
        "memory-model" => reports::memory_report(),
        "e2e-model" => reports::e2e_report(11),
        "gen-data" => cmd_gen_data(&args)?,
        "decode" => cmd_decode(&args)?,
        "serve" => cmd_serve(&args)?,
        "metrics" => cmd_metrics(&args)?,
        "lint" => cmd_lint(&args)?,
        "help" | _ => {
            println!("{}", HELP);
            return Ok(());
        }
    }
    args.finish().map_err(|e| anyhow!(e))?;
    Ok(())
}

const HELP: &str = "flashmask — FlashMask (ICLR 2025) reproduction CLI
subcommands:
  info             artifact manifest + PJRT platform
  train            end-to-end training (--steps N --task sft|lora|dpo|rm
                   --variant flashmask|densemask --seed S --loss-csv path)
  convergence      paper Fig 3: train flashmask vs densemask, compare losses
  kernel-bench     paper Fig 5/8 + Tables 4-9 (--measure-n N --head-dim D)
  sparsity-bench   paper Fig 4a (--n N)
  inference-bench  paper Tables 10-14 (--n N)
  memory-model     paper Table 2, Fig 4b, Fig 7
  e2e-model        paper Fig 2 curves + Fig 6 histogram
  gen-data         sample synthetic training data (--task T --n N)
  decode           autoregressive decode serving: paged KV cache +
                   continuous batching (--requests R --n N --d D
                   --heads H --page P --max-pages M --seed S --dense)
                   --prefix-cache shares page-aligned identical prompt
                   prefixes across sequences (content-addressed pages,
                   refcounted with copy-on-write; prefill computes the
                   unshared suffix only)
                   head layout: --kv-heads K shares each KV head across
                   a group of H/K query heads (GQA; K=1 is MQA) — KV
                   pages, pool pressure and page classification all
                   scale with K instead of H
                   speculative decoding: --speculate K drafts and
                   verifies up to K tokens per step through a tree
                   FlashMask (greedy-exact: identical tokens to
                   sequential decode); --draft ngram|oracle picks the
                   proposer (default ngram = n-gram self-drafting;
                   oracle replays the teacher-forced continuation with
                   --accept-rate A, default 1.0, for throughput studies);
                   --adaptive shrinks/grows the draft budget from a
                   rolling acceptance window (dynamic k)
  serve            streaming serve router under Poisson load: token-
                   budget admission (TGI-style) + per-request streams
                   (--requests R --n N --d D --heads H --kv-heads K
                   --page P --max-pages M --rate req/s --seed S
                   --max-active A --dense)
                   budget knobs: --prefill-budget T caps prompt tokens
                   per admission wave, --total-budget T caps worst-case
                   running tokens (default: pool token capacity, i.e.
                   preemption-free), --waiting-served-ratio F pauses
                   prefill until a wave is worth the decode stall,
                   --max-waiting W forces admission after W decode
                   iterations (starvation valve)
                   --compare-fifo replays the identical arrival trace
                   through the strict-FIFO page-count batcher and
                   prints the head-to-head latency table
                   --prefix-cache enables content-addressed KV prefix
                   sharing: admission fit checks and wave reservations
                   count only pages that are new after prefix reuse
  lint             project-native static analysis over the source tree
                   (lint [paths…] [--json]; paths default to rust/src,
                   rust/benches and examples).  Passes: hot-path-panic,
                   deprecated-shim, direct-print, telemetry-names,
                   unsafe-hygiene (DESIGN.md §Static analysis).  Exits
                   nonzero on any non-suppressed diagnostic; suppress a
                   finding with `// lint: allow(pass[:rule]) — reason`
                   on or above the line (allow-file(…) for a module)
  metrics          run a small prefill+decode workload and dump the
                   telemetry registry snapshot + span tree as JSON
                   (--n N --d D --requests R --seed S; --no-trace
                   disables span collection; --sample-every K keeps
                   every K-th request trace)
                   --trace-out FILE also writes the span trees as a
                   chrome://tracing JSON document (open in Perfetto)
                   --watch S repeats the decode round and dumps a
                   fresh snapshot every S seconds (--watch-iters N,
                   default 3) so counters can be seen advancing
common: --artifacts DIR (default ./artifacts)
        --log-level debug|info|warn|error (or FLASHMASK_LOG env var)";

fn cmd_lint(args: &Args) -> Result<()> {
    let json = args.flag("json");
    let mut roots: Vec<PathBuf> = args.positionals().iter().map(PathBuf::from).collect();
    if roots.is_empty() {
        roots = flashmask::analysis::default_roots();
        if roots.is_empty() {
            return Err(anyhow!(
                "lint: no default roots found — run from the repo or crate root, \
                 or pass paths explicitly"
            ));
        }
    }
    let report = flashmask::analysis::lint(&roots).map_err(|e| anyhow!(e))?;
    if json {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        for d in &report.diagnostics {
            println!("{}", d.render());
        }
        println!(
            "flashmask lint: {} file(s), {} pass(es): {} diagnostic(s), {} suppressed{}",
            report.files,
            report.passes.len(),
            report.diagnostics.len(),
            report.suppressed,
            if report.clean() { " — clean" } else { "" }
        );
    }
    args.finish().map_err(|e| anyhow!(e))?;
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    println!("platform : {}", rt.platform());
    println!("preset   : {} ({} params)", rt.manifest.preset, rt.manifest.model.n_params);
    println!(
        "model    : d={} L={} H={} dh={} seq={} tiles {}x{}",
        rt.manifest.model.d_model,
        rt.manifest.model.n_layers,
        rt.manifest.model.n_heads,
        rt.manifest.model.d_head,
        rt.manifest.model.max_seq,
        rt.manifest.model.br,
        rt.manifest.model.bc
    );
    let mut t = Table::new(vec!["artifact", "file", "inputs"]);
    for (name, a) in &rt.manifest.artifacts {
        t.row(vec![name.clone(), a.file.clone(), a.inputs.len().to_string()]);
    }
    t.print();
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    let steps = args.get_usize("steps", 100).map_err(|e| anyhow!(e))?;
    let task = Task::parse(&args.get_or("task", "sft")).map_err(|e| anyhow!(e))?;
    let opts = TrainerOptions {
        variant: args.get_or("variant", "flashmask"),
        seed: args.get_u64("seed", 0).map_err(|e| anyhow!(e))? as i32,
        log_every: args.get_usize("log-every", 10).map_err(|e| anyhow!(e))?,
        quiet: args.flag("quiet"),
    };
    let mut trainer = Trainer::new(&rt, opts)?;
    println!(
        "training {} ({} params) on synthetic {task} packing, {} steps",
        rt.manifest.preset,
        trainer.n_params(),
        steps
    );
    let mut batcher = Batcher::new(
        rt.manifest.model.max_seq,
        rt.manifest.batch,
        task,
        args.get_u64("data-seed", 1).map_err(|e| anyhow!(e))?,
    );
    let log = trainer.train(&mut batcher, steps)?;
    println!(
        "done: {} steps in {:.1}s ({:.0} tok/s), loss {:.4} -> {:.4}",
        log.steps,
        log.elapsed_s,
        log.tokens_per_s,
        log.losses.first().unwrap_or(&f32::NAN),
        log.losses.last().unwrap_or(&f32::NAN)
    );
    if let Some(path) = args.get("loss-csv") {
        trainer.metrics.write_csv(std::path::Path::new(path))?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn cmd_convergence(args: &Args) -> Result<()> {
    let rt = Runtime::open(&artifacts_dir(args))?;
    let steps = args.get_usize("steps", 20).map_err(|e| anyhow!(e))?;
    let task = Task::parse(&args.get_or("task", "sft")).map_err(|e| anyhow!(e))?;
    let mut losses = Vec::new();
    for variant in ["flashmask", "densemask"] {
        let mut trainer = Trainer::new(
            &rt,
            TrainerOptions { variant: variant.into(), quiet: true, ..Default::default() },
        )?;
        let mut batcher = Batcher::new(rt.manifest.model.max_seq, rt.manifest.batch, task, 1);
        let log = trainer.train(&mut batcher, steps)?;
        losses.push(log.losses);
    }
    let mut t = Table::new(vec!["step", "flashmask", "densemask", "bit-identical"])
        .title("paper Fig 3 (deterministic): loss curves must match exactly");
    let mut all_equal = true;
    for i in 0..steps {
        let eq = losses[0][i].to_bits() == losses[1][i].to_bits();
        all_equal &= eq;
        t.row(vec![
            (i + 1).to_string(),
            format!("{:.6}", losses[0][i]),
            format!("{:.6}", losses[1][i]),
            eq.to_string(),
        ]);
    }
    t.print();
    println!("bit-level convergence equality: {}", if all_equal { "PASS" } else { "FAIL" });
    if !all_equal {
        anyhow::bail!("convergence curves diverged");
    }
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    use flashmask::decode::{BatcherConfig, DraftKind, HeadLayout, SpecPolicy};
    use flashmask::mask::builders;
    use flashmask::server::{EngineKind, Request, RequestQueue, Scheduler, SchedulerConfig, ServeEngine};
    use flashmask::util::rng::Rng;

    let n_requests = args.get_usize("requests", 8).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("n", 512).map_err(|e| anyhow!(e))?;
    let d = args.get_usize("d", 32).map_err(|e| anyhow!(e))?;
    let heads = args.get_usize("heads", 2).map_err(|e| anyhow!(e))?;
    let kv_heads = args.get_usize("kv-heads", heads).map_err(|e| anyhow!(e))?;
    let page = args.get_usize("page", 16).map_err(|e| anyhow!(e))?;
    let max_pages = args.get_usize("max-pages", 4096).map_err(|e| anyhow!(e))?;
    let skip = !args.flag("dense");
    let prefix_cache = args.flag("prefix-cache");
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    let spec_k = args.get_usize("speculate", 0).map_err(|e| anyhow!(e))?;
    let adaptive = args.flag("adaptive");
    let draft = args.get_or("draft", "ngram");
    let accept_rate = args.get_f64("accept-rate", 1.0).map_err(|e| anyhow!(e))?;
    anyhow::ensure!(n >= 2, "--n must be >= 2 (got {n})");
    anyhow::ensure!(page >= 1, "--page must be >= 1");
    anyhow::ensure!(d >= 1 && heads >= 1, "--d and --heads must be >= 1");
    anyhow::ensure!(
        kv_heads >= 1 && heads % kv_heads == 0,
        "--kv-heads must divide --heads ({heads} % {kv_heads} != 0)"
    );
    anyhow::ensure!(
        (0.0..=1.0).contains(&accept_rate),
        "--accept-rate must be in [0, 1] (got {accept_rate})"
    );
    let layout = HeadLayout::new(heads, kv_heads);
    let spec = if spec_k <= 1 {
        SpecPolicy::Off
    } else {
        let kind = match draft.as_str() {
            "ngram" | "self" => DraftKind::Ngram,
            "oracle" => DraftKind::Oracle { accept_rate, branch: 2, seed },
            other => anyhow::bail!("--draft must be ngram|oracle (got '{other}')"),
        };
        if adaptive {
            SpecPolicy::Adaptive { k_max: spec_k, draft: kind }
        } else {
            match kind {
                DraftKind::Ngram => SpecPolicy::SelfDraft { k: spec_k },
                DraftKind::Oracle { .. } => {
                    SpecPolicy::Oracle { k: spec_k, accept_rate, branch: 2, seed }
                }
            }
        }
    };

    let mut rng = Rng::new(seed);
    let mut queue = RequestQueue::new();
    for i in 0..n_requests {
        // ragged lengths + realistic decode mask mix
        let ni = (n / 2 + (rng.range(0, (n / 2) as i64) as usize)).max(2 * page);
        let mask = match i % 4 {
            0 => builders::causal(ni),
            1 => builders::sliding_window(ni, (ni / 8).max(1)),
            2 => builders::causal_document(ni, &[ni / 2, ni - ni / 2]),
            _ => builders::random_eviction(ni, &mut rng),
        };
        let mut mk =
            |hh: usize| (0..hh * ni * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
        let q = mk(layout.q_heads);
        let k = mk(layout.kv_heads);
        let v = mk(layout.kv_heads);
        queue.push(Request::with_layout(0, layout, ni, d, q, k, v, mask))?;
    }
    println!(
        "queued {n_requests} decode requests (ragged n up to {n}, layout {layout}, group {}, d={d})",
        layout.group()
    );

    let scheduler = Scheduler::new(SchedulerConfig::default());
    let reqs = scheduler.drain_for_decode(&mut queue, n_requests);
    let decode_reqs: Vec<_> = reqs
        .into_iter()
        .map(|r| {
            let prompt = r.n / 4;
            r.into_decode(prompt)
        })
        .collect();
    let mut engine = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (page, page));
    let cfg =
        BatcherConfig { page_size: page, d, max_pages, max_active: 8, skip, spec, prefix_cache };
    let report = engine.execute_decode(decode_reqs, cfg)?;

    println!("\n=== decode report ({}) ===", if skip { "flashmask page skip" } else { "dense cache" });
    println!("sequences     : {}", report.sequences);
    println!("decoded tokens: {}", report.tokens);
    println!("throughput    : {:.0} tokens/s", report.tokens_per_s);
    println!("pages skipped : {:.1}%", report.pages_skip_fraction * 100.0);
    println!("preemptions   : {} ({} pages evicted)", report.preemptions, report.evicted_pages);
    println!("peak pool use : {} pages", report.peak_pages);
    if prefix_cache {
        println!(
            "prefix cache  : {} hits / {} misses, {} shared pages attached, {} CoW copies",
            report.prefix_hits, report.prefix_misses, report.prefix_shared_pages, report.cow_copies
        );
        println!("prefill MACs  : {} (suffix-only under sharing)", report.prefill_macs);
    }
    println!(
        "resident KV   : {:.1} KiB peak ({:.2} pages/token; {} chains per sequence)",
        report.resident_kv_bytes as f64 / 1024.0,
        report.pages_per_token,
        layout.kv_heads
    );
    if spec_k > 1 {
        println!(
            "speculation   : --draft {draft} k={spec_k}{}: {} drafted, {} accepted ({:.1}%), {} fallback steps",
            if adaptive { " (adaptive)" } else { "" },
            report.drafted_tokens,
            report.accepted_tokens,
            report.accept_rate() * 100.0,
            report.spec_fallbacks
        );
    }
    println!(
        "decode plans  : {} built for {} sequences ({} tokens stepped through them)",
        report.plans_built, report.sequences, report.tokens
    );
    let rep = engine.report();
    println!("decode p50    : {:.2} ms", rep.p50_compute_ms);
    println!("decode p99    : {:.2} ms", rep.p99_compute_ms);
    println!("TTFT p50/p99  : {:.2} / {:.2} ms", rep.p50_ttft_ms, rep.p99_ttft_ms);
    println!("ITL  p50/p99  : {:.2} / {:.2} ms", rep.p50_itl_ms, rep.p99_itl_ms);
    if rep.fallbacks > 0 {
        println!("fallbacks     : {} (backend lacked a capability; see log)", rep.fallbacks);
    }
    Ok(())
}

/// `flashmask serve`: drive the streaming router under a seeded
/// Poisson arrival trace and report TTFT / per-token ITL percentiles;
/// with `--compare-fifo` the identical trace is replayed through the
/// strict-FIFO page-count batcher for a head-to-head latency table
/// (DESIGN.md §Serving).
fn cmd_serve(args: &Args) -> Result<()> {
    use flashmask::decode::{
        BatcherConfig, ContinuousBatcher, DecodeRequest, HeadLayout, SpecPolicy,
    };
    use flashmask::mask::builders;
    use flashmask::server::{poisson_arrivals_ms, replay_arrivals, Router, RouterConfig};
    use flashmask::util::rng::Rng;

    let n_requests = args.get_usize("requests", 12).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("n", 256).map_err(|e| anyhow!(e))?;
    let d = args.get_usize("d", 32).map_err(|e| anyhow!(e))?;
    let heads = args.get_usize("heads", 2).map_err(|e| anyhow!(e))?;
    let kv_heads = args.get_usize("kv-heads", heads).map_err(|e| anyhow!(e))?;
    let page = args.get_usize("page", 16).map_err(|e| anyhow!(e))?;
    let max_pages = args.get_usize("max-pages", 4096).map_err(|e| anyhow!(e))?;
    let max_active = args.get_usize("max-active", 8).map_err(|e| anyhow!(e))?;
    let rate = args.get_f64("rate", 200.0).map_err(|e| anyhow!(e))?;
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    let skip = !args.flag("dense");
    let prefix_cache = args.flag("prefix-cache");
    let prefill_budget = args.get_usize("prefill-budget", 4096).map_err(|e| anyhow!(e))?;
    let total_budget =
        args.get_usize("total-budget", max_pages * page / kv_heads.max(1)).map_err(|e| anyhow!(e))?;
    let ratio = args.get_f64("waiting-served-ratio", 1.2).map_err(|e| anyhow!(e))?;
    let max_waiting = args.get_usize("max-waiting", 20).map_err(|e| anyhow!(e))?;
    let compare_fifo = args.flag("compare-fifo");
    anyhow::ensure!(n >= 8, "--n must be >= 8 (got {n})");
    anyhow::ensure!(rate > 0.0, "--rate must be positive (got {rate})");
    anyhow::ensure!(
        kv_heads >= 1 && heads % kv_heads == 0,
        "--kv-heads must divide --heads ({heads} % {kv_heads} != 0)"
    );
    let layout = HeadLayout::new(heads, kv_heads);

    // the same ragged request set + arrival trace for every loop
    let mut rng = Rng::new(seed);
    let make_requests = |rng: &mut Rng| -> Vec<DecodeRequest> {
        (0..n_requests)
            .map(|i| {
                let ni = (n / 2 + (rng.range(0, (n / 2) as i64) as usize)).max(2 * page);
                let mask = match i % 4 {
                    0 => builders::causal(ni),
                    1 => builders::sliding_window(ni, (ni / 8).max(1)),
                    2 => builders::causal_document(ni, &[ni / 2, ni - ni / 2]),
                    _ => builders::random_eviction(ni, rng),
                };
                let mut mk = |hh: usize| {
                    (0..hh * ni * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>()
                };
                let q = mk(layout.q_heads);
                let k = mk(layout.kv_heads);
                let v = mk(layout.kv_heads);
                DecodeRequest::with_layout(i as u64, layout, ni, d, ni / 4, q, k, v, mask)
            })
            .collect()
    };
    let reqs = make_requests(&mut rng);
    let due = poisson_arrivals_ms(rate, n_requests, &mut rng);
    let batcher_cfg = BatcherConfig {
        page_size: page,
        d,
        max_pages,
        max_active,
        skip,
        spec: SpecPolicy::Off,
        prefix_cache,
    };

    println!(
        "serving {n_requests} requests (ragged n up to {n}, layout {layout}, d={d}) \
         at {rate:.0} req/s Poisson"
    );
    let mut router = Router::new(RouterConfig {
        batcher: batcher_cfg,
        max_batch_prefill_tokens: prefill_budget,
        max_batch_total_tokens: total_budget,
        waiting_served_ratio: ratio,
        max_waiting_tokens: max_waiting,
    });
    let mut rxs = Vec::new();
    let wall_ms = replay_arrivals(reqs.clone(), &due, |cmd| match cmd {
        Some(req) => {
            rxs.push(router.submit(req)?);
            Ok(true)
        }
        None => router.tick(),
    })?;
    let rep = router.report();
    let streamed: usize = rxs.iter().map(|rx| rx.try_iter().count()).sum();

    println!("\n=== router report (token-budget admission) ===");
    println!("sequences     : {} retired, {} cancelled", rep.sequences, rep.cancelled);
    println!("decoded tokens: {} ({} stream events)", rep.tokens, streamed);
    println!("throughput    : {:.0} tokens/s over {:.0} ms wall", rep.tokens_per_s, wall_ms);
    println!(
        "admission     : {} waves ({} forced), {} preemptions, {} prefill rejects",
        rep.waves, rep.forced_waves, rep.preemptions, rep.prefill_rejects
    );
    println!("peak pool use : {} pages", rep.peak_pages);
    println!("pages skipped : {:.1}%", rep.pages_skip_fraction * 100.0);
    if prefix_cache {
        println!(
            "prefix cache  : {} hits / {} misses, {} shared pages attached, {} CoW copies",
            rep.prefix_hits, rep.prefix_misses, rep.prefix_shared_pages, rep.cow_copies
        );
    }
    println!("TTFT p50/p99  : {:.2} / {:.2} ms", rep.ttft_p50_ms, rep.ttft_p99_ms);
    println!("ITL  p50/p99  : {:.2} / {:.2} ms (per-token gaps)", rep.itl_p50_ms, rep.itl_p99_ms);

    if compare_fifo {
        let mut b = ContinuousBatcher::new(batcher_cfg);
        let fifo_wall = replay_arrivals(reqs, &due, |cmd| match cmd {
            Some(req) => {
                b.submit(req)?;
                Ok(true)
            }
            None => b.step(),
        })?;
        let f = b.report();
        let mut t = Table::new(vec!["metric", "fifo (page-count)", "router (token-budget)"])
            .title("identical Poisson trace, head-to-head");
        t.row(vec![
            "TTFT p50/p99 ms".into(),
            format!("{:.2} / {:.2}", f.ttft_p50_ms, f.ttft_p99_ms),
            format!("{:.2} / {:.2}", rep.ttft_p50_ms, rep.ttft_p99_ms),
        ]);
        t.row(vec![
            "ITL p50/p99 ms".into(),
            format!("{:.2} / {:.2}", f.itl_p50_ms, f.itl_p99_ms),
            format!("{:.2} / {:.2}", rep.itl_p50_ms, rep.itl_p99_ms),
        ]);
        t.row(vec![
            "tokens/s".into(),
            format!("{:.0}", f.tokens_per_s),
            format!("{:.0}", rep.tokens_per_s),
        ]);
        t.row(vec!["preemptions".into(), f.preemptions.to_string(), rep.preemptions.to_string()]);
        t.row(vec![
            "wall ms".into(),
            format!("{fifo_wall:.0}"),
            format!("{wall_ms:.0}"),
        ]);
        t.print();
    }
    Ok(())
}

/// `flashmask metrics`: exercise the prefill + decode serving paths on
/// a small synthetic workload, then dump the global telemetry registry
/// (tile, plan-cache, decode and serve metrics from one registry) plus
/// the collected span trees as a JSON document on stdout.
fn cmd_metrics(args: &Args) -> Result<()> {
    use flashmask::decode::{BatcherConfig, SpecPolicy};
    use flashmask::mask::builders;
    use flashmask::server::{EngineKind, Request, RequestQueue, Scheduler, SchedulerConfig, ServeEngine};
    use flashmask::telemetry::trace;
    use flashmask::util::rng::Rng;

    let n = args.get_usize("n", 256).map_err(|e| anyhow!(e))?;
    let d = args.get_usize("d", 32).map_err(|e| anyhow!(e))?;
    let n_requests = args.get_usize("requests", 4).map_err(|e| anyhow!(e))?;
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    let sample_every = args.get_u64("sample-every", 1).map_err(|e| anyhow!(e))?;
    let watch = args.get_f64("watch", 0.0).map_err(|e| anyhow!(e))?;
    let watch_iters = args.get_usize("watch-iters", 3).map_err(|e| anyhow!(e))?;
    let trace_out = args.get("trace-out").map(str::to_string);
    anyhow::ensure!(n >= 32, "--n must be >= 32 (got {n})");
    anyhow::ensure!(n_requests >= 1, "--requests must be >= 1");
    anyhow::ensure!(watch >= 0.0, "--watch must be non-negative seconds (got {watch})");
    if !args.flag("no-trace") {
        trace::set_enabled(true);
        trace::set_sample_every(sample_every.max(1));
    }

    fn gauss(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32() * 0.5).collect()
    }

    let mut rng = Rng::new(seed);
    // prefill: repeat one mask so the plan cache records hits as well
    // as misses, plus one distinct mask for a second compile
    let mut queue = RequestQueue::new();
    for i in 0..n_requests {
        let mask = if i == 0 {
            builders::sliding_window(n, (n / 8).max(1))
        } else {
            builders::causal(n)
        };
        let (q, k, v) = (gauss(&mut rng, n * d), gauss(&mut rng, n * d), gauss(&mut rng, n * d));
        queue.push(Request::new(0, 1, n, d, q, k, v, mask))?;
    }
    let scheduler = Scheduler::new(SchedulerConfig { max_batch: n_requests, max_wait_ms: 0.0 });
    let mut engine = ServeEngine::new(EngineKind::Cpu { threads: 1 }, (16, 16));
    if let Some(plan) = scheduler.next_batch(&mut queue, std::time::Instant::now()) {
        engine.execute(plan)?;
    }
    // decode: a couple of short sequences through the batcher (rerun
    // before each --watch snapshot so successive dumps show the
    // registry counters advancing)
    let decode_round = |engine: &mut ServeEngine, rng: &mut Rng| -> Result<()> {
        let decode_reqs: Vec<_> = (0..2)
            .map(|_| {
                let mask = builders::causal(n);
                let (q, k, v) = (gauss(rng, n * d), gauss(rng, n * d), gauss(rng, n * d));
                Request::new(0, 1, n, d, q, k, v, mask).into_decode(n / 2)
            })
            .collect();
        engine.execute_decode(
            decode_reqs,
            BatcherConfig {
                page_size: 16,
                d,
                max_pages: 4096,
                max_active: 2,
                skip: true,
                spec: SpecPolicy::Off,
                prefix_cache: false,
            },
        )?;
        Ok(())
    };
    decode_round(&mut engine, &mut rng)?;

    let snapshots = if watch > 0.0 { watch_iters.max(1) } else { 1 };
    let mut all_roots = Vec::new();
    for i in 0..snapshots {
        if i > 0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(watch));
            decode_round(&mut engine, &mut rng)?;
        }
        if snapshots > 1 {
            println!("=== telemetry snapshot {}/{snapshots} (every {watch}s) ===", i + 1);
        }
        let roots = trace::take_roots();
        println!("{}", reports::telemetry_report_with_roots(&roots).to_string_pretty());
        all_roots.extend(roots);
    }
    if let Some(path) = trace_out {
        let doc = trace::roots_to_chrome_json(&all_roots);
        std::fs::write(&path, doc.to_string_pretty())
            .map_err(|e| anyhow!("writing --trace-out {path}: {e}"))?;
        println!(
            "chrome trace written to {path} ({} root spans; open in chrome://tracing or Perfetto)",
            all_roots.len()
        );
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let task = Task::parse(&args.get_or("task", "sft")).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("n", 4096).map_err(|e| anyhow!(e))?;
    let count = args.get_usize("count", 5).map_err(|e| anyhow!(e))?;
    let mut rng = flashmask::util::rng::Rng::new(args.get_u64("seed", 0).map_err(|e| anyhow!(e))?);
    let mut t = Table::new(vec!["sample", "docs", "rho", "layout (q+answers)"])
        .title(format!("synthetic {task} samples at N={n} (paper A.2.1)"));
    for i in 0..count {
        let s = docgen::gen_sample(n, task, &mut rng);
        let layout: Vec<String> = s
            .docs
            .iter()
            .map(|d| format!("{}+{:?}{}", d.question_len, d.answer_lens, if d.is_padding { "(pad)" } else { "" }))
            .collect();
        t.row(vec![
            i.to_string(),
            s.docs.len().to_string(),
            format!("{:.3}", s.sparsity),
            layout.join(" | "),
        ]);
    }
    t.print();
    if args.flag("histogram") {
        let h = docgen::sparsity_histogram(n, task, 60, 3);
        let mut t = Table::new(vec!["rho bin", "count"]).title("sparsity histogram (paper Fig 6)");
        for (c, cnt) in h {
            t.row(vec![format!("{c:.2}"), cnt.to_string()]);
        }
        t.print();
    }
    Ok(())
}
