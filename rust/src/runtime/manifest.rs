//! `artifacts/manifest.json` — the python↔rust ABI contract.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("spec missing dtype"))?
                .to_string(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
}

/// Model geometry (mirrors `ModelConfig` on the python side).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub br: usize,
    pub bc: usize,
    pub n_params: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub batch: usize,
    pub model: ModelInfo,
    pub params: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let get = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model missing '{k}'"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing artifacts"))?;
        for (name, a) in arts {
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(name.clone(), ArtifactInfo { file, inputs });
        }
        if params.is_empty() {
            bail!("manifest has no parameters");
        }
        Ok(Manifest {
            preset: j
                .get("preset")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(1),
            model: ModelInfo {
                vocab: get("vocab")?,
                d_model: get("d_model")?,
                n_layers: get("n_layers")?,
                n_heads: get("n_heads")?,
                d_head: get("d_head")?,
                d_ff: get("d_ff")?,
                max_seq: get("max_seq")?,
                br: get("br")?,
                bc: get("bc")?,
                n_params: get("n_params")?,
            },
            params,
            artifacts,
        })
    }

    pub fn n_leaves(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "preset": "tiny", "batch": 4,
        "model": {"vocab": 256, "d_model": 256, "n_layers": 4, "n_heads": 8,
                  "d_head": 32, "d_ff": 688, "max_seq": 512, "br": 64,
                  "bc": 64, "n_params": 3300000},
        "params": [{"name": "embed", "shape": [256, 256], "dtype": "float32"}],
        "artifacts": {
            "init": {"file": "init.hlo.txt",
                     "inputs": [{"name": "seed", "shape": [1], "dtype": "int32"}]}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.n_params, 3_300_000);
        assert_eq!(m.params[0].numel(), 256 * 256);
        assert_eq!(m.artifacts["init"].inputs[0].dtype, "int32");
    }

    #[test]
    fn rejects_empty_params() {
        let bad = SAMPLE.replace(
            r#""params": [{"name": "embed", "shape": [256, 256], "dtype": "float32"}]"#,
            r#""params": []"#,
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.contains_key("train_step_flashmask"));
            let ts = &m.artifacts["train_step_flashmask"];
            assert_eq!(ts.inputs.len(), 3 * m.n_leaves() + 1 + 7);
        }
    }
}
