//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos, but the text parser reassigns ids (see
//! /opt/xla-example/README.md).  Python never runs at request time: the
//! manifest carries the full ABI (argument order, shapes, dtypes).

pub mod manifest;

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

pub use manifest::{ArtifactInfo, Manifest, TensorSpec};

/// Host-side tensor: shape + f32/i32 storage, the runtime's ABI type.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            HostTensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            _ => bail!("not an f32 scalar"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("not an f32 tensor"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.len() == 1 {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported element type {other:?}"),
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.info.inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input '{}' shape {:?} != expected {:?}",
                    self.name,
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&lits)
    }

    /// Execute with prebuilt literals (hot path: callers may cache
    /// literals for constant operands).
    pub fn run_literals(&self, lits: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let result = self.exe.execute::<xla::Literal>(lits)?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The PJRT runtime: client + artifact directory + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open an artifacts directory produced by `make artifacts`.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`?)"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest })
    }

    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let info = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { name: name.to_string(), info, exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip_f32() {
        let t = HostTensor::F32 { shape: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] };
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 3]);
        assert_eq!(back.as_f32().unwrap(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn host_tensor_roundtrip_i32() {
        let t = HostTensor::I32 { shape: vec![4], data: vec![7, -1, 0, 3] };
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        match back {
            HostTensor::I32 { data, .. } => assert_eq!(data, vec![7, -1, 0, 3]),
            _ => panic!("wrong type"),
        }
    }

    #[test]
    fn scalar_helpers() {
        let t = HostTensor::F32 { shape: vec![], data: vec![2.5] };
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        let t2 = HostTensor::F32 { shape: vec![2], data: vec![1.0, 2.0] };
        assert!(t2.scalar_f32().is_err());
    }
}
