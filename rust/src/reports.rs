//! Report generators — one function per paper table/figure.
//!
//! Shared by the `flashmask` CLI subcommands and the `cargo bench`
//! targets (DESIGN.md §5 maps experiments to these functions).  Each
//! report prints (a) *measured* numbers from the CPU engine at
//! CPU-feasible sizes and (b) the calibrated A100-model projection at
//! the paper's sizes, next to the paper's published numbers where we
//! have them.

use crate::attention::api::{AttnProblem, Backend, CpuBackend, ExecutionPlan, KvViews, QViews};
use crate::attention::{bsr, flex, AttnConfig};
use crate::mask::{builders, FlashMask, MaskKind};
use crate::perf::a100_model::{self, Method};
use crate::perf::{flops, memory_model};
use crate::util::bench::{bench, BenchOpts};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::docgen::{self, Task};
use crate::workload::sparsity_buckets::{self, BucketConfig};

fn rand_qkv(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut mk = || (0..n * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
    (mk(), mk(), mk())
}

/// Independent upstream gradient for backward timings — never alias q
/// as dO (a correlated dP = dO·Vᵀ skews the measurement).
fn rand_do(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xD0D0);
    (0..n * d).map(|_| rng.normal_f32() * 0.5).collect()
}

/// Paper anchor values: FLASHMASK total TFLOPs/s from Tables 4–6 (hd128).
fn paper_anchor(kind: MaskKind, n: usize) -> Option<f64> {
    let rows_8k: &[(&str, f64)] = &[
        ("full", 204.81), ("causal", 198.39), ("sliding_window", 118.24),
        ("causal_document", 144.67), ("document", 158.40), ("share_question", 129.01),
        ("global_sliding_window", 138.47), ("causal_blockwise", 171.79),
        ("prefix_lm_document", 139.58), ("prefix_lm_causal", 178.03),
        ("qk_sparse", 179.74), ("random_eviction", 169.84),
    ];
    let rows_32k: &[(&str, f64)] = &[
        ("full", 211.41), ("causal", 211.73), ("sliding_window", 157.25),
        ("causal_document", 150.59), ("document", 150.84), ("share_question", 131.47),
        ("global_sliding_window", 157.71), ("causal_blockwise", 171.61),
        ("prefix_lm_document", 137.07), ("prefix_lm_causal", 186.90),
        ("qk_sparse", 192.51), ("random_eviction", 180.06),
    ];
    let rows_128k: &[(&str, f64)] = &[
        ("full", 213.27), ("causal", 213.41), ("sliding_window", 175.73),
        ("causal_document", 167.61), ("document", 165.71), ("share_question", 150.12),
        ("global_sliding_window", 166.85), ("causal_blockwise", 183.00),
        ("prefix_lm_document", 148.75), ("prefix_lm_causal", 188.19),
        ("qk_sparse", 194.44), ("random_eviction", 181.93),
    ];
    let rows = match n {
        8192 => rows_8k,
        32768 => rows_32k,
        131072 => rows_128k,
        _ => return None,
    };
    let name = kind.to_string();
    rows.iter().find(|(k, _)| *k == name).map(|(_, v)| *v)
}

/// Fig. 5/8 + Tables 4–9: kernel speed across the 12 mask cases.
///
/// `measure_n`: CPU-engine wall-clock size; `paper_ns`: A100-model
/// projection sizes.  `head_dim` ∈ {64, 128}.
///
/// Returns the measured section as a machine-readable [`Json`] blob
/// (one entry per mask) so `scripts/bench.sh` can persist the perf
/// trajectory into `BENCH_kernel.json`.  Asserts that the
/// interval-driven tile schedule visits strictly fewer tiles than the
/// dense `tr*tc` scan on every non-full mask with anything to skip at
/// this tile granularity — a perf regression in the scheduler fails
/// the bench loudly.
pub fn kernel_mask_report(
    measure_n: usize,
    paper_ns: &[usize],
    head_dim: usize,
    opts: BenchOpts,
) -> Json {
    // -- measured section (CPU engine) --
    let d = head_dim.min(64); // CPU time budget; structure is what matters
    let (q, k, v) = rand_qkv(measure_n, d, 1);
    let cfg = AttnConfig::new(64.min(measure_n), 64.min(measure_n), d);
    let mut t = Table::new(vec![
        "mask", "rho", "fm fw ms", "GF/s", "tiles visited", "fm bw ms", "dense-mask fw ms",
        "flex fw ms", "speedup vs dense",
    ])
    .title(format!(
        "measured CPU engine, N={measure_n}, d={d} (shape check; A100 projection below)"
    ));
    let qv = QViews::new(&q, 1, measure_n, d).expect("bench q view");
    let kvv = KvViews::new(&k, &v, 1, measure_n, d).expect("bench k/v views");
    let mut json_masks: Vec<Json> = Vec::new();
    for (kind, mask) in builders::benchmark_suite(measure_n, 42) {
        // one plan per (mask, shape), reused across every timed call —
        // the amortized serving path the PlanCache gives a deployment
        let problem = AttnProblem::new(measure_n, d).mask(&mask).tile(cfg.br, cfg.bc);
        let plan = problem.plan().expect("bench plan");
        let plan_dense = problem.skip(false).plan().expect("bench dense plan");
        let rho = mask.block_sparsity(cfg.br, cfg.bc);
        let fm_fw = bench("fm_fw", opts, || {
            let _ = CpuBackend.prefill(&plan, qv, kvv).expect("fm prefill");
        });
        let out = CpuBackend.prefill(&plan, qv, kvv).expect("fm prefill");
        let (fwd, st) = (&out.outs[0], out.stats);
        // interval scheduling must beat the dense tr*tc scan whenever
        // Eq. 4 skips anything at this tile granularity (tiny grids or
        // degenerate mask draws may legitimately have nothing to skip:
        // then there is nothing for ranges to exclude either)
        if kind != MaskKind::Full && st.tiles_skipped > 0 {
            assert!(
                st.tiles_visited < st.tiles_total,
                "{kind}: schedule visited {} of {} tiles with {} skipped — interval ranges bought nothing",
                st.tiles_visited,
                st.tiles_total,
                st.tiles_skipped
            );
        }
        let gflops = st.flops() as f64 / (fm_fw.median_ms / 1e3) / 1e9;
        let do_ = rand_do(measure_n, d, 42);
        let fm_bw = bench("fm_bw", opts, || {
            let _ = CpuBackend
                .backward(&plan, &q, &k, &v, &fwd.o, &do_, &fwd.lse)
                .expect("fm backward");
        });
        let dm_fw = bench("dm_fw", opts, || {
            let _ = CpuBackend.prefill(&plan_dense, qv, kvv).expect("dense-mask prefill");
        });
        let pred = |i: usize, j: usize| mask.allowed(i, j);
        let bm = flex::BlockMask::build(&pred, measure_n, cfg.br, cfg.bc);
        let fx_fw = bench("fx_fw", opts, || {
            let _ = flex::flex_forward(&q, &k, &v, measure_n, d, &pred, &bm, cfg);
        });
        t.row(vec![
            kind.to_string(),
            format!("{rho:.2}"),
            format!("{:.2}", fm_fw.median_ms),
            format!("{gflops:.1}"),
            format!("{}/{}", st.tiles_visited, st.tiles_total),
            format!("{:.2}", fm_bw.median_ms),
            format!("{:.2}", dm_fw.median_ms),
            format!("{:.2}", fx_fw.median_ms),
            format!("{:.2}x", dm_fw.median_ms / fm_fw.median_ms),
        ]);
        json_masks.push(Json::obj(vec![
            ("mask", Json::Str(kind.to_string())),
            ("rho", Json::Num(rho)),
            ("fm_fw_ms", Json::Num(fm_fw.median_ms)),
            ("fm_bw_ms", Json::Num(fm_bw.median_ms)),
            ("dense_mask_fw_ms", Json::Num(dm_fw.median_ms)),
            ("flex_fw_ms", Json::Num(fx_fw.median_ms)),
            ("gflops", Json::Num(gflops)),
            ("tiles_visited", Json::Num(st.tiles_visited as f64)),
            ("tiles_total", Json::Num(st.tiles_total as f64)),
            ("speedup_vs_dense", Json::Num(dm_fw.median_ms / fm_fw.median_ms)),
        ]));
    }
    t.print();

    // -- A100-model projection at paper scale --
    for &n in paper_ns {
        let (batch, heads) = flops::paper_bench_geometry(n, head_dim);
        let mut t = Table::new(vec![
            "mask", "rho", "FM total TF/s", "Flex total TF/s", "FM vs Flex", "paper FM TF/s",
        ])
        .title(format!(
            "A100 model projection, N={n} hd={head_dim} (paper Tables 4-9 / Fig 5,8)"
        ));
        for (kind, mask) in builders::benchmark_suite(n, 42) {
            let fm = a100_model::estimate(Method::FlashMask, &mask, batch, heads, head_dim);
            let fx = a100_model::estimate(Method::FlexAttention, &mask, batch, heads, head_dim);
            let (_, _, fm_t) = a100_model::tflops_per_s(&fm);
            let (_, _, fx_t) = a100_model::tflops_per_s(&fx);
            let anchor = if head_dim == 128 {
                paper_anchor(kind, n).map(|v| format!("{v:.1}")).unwrap_or_default()
            } else {
                String::new()
            };
            t.row(vec![
                kind.to_string(),
                format!("{:.2}", fm.sparsity),
                format!("{fm_t:.1}"),
                format!("{fx_t:.1}"),
                format!("+{:.1}%", (fm_t / fx_t - 1.0) * 100.0),
                anchor,
            ]);
        }
        t.print();
    }

    Json::obj(vec![
        ("measure_n", Json::Num(measure_n as f64)),
        ("head_dim", Json::Num(head_dim as f64)),
        ("measured_d", Json::Num(d as f64)),
        ("masks", Json::Arr(json_masks)),
    ])
}

/// Fig. 4(a): kernel latency vs block sparsity for three mask families.
pub fn sparsity_report(n: usize, d: usize, opts: BenchOpts, seed: u64) {
    let cfg = AttnConfig::new(64.min(n), 64.min(n), d);
    let (q, k, v) = rand_qkv(n, d, seed);
    let do_ = rand_do(n, d, seed);
    let qv = QViews::new(&q, 1, n, d).expect("bench q view");
    let kvv = KvViews::new(&k, &v, 1, n, d).expect("bench k/v views");
    for kind in [MaskKind::CausalDocument, MaskKind::ShareQuestion, MaskKind::Document] {
        let bcfg = BucketConfig { min_per_bucket: 1, max_per_bucket: 2, max_draws: 600 };
        let mut samples = sparsity_buckets::sample_buckets(kind, n, cfg.bc, &bcfg, seed);
        samples.sort_by(|a, b| a.sparsity.partial_cmp(&b.sparsity).unwrap());
        let mut t = Table::new(vec!["rho", "fw+bw ms (measured)", "tiles run", "A100 model ms"])
            .title(format!("latency vs sparsity: {kind} N={n} d={d} (paper Fig 4a)"));
        for s in &samples {
            let plan = AttnProblem::new(n, d)
                .mask(&s.mask)
                .tile(cfg.br, cfg.bc)
                .plan()
                .expect("bench plan");
            let st = bench("fwbw", opts, || {
                let out = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
                let _ = CpuBackend
                    .backward(&plan, &q, &k, &v, &out.outs[0].o, &do_, &out.outs[0].lse)
                    .expect("backward");
            });
            let census = plan.census();
            let est = a100_model::estimate(Method::FlashMask, &s.mask, 4, 32, 128);
            t.row(vec![
                format!("{:.2}", s.sparsity),
                format!("{:.2}", st.median_ms),
                format!("{}", census.tiles_partial + census.tiles_unmasked),
                format!("{:.2}", est.total_ms()),
            ]);
        }
        t.print();
    }
}

/// Tables 10–14: inference comparison vs FlashInfer-like baselines.
pub fn inference_report(n: usize, d: usize, opts: BenchOpts, seed: u64) {
    // block-aligned document mask (paper adapts data to multiples of 64)
    let align = 16usize.min(n / 4).max(1);
    let mut rng = Rng::new(seed);
    let n_docs = 4;
    let mut lens = vec![align; n_docs];
    let mut rest = n - align * n_docs;
    for l in lens.iter_mut().take(n_docs - 1) {
        let extra = (rng.gen_range((rest / align) as u64 + 1) as usize) * align;
        *l += extra;
        rest -= extra;
    }
    lens[n_docs - 1] += rest / align * align + rest % align; // absorb remainder
    let mask = builders::document(n, &lens);
    let pred = |i: usize, j: usize| mask.allowed(i, j);
    let (q, k, v) = rand_qkv(n, d, seed);
    let qv = QViews::new(&q, 1, n, d).expect("bench q view");
    let kvv = KvViews::new(&k, &v, 1, n, d).expect("bench k/v views");
    let scale = 1.0 / (d as f32).sqrt();
    let rho = mask.block_sparsity(align, align);

    let mut t = Table::new(vec!["method", "R/C", "fw ms", "vs FLASHMASK"])
        .title(format!("inference fwd, Document mask, N={n} d={d} rho={rho:.2} (paper Tables 12-14)"));
    let cfg = AttnConfig::new(64.min(n), 64.min(n), d);
    let fm_plan = |m: &FlashMask, skip: bool| -> ExecutionPlan {
        AttnProblem::new(n, d).mask(m).tile(cfg.br, cfg.bc).skip(skip).plan().expect("bench plan")
    };
    let plan = fm_plan(&mask, true);
    let plan_dense = fm_plan(&mask, false);
    let fm = bench("flashmask", opts, || {
        let _ = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
    });
    // FlashInfer dense: computes everything with a token mask
    let dm = bench("fi-dense", opts, || {
        let _ = CpuBackend.prefill(&plan_dense, qv, kvv).expect("prefill");
    });
    let mut rc = 1usize;
    while rc <= align {
        if n % rc == 0 {
            if let Ok(bsr_mask) = bsr::BsrMask::build(&pred, n, rc) {
                let st = bench("fi-sparse", opts, || {
                    let _ = bsr::bsr_forward(&q, &k, &v, n, d, &bsr_mask, scale);
                });
                t.row(vec![
                    "FlashInfer-like Sparse".into(),
                    format!("{rc}"),
                    format!("{:.2}", st.median_ms),
                    format!("{:.2}x", st.median_ms / fm.median_ms),
                ]);
            }
        }
        rc *= 2;
    }
    t.row(vec![
        "FlashInfer-like Dense".into(),
        "-".into(),
        format!("{:.2}", dm.median_ms),
        format!("{:.2}x", dm.median_ms / fm.median_ms),
    ]);
    t.row(vec!["FLASHMASK".into(), "-".into(), format!("{:.2}", fm.median_ms), "1.00x".into()]);
    t.print();

    // causal-document + shared-question single rows (Tables 10-11 shape)
    for kind in [MaskKind::CausalDocument, MaskKind::ShareQuestion] {
        let mask = builders::build(kind, n, &mut rng);
        let plan = fm_plan(&mask, true);
        let plan_dense = fm_plan(&mask, false);
        let fm = bench("fm", opts, || {
            let _ = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
        });
        let dm = bench("dm", opts, || {
            let _ = CpuBackend.prefill(&plan_dense, qv, kvv).expect("prefill");
        });
        let mut t = Table::new(vec!["method", "fw ms", "speedup"])
            .title(format!("inference fwd, {kind}, N={n} (paper Tables 10-11)"));
        t.row(vec!["FLASHMASK".into(), format!("{:.2}", fm.median_ms), "1.00x".into()]);
        t.row(vec![
            "dense-mask".into(),
            format!("{:.2}", dm.median_ms),
            format!("{:.2}x", dm.median_ms / fm.median_ms),
        ]);
        t.print();
    }
}

/// Table 2 + Fig. 4(b) + Fig. 7: memory model.
pub fn memory_report() {
    use memory_model::*;
    let mut t = Table::new(vec![
        "seq", "param+opt GB", "act GB", "peak layer GB", "dense mask GB", "flashmask MB",
        "total(FM) GB", "total(dense) GB", "paper total(FM)",
    ])
    .title("Llama2-7B per-GPU training memory (paper Table 2 / Fig 4b)");
    let paper_total = [
        (4096, 13.14), (8192, 13.73), (16384, 16.01), (32768, 19.52),
        (65536, 25.57), (131072, 42.08), (262144, 68.81),
    ];
    let layout = paper_layout(&LLAMA2_7B);
    for (seq, paper) in paper_total {
        let fm = breakdown(&LLAMA2_7B, &layout, seq, MaskMemory::FlashMask);
        let dm = breakdown(&LLAMA2_7B, &layout, seq, MaskMemory::DenseMask);
        t.row(vec![
            format!("{}K", seq / 1024),
            format!("{:.2}", fm.param_opt_gb),
            format!("{:.2}", fm.activations_gb),
            format!("{:.2}", fm.peak_layer_gb),
            format!("{:.2}", dm.mask_gb),
            format!("{:.3}", fm.mask_gb * 1024.0),
            format!("{:.2}", fm.total_gb),
            format!("{:.2}", dm.total_gb),
            format!("{paper:.2}"),
        ]);
    }
    t.print();

    let mut t = Table::new(vec!["model", "flashmask max seq", "dense max seq", "vanilla max seq"])
        .title("max trainable sequence in 80GB (paper Fig 2's length advantage)");
    for model in [LLAMA2_7B, LLAMA2_13B, LLAMA2_70B] {
        let layout = paper_layout(&model);
        t.row(vec![
            model.name.to_string(),
            format!("{}K", max_seq(&model, &layout, MaskMemory::FlashMask, 80.0) / 1024),
            format!("{}K", max_seq(&model, &layout, MaskMemory::DenseMask, 80.0) / 1024),
            format!("{}K", max_seq(&model, &layout, MaskMemory::VanillaDense, 80.0) / 1024),
        ]);
    }
    t.print();
}

/// Fig. 2 (analytic): end-to-end training throughput curves, and
/// Fig. 6: sparsity histogram of the synthetic dataset.
pub fn e2e_report(seed: u64) {
    for task in [Task::Sft, Task::Dpo, Task::Rm] {
        let mut t = Table::new(vec![
            "seq", "rho(mean)", "FM tok/s/gpu", "DenseMask tok/s", "Vanilla tok/s", "FM speedup",
        ])
        .title(format!("Llama2-7B {task} throughput model (paper Fig 2 shape)"));
        let model = memory_model::LLAMA2_7B;
        let layout = memory_model::paper_layout(&model);
        for seq in [4096usize, 8192, 16384, 32768, 65536, 131072] {
            let mut rng = Rng::new(seed ^ seq as u64);
            // mean sparsity of the task's mask family at this length
            let mut rho = 0.0;
            let reps = 4;
            for _ in 0..reps {
                rho += docgen::gen_sample(seq.min(16384), task, &mut rng).sparsity / reps as f64;
            }
            let heads_per_gpu = model.heads / layout.tp;
            let hd = model.hidden / model.heads;
            let est = |method: Method, mask_rho: f64| -> f64 {
                // per-layer attention time from the A100 model + dense
                // matmul time at 55% MFU (measured A800 full-recompute)
                let mask = synth_mask(seq, mask_rho);
                let e = a100_model::estimate(method, &mask, 1, heads_per_gpu, hd);
                let attn_s = e.total_ms() / 1e3 * (model.layers / layout.pp) as f64 * 1.33; // +recompute fwd
                let dense_flops = flops::transformer_train_flops_per_token(
                    model.n_params / (layout.tp * layout.pp) as f64,
                ) * seq as f64 * 1.33;
                let dense_s = dense_flops / (0.55 * a100_model::A100_PEAK_TFLOPS * 1e12);
                seq as f64 / (attn_s + dense_s)
            };
            let fits = |mm: memory_model::MaskMemory| {
                memory_model::breakdown(&model, &layout, seq, mm).total_gb <= 80.0
            };
            let fm = est(Method::FlashMask, rho);
            let dm = if fits(memory_model::MaskMemory::DenseMask) {
                est(Method::FlashDenseMask, rho)
            } else {
                f64::NAN
            };
            let va = if fits(memory_model::MaskMemory::VanillaDense) {
                est(Method::Vanilla, rho)
            } else {
                f64::NAN
            };
            let speedup = if dm.is_nan() { "OOM(dense)".to_string() } else { format!("{:.2}x", fm / dm) };
            t.row(vec![
                format!("{}K", seq / 1024),
                format!("{rho:.2}"),
                format!("{fm:.0}"),
                if dm.is_nan() { "OOM".into() } else { format!("{dm:.0}") },
                if va.is_nan() { "OOM".into() } else { format!("{va:.0}") },
                speedup,
            ]);
        }
        t.print();
    }

    // Fig 6: sparsity histogram of the synthetic training data
    let mut t = Table::new(vec!["rho bin", "sft", "dpo", "rm"])
        .title("synthetic dataset sparsity distribution (paper Fig 6)");
    let n = 4096;
    let h_sft = docgen::sparsity_histogram(n, Task::Sft, 60, seed);
    let h_dpo = docgen::sparsity_histogram(n, Task::Dpo, 60, seed);
    let h_rm = docgen::sparsity_histogram(n, Task::Rm, 60, seed);
    for i in 0..10 {
        t.row(vec![
            format!("{:.2}", h_sft[i].0),
            format!("{}", h_sft[i].1),
            format!("{}", h_dpo[i].1),
            format!("{}", h_rm[i].1),
        ]);
    }
    t.print();
}

/// `flashmask metrics` payload: the process-wide telemetry snapshot
/// (counters, gauges, latency histograms) plus any collected trace
/// roots, as one JSON document (DESIGN.md §Telemetry).
///
/// Unlike the other reports this does not *run* anything — it reads
/// whatever the preceding workload left in the global registry, so
/// callers populate it first (the CLI runs a small prefill+decode
/// workload before dumping).
pub fn telemetry_report() -> Json {
    let roots = crate::telemetry::trace::take_roots();
    telemetry_report_with_roots(&roots)
}

/// [`telemetry_report`] over an explicit set of already-drained span
/// roots — lets the CLI reuse one drain for both the JSON dump and a
/// chrome://tracing export
/// ([`roots_to_chrome_json`](crate::telemetry::trace::roots_to_chrome_json)).
pub fn telemetry_report_with_roots(roots: &[crate::telemetry::trace::SpanNode]) -> Json {
    let snap = crate::telemetry::metrics::global().snapshot();
    Json::obj(vec![
        ("metrics", snap),
        ("spans", crate::telemetry::trace::roots_to_json(roots)),
    ])
}

/// A synthetic causal-document mask hitting a target block sparsity
/// (helper for the throughput model).
fn synth_mask(n: usize, target_rho: f64) -> FlashMask {
    // causal mask has rho≈0.5; more docs => higher rho.  binary-search
    // the doc count.
    let mut k = 1usize;
    let mut best = builders::causal(n);
    for _ in 0..12 {
        let lens = vec![n / k.max(1); k.max(1)];
        let mut lens = lens;
        let sum: usize = lens.iter().sum();
        if sum < n {
            lens[0] += n - sum;
        }
        let m = builders::causal_document(n, &lens);
        let rho = m.block_sparsity(128.min(n), 128.min(n));
        best = m;
        if rho >= target_rho || k >= n / 256 {
            break;
        }
        k *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_mask_monotone() {
        let lo = synth_mask(2048, 0.5);
        let hi = synth_mask(2048, 0.95);
        assert!(hi.block_sparsity(128, 128) >= lo.block_sparsity(128, 128));
    }

    #[test]
    fn paper_anchor_lookup() {
        assert_eq!(paper_anchor(MaskKind::Full, 32768), Some(211.41));
        assert_eq!(paper_anchor(MaskKind::Causal, 999), None);
    }

    #[test]
    fn memory_report_runs() {
        memory_report();
    }
}
