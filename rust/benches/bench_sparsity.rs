//! `cargo bench --bench bench_sparsity`
//!
//! Regenerates paper Fig. 4(a): kernel latency (fwd+bwd) vs block
//! sparsity for Causal Document / Share Question / Document masks —
//! measured on the CPU engine (latency must fall linearly as ρ rises)
//! plus the A100-model projection at paper scale.

use flashmask::reports;
use flashmask::util::bench::BenchOpts;

fn main() {
    let n = std::env::var("FM_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024usize);
    let opts = BenchOpts { warmup: 1, iters: 5, max_seconds: 12.0 };
    reports::sparsity_report(n, 32, opts, 7);
}
