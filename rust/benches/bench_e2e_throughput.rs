//! `cargo bench --bench bench_e2e_throughput`
//!
//! Regenerates paper Fig. 2 (end-to-end training throughput across
//! sequence lengths and tasks, FLASHMASK vs dense baselines — analytic
//! A800-scale model with OOM cutoffs) and Fig. 6 (sparsity histogram of
//! the synthetic training data).
//!
//! A *measured* end-to-end run on this machine's PJRT CPU backend is
//! also performed when artifacts are present (a short train for each
//! attention variant), demonstrating the real stack.

use flashmask::coordinator::{Batcher, Trainer, TrainerOptions};
use flashmask::reports;
use flashmask::runtime::Runtime;
use flashmask::workload::docgen::Task;
use std::path::Path;

fn main() {
    reports::e2e_report(11);

    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts/ missing — skipping measured PJRT section; run `make artifacts`)");
        return;
    }
    println!("\n== measured PJRT CPU end-to-end (this machine) ==");
    let rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime unavailable: {e:#}");
            return;
        }
    };
    let steps = std::env::var("FM_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize);
    for variant in ["flashmask", "densemask"] {
        let mut trainer = Trainer::new(
            &rt,
            TrainerOptions { variant: variant.into(), quiet: true, ..Default::default() },
        )
        .expect("trainer");
        let mut batcher = Batcher::new(rt.manifest.model.max_seq, rt.manifest.batch, Task::Sft, 5);
        let log = trainer.train(&mut batcher, steps).expect("train");
        println!(
            "{variant:>10}: {} steps, {:>7.0} tok/s, final loss {:.4}",
            log.steps, log.tokens_per_s, log.losses.last().unwrap()
        );
    }
}
