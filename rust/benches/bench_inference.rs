//! `cargo bench --bench bench_inference`
//!
//! Regenerates paper Tables 10–14 (appendix B): inference forward pass,
//! FLASHMASK vs FlashInfer-like sparse BSR (varying mask block size R/C)
//! and FlashInfer-like dense-mask baselines.  The R/C sweep reproduces
//! the paper's finding that BSR only becomes competitive at R=C >= 16,
//! while FLASHMASK needs no block-aligned masks at all.

use flashmask::reports;
use flashmask::util::bench::BenchOpts;

fn main() {
    let opts = BenchOpts { warmup: 1, iters: 5, max_seconds: 12.0 };
    for n in [512usize, 1024, 2048] {
        println!("\n######## sequence length {n} ########");
        reports::inference_report(n, 64, opts, 7);
    }
}
