//! `cargo bench --bench bench_ablation`
//!
//! Ablations of FLASHMASK's design choices (DESIGN.md §8):
//!
//! 1. tile-size sweep — Br/Bc trade partial-tile overhead against skip
//!    granularity (the paper fixes 128×128 on A100);
//! 2. min/max precompute on/off — classify tiles from the precomputed
//!    8 vectors vs re-scanning the raw interval vectors per tile
//!    (the paper's "Preprocessing" step is exactly this saving);
//! 3. skip on/off — the headline mechanism, isolated.

use flashmask::attention::api::{AttnProblem, Backend, CpuBackend, KvViews, QViews};
use flashmask::attention::AttnConfig;
use flashmask::mask::{builders, BlockTable};
use flashmask::util::bench::{bench, BenchOpts};
use flashmask::util::rng::Rng;
use flashmask::util::table::Table;
use std::time::Instant;

fn main() {
    let n = std::env::var("FM_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1024usize);
    let d = 64;
    let opts = BenchOpts { warmup: 1, iters: 5, max_seconds: 10.0 };
    let mut rng = Rng::new(5);
    let mut mk = || (0..n * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
    let (q, k, v) = (mk(), mk(), mk());
    // dO must be its own draw: reusing q as the upstream gradient
    // correlates dP with S and flatters the backward timings
    let do_ = mk();
    let mask = builders::causal_document(n, &[n / 4; 4]);

    // 1. tile-size sweep
    let mut t = Table::new(vec!["Br", "Bc", "rho(block)", "fw ms", "fw+bw ms"])
        .title(format!("ablation: tile size sweep (causal-document, N={n}, d={d})"));
    for &(br, bc) in &[(16usize, 16usize), (32, 32), (64, 64), (128, 128), (32, 128), (128, 32)] {
        if br > n || bc > n {
            continue;
        }
        let cfg = AttnConfig::new(br, bc, d);
        let plan =
            AttnProblem::new(n, d).mask(&mask).tile(cfg.br, cfg.bc).plan().expect("plan");
        let qv = QViews::new(&q, 1, n, d).expect("q view");
        let kvv = KvViews::new(&k, &v, 1, n, d).expect("k/v views");
        let fw = bench("fw", opts, || {
            let _ = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
        });
        let fwbw = bench("fwbw", opts, || {
            let out = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
            let _ = CpuBackend
                .backward(&plan, &q, &k, &v, &out.outs[0].o, &do_, &out.outs[0].lse)
                .expect("backward");
        });
        t.row(vec![
            br.to_string(),
            bc.to_string(),
            format!("{:.2}", mask.block_sparsity(br, bc)),
            format!("{:.2}", fw.median_ms),
            format!("{:.2}", fwbw.median_ms),
        ]);
    }
    t.print();

    // 2. min/max precompute: build cost vs per-call classification saving
    let mut t = Table::new(vec!["what", "time"])
        .title("ablation: min/max preprocessing (paper Alg. 1 line 4)");
    let t0 = Instant::now();
    for _ in 0..1000 {
        let _ = std::hint::black_box(BlockTable::build(&mask, 64));
    }
    t.row(vec!["BlockTable::build x1000".into(), format!("{:.2} ms", t0.elapsed().as_secs_f64() * 1e3)]);
    let table = BlockTable::build(&mask, 64);
    let t0 = Instant::now();
    let mut acc = 0usize;
    for _ in 0..1000 {
        let (f, p, u) = table.census(&mask, 64);
        acc += f + p + u;
    }
    t.row(vec![
        format!("classify all tiles x1000 (census {acc})"),
        format!("{:.2} ms", t0.elapsed().as_secs_f64() * 1e3),
    ]);
    // naive: re-scan raw vectors per tile (what skipping the
    // preprocessing step would cost inside the kernel)
    let t0 = Instant::now();
    let mut naive = 0usize;
    for _ in 0..1000 {
        for bi in 0..n / 64 {
            for bj in 0..n / 64 {
                let cols = bj * 64..(bj + 1) * 64;
                let lts_max = cols.clone().map(|j| mask.lts[j]).max().unwrap();
                let lte_min = cols.clone().map(|j| mask.lte[j]).min().unwrap();
                let fully = (bi * 64) as i32 >= lts_max && ((bi + 1) * 64) as i32 <= lte_min;
                naive += usize::from(fully);
            }
        }
    }
    t.row(vec![
        format!("naive per-tile rescan x1000 ({naive} skips)"),
        format!("{:.2} ms", t0.elapsed().as_secs_f64() * 1e3),
    ]);
    t.print();

    // 3. skip on/off isolated, across sparsity levels
    let mut t = Table::new(vec!["docs", "rho", "skip fw ms", "no-skip fw ms", "speedup"])
        .title("ablation: block skipping isolated (the paper's mechanism)");
    for docs in [1usize, 2, 4, 8, 16] {
        if n / docs < 1 {
            continue;
        }
        let mask = builders::causal_document(n, &vec![n / docs; docs]);
        let problem = AttnProblem::new(n, d).mask(&mask).tile(64, 64);
        let plan = problem.plan().expect("plan");
        let plan_dense = problem.skip(false).plan().expect("plan");
        let qv = QViews::new(&q, 1, n, d).expect("q view");
        let kvv = KvViews::new(&k, &v, 1, n, d).expect("k/v views");
        let on = bench("on", opts, || {
            let _ = CpuBackend.prefill(&plan, qv, kvv).expect("prefill");
        });
        let off = bench("off", opts, || {
            let _ = CpuBackend.prefill(&plan_dense, qv, kvv).expect("prefill");
        });
        t.row(vec![
            docs.to_string(),
            format!("{:.2}", mask.block_sparsity(64, 64)),
            format!("{:.2}", on.median_ms),
            format!("{:.2}", off.median_ms),
            format!("{:.2}x", off.median_ms / on.median_ms),
        ]);
    }
    t.print();
}
