//! `cargo bench --bench bench_decode [-- --smoke] [-- --speculate K]`
//!
//! Autoregressive decode through the paged KV cache, two comparisons:
//!
//! 1. FLASHMASK page skipping vs. a dense-cache baseline that visits
//!    every page (the decode analogue of Tables 10–14).
//! 2. Speculative decoding (tree-mask verify, high-acceptance oracle
//!    drafter) vs. one-token-at-a-time sequential decode, reporting
//!    accepted-tokens/s — the FlashAttention-2 multi-row batching win.
//!
//! The speculative run double-checks the exactness guarantee: its
//! outputs are compared row-for-row against the sequential run and the
//! bench aborts on any divergence, so `scripts/verify.sh` fails loudly
//! if the kernel and the oracle ever disagree.
//!
//! `--smoke` shrinks the workload to a ~2 s run for scripts/verify.sh.

use flashmask::decode::{
    BatcherConfig, ContinuousBatcher, DecodeRequest, DecodeResponse, SpecPolicy,
};
use flashmask::mask::builders;
use flashmask::util::bench::time_once;
use flashmask::util::rng::Rng;
use flashmask::util::table::Table;

fn requests(n: usize, d: usize, heads: usize, count: usize, mask_of: &dyn Fn(usize, &mut Rng) -> flashmask::mask::FlashMask) -> Vec<DecodeRequest> {
    let mut rng = Rng::new(42);
    (0..count as u64)
        .map(|id| {
            let mask = mask_of(n, &mut rng);
            let mut mk =
                || (0..heads * n * d).map(|_| rng.normal_f32() * 0.5).collect::<Vec<f32>>();
            DecodeRequest::new(id, heads, n, d, n / 4, mk(), mk(), mk(), mask)
        })
        .collect()
}

fn run(
    reqs: &[DecodeRequest],
    page_size: usize,
    d: usize,
    skip: bool,
    spec: SpecPolicy,
) -> (f64, flashmask::decode::BatcherReport, Vec<DecodeResponse>) {
    let cfg = BatcherConfig { page_size, d, max_pages: 1 << 16, max_active: 8, skip, spec };
    let mut b = ContinuousBatcher::new(cfg);
    for r in reqs {
        b.submit(r.clone()).expect("submit");
    }
    let (report, ms) = time_once(|| b.run().expect("decode run"));
    let mut done = b.take_finished();
    done.sort_by_key(|r| r.id);
    (ms, report, done)
}

/// Oracle check: speculative outputs must match sequential row-for-row.
fn assert_identical(name: &str, seq: &[DecodeResponse], spec: &[DecodeResponse]) {
    assert_eq!(seq.len(), spec.len(), "{name}: sequence count diverged");
    for (a, b) in seq.iter().zip(spec) {
        assert_eq!(a.id, b.id, "{name}: retirement ids diverged");
        assert_eq!(a.o.len(), b.o.len(), "{name}: output shape diverged");
        for (i, (x, y)) in a.o.iter().zip(&b.o).enumerate() {
            assert!(
                (x - y).abs() < 1e-4,
                "{name}: speculative decode diverged from sequential at req {} elem {i}: {x} vs {y}",
                a.id
            );
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let spec_k: usize = match args.iter().position(|a| a == "--speculate") {
        None => 4,
        Some(i) => args
            .get(i + 1)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--speculate needs an integer draft budget")),
    };
    let (n, d, heads, count) = if smoke { (256, 16, 1, 2) } else { (1024, 32, 2, 4) };
    let page_size = 32;
    assert!(n >= 4 * page_size, "acceptance regime: n >= 4x page size");

    let cases: Vec<(&str, Box<dyn Fn(usize, &mut Rng) -> flashmask::mask::FlashMask>)> = vec![
        ("causal", Box::new(|n, _| builders::causal(n))),
        ("sliding_window", Box::new(|n, _| builders::sliding_window(n, (n / 8).max(1)))),
        (
            "causal_document",
            Box::new(|n, rng| {
                let k = flashmask::workload::docgen::sample_doc_lens(n, 4, 1, rng);
                builders::causal_document(n, &k)
            }),
        ),
        ("random_eviction", Box::new(|n, rng| builders::random_eviction(n, rng))),
    ];

    println!(
        "decode bench: n={n} d={d} heads={heads} seqs={count} page={page_size} speculate={spec_k}{}",
        if smoke { " (smoke)" } else { "" }
    );
    let mut t = Table::new(vec![
        "mask",
        "tok/s skip",
        "tok/s dense",
        "speedup",
        "pages skipped",
    ])
    .title("paged-KV decode: FLASHMASK page skip vs dense cache");
    let mut s = Table::new(vec![
        "mask",
        "accepted tok/s",
        "sequential tok/s",
        "speedup",
        "accept rate",
        "pages skipped",
    ])
    .title(format!(
        "speculative decode (oracle draft, k={spec_k}) vs one-token-at-a-time"
    ));
    for (name, mask_of) in &cases {
        let reqs = requests(n, d, heads, count, mask_of.as_ref());
        let (ms_skip, rep_skip, seq_out) = run(&reqs, page_size, d, true, SpecPolicy::Off);
        let (ms_dense, _, _) = run(&reqs, page_size, d, false, SpecPolicy::Off);
        let tokens = rep_skip.tokens;
        let tps_skip = tokens as f64 / (ms_skip / 1e3);
        let tps_dense = tokens as f64 / (ms_dense / 1e3);
        let frac = rep_skip.pages_skip_fraction;
        if *name == "sliding_window" {
            assert!(frac > 0.0, "sliding-window decode must skip pages at n >= 4x page size");
        }
        t.row(vec![
            name.to_string(),
            format!("{tps_skip:.0}"),
            format!("{tps_dense:.0}"),
            format!("{:.2}x", ms_dense / ms_skip),
            format!("{:.1}%", frac * 100.0),
        ]);

        if spec_k > 1 {
            let policy =
                SpecPolicy::Oracle { k: spec_k, accept_rate: 1.0, branch: 1, seed: 99 };
            let (ms_spec, rep_spec, spec_out) = run(&reqs, page_size, d, true, policy);
            assert_identical(name, &seq_out, &spec_out);
            assert_eq!(rep_spec.tokens, tokens, "{name}: speculative run dropped tokens");
            assert!(
                rep_spec.accept_rate() > 0.99,
                "{name}: high-acceptance draft accepted only {:.2}",
                rep_spec.accept_rate()
            );
            let tps_spec = tokens as f64 / (ms_spec / 1e3);
            s.row(vec![
                name.to_string(),
                format!("{tps_spec:.0}"),
                format!("{tps_skip:.0}"),
                format!("{:.2}x", ms_skip / ms_spec),
                format!("{:.1}%", rep_spec.accept_rate() * 100.0),
                format!("{:.1}%", rep_spec.pages_skip_fraction * 100.0),
            ]);
        }
    }
    t.print();
    if spec_k > 1 {
        s.print();
    }
}
